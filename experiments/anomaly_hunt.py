"""Bisect the 8-16 node SSDUP+ shortfall (see experiments/ANOMALY.md).

Replays the fleet benchmark's mixed workload (the exact recipe behind the
``fleet_*`` rows in bench_results.csv) while varying, one axis at a time:

* node count x scheme x shard policy        (--nodes / --schemes / --policies)
* the traffic-aware flush gate              (--gates, ssdup+ only)
* per-shard vs fleet-scope threshold state  (--scopes, via
  ``FleetSimulator(threshold_scope=...)``)
* adaptive-threshold window                 (--windows)
* trace composition (arrival burstiness)    (--bursts)

plus a straggler drill-down (--straggler N) that reruns the straggler
node's shard alone and dumps the per-stream routing decisions
(percentage, threshold-in-effect, device) next to the node's clocks —
the level at which the flush-gate self-interference mechanism is visible.

    PYTHONPATH=src python experiments/anomaly_hunt.py              # full hunt
    PYTHONPATH=src python experiments/anomaly_hunt.py --straggler 16
    PYTHONPATH=src python experiments/anomaly_hunt.py --csv out.csv
"""

from __future__ import annotations

import argparse
import sys
from typing import Sequence

sys.path.insert(0, "src")

from repro.core import (  # noqa: E402
    FleetSimulator,
    IONodeSimulator,
    TraceBatch,
    compute_stream_scores,
    ior,
    mixed,
    relabel,
)
from repro.core.workloads import GiB, MiB  # noqa: E402
from repro.testing.perf import atomic_write_text  # noqa: E402

SCHEMES = ("orangefs", "orangefs-bb", "ssdup", "ssdup+")
POLICIES = ("range-offset", "round-robin-app", "hash-file")


def build_load(total_bytes: int, burst_requests: int | None = 512) -> TraceBatch:
    """The bench_fleet.bench_scaling recipe (4-app mix), parameterized
    by arrival burstiness so trace composition can be swept."""

    per_app = max(total_bytes // 4, 64 * MiB)
    apps = [
        relabel(ior("segmented-contiguous", 8, total_bytes=per_app, seed=1),
                app_id=0, file_id=0),
        relabel(ior("segmented-random", 8, total_bytes=per_app, seed=2),
                app_id=1, file_id=1),
        relabel(ior("strided", 32, total_bytes=per_app, seed=3),
                app_id=2, file_id=2),
        relabel(ior("segmented-random", 16, total_bytes=per_app, seed=4),
                app_id=3, file_id=3),
    ]
    return TraceBatch.from_requests(mixed(*apps, burst_requests=burst_requests).trace)


def run_one(batch: TraceBatch, nodes: int, scheme: str, policy: str,
            **kwargs):
    fleet_ssd = batch.total_bytes // 2
    return FleetSimulator(
        num_nodes=nodes, scheme=scheme, policy=policy,
        ssd_capacity=max(fleet_ssd // nodes, 64 * MiB), **kwargs,
    ).run(batch)


def _row(experiment: str, scheme: str, policy: str, nodes: int,
         variant: str, fr) -> dict:
    return {
        "experiment": experiment,
        "scheme": scheme,
        "policy": policy,
        "nodes": nodes,
        "variant": variant,
        "agg_mbs": round(fr.throughput_mbs, 1),
        "straggler_io_s": round(fr.io_seconds, 4),
        "imbalance": round(fr.load_imbalance, 3),
        "ssd_ratio": round(fr.ssd_byte_ratio, 3),
    }


def _print_rows(rows: list[dict]) -> None:
    cols = ("experiment", "scheme", "policy", "nodes", "variant",
            "agg_mbs", "straggler_io_s", "imbalance", "ssd_ratio")
    widths = {c: max(len(c), *(len(str(r[c])) for r in rows)) for c in cols}
    print("  ".join(c.ljust(widths[c]) for c in cols))
    for r in rows:
        print("  ".join(str(r[c]).ljust(widths[c]) for c in cols))


# -- the hunt axes -----------------------------------------------------


def hunt_base(batch, nodes_list, schemes, policies) -> list[dict]:
    """Axis 1: where does the shortfall live? (scheme x policy x nodes)"""

    rows = []
    for policy in policies:
        for nodes in nodes_list:
            for scheme in schemes:
                fr = run_one(batch, nodes, scheme, policy)
                rows.append(_row("base", scheme, policy, nodes, "-", fr))
    return rows


def hunt_gates(batch, nodes_list, gates) -> list[dict]:
    """Axis 2: the traffic-aware flush gate (ssdup+, range-offset)."""

    rows = []
    for nodes in nodes_list:
        for gate in gates:
            fr = run_one(batch, nodes, "ssdup+", "range-offset",
                         flush_gate=gate)
            rows.append(_row("flush-gate", "ssdup+", "range-offset", nodes,
                             f"gate={gate}", fr))
    return rows


def hunt_scopes(batch, nodes_list) -> list[dict]:
    """Axis 3: per-shard (cold) vs fleet-scope (warm) threshold state."""

    rows = []
    for nodes in nodes_list:
        for scope in ("node", "fleet"):
            fr = run_one(batch, nodes, "ssdup+", "range-offset",
                         threshold_scope=scope)
            rows.append(_row("threshold-scope", "ssdup+", "range-offset",
                             nodes, f"scope={scope}", fr))
    return rows


def hunt_windows(batch, nodes_list, windows) -> list[dict]:
    """Axis 4: adaptive-threshold window (history length)."""

    rows = []
    for nodes in nodes_list:
        for window in windows:
            fr = run_one(batch, nodes, "ssdup+", "range-offset",
                         adaptive_window=window)
            rows.append(_row("adaptive-window", "ssdup+", "range-offset",
                             nodes, f"window={window}", fr))
    return rows


def hunt_bursts(total_bytes, nodes_list, bursts) -> list[dict]:
    """Axis 5: trace composition (arrival burstiness changes how many
    coherent streams each shard sees)."""

    rows = []
    for burst in bursts:
        batch = build_load(total_bytes, burst_requests=burst)
        for nodes in nodes_list:
            for scheme in ("orangefs", "ssdup+"):
                fr = run_one(batch, nodes, scheme, "range-offset")
                rows.append(_row("burstiness", scheme, "range-offset",
                                 nodes, f"burst={burst}", fr))
    return rows


def straggler_report(batch, nodes: int, scheme: str = "ssdup+",
                     policy: str = "range-offset", **kwargs) -> None:
    """Rerun the straggler node's shard alone and dump routing decisions."""

    fleet_ssd = batch.total_bytes // 2
    cap = max(fleet_ssd // nodes, 64 * MiB)
    fleet = FleetSimulator(num_nodes=nodes, scheme=scheme, policy=policy,
                           ssd_capacity=cap, **kwargs)
    fr = fleet.run(batch)
    idx = fr.straggler
    shard = fleet.shard(batch)[idx]
    scores = compute_stream_scores(shard)
    node = IONodeSimulator(scheme=scheme, ssd_capacity=cap, **kwargs)
    res = node.run(shard, scores=scores)

    print(f"\n== straggler: node {idx}/{nodes} ({scheme}, {policy}) ==")
    print(f"shard: {shard.num_requests} requests, "
          f"{shard.total_bytes / MiB:.0f} MiB, "
          f"{len(scores)} streams; node ssd_capacity {cap / MiB:.0f} MiB")
    if node.redirector is not None:
        print(f"{'stream':>6s} {'pct':>7s} {'thr_in_effect':>13s} {'device':>7s}")
        for i, (pct, thr, device) in enumerate(node.redirector.decisions):
            print(f"{i:6d} {pct:7.3f} {thr:13.3f} {device.name.lower():>7s}")
    print(f"io_seconds={res.io_seconds:.4f}  total={res.total_seconds:.4f}  "
          f"flushes={res.flushes}  blocked={res.blocked_seconds:.4f}  "
          f"ssd_bytes={res.bytes_to_ssd}")
    base = IONodeSimulator(scheme="orangefs").run(shard)
    print(f"orangefs same shard: io_seconds={base.io_seconds:.4f} "
          f"(delta {res.io_seconds - base.io_seconds:+.4f}s)")


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--total-bytes", type=int, default=2 * GiB)
    ap.add_argument("--nodes", default="1,2,4,8,16",
                    help="node counts for the base table")
    ap.add_argument("--variant-nodes", default="8,16",
                    help="node counts for the variant axes")
    ap.add_argument("--schemes", default=",".join(SCHEMES))
    ap.add_argument("--policies", default=",".join(POLICIES))
    ap.add_argument("--gates", default="0.5,0.75,1.01",
                    help="flush_gate values (>1 never flushes concurrently)")
    ap.add_argument("--windows", default="64,none")
    ap.add_argument("--bursts", default="512,128,none")
    ap.add_argument("--skip", default="",
                    help="comma-separated axes to skip "
                         "(base,flush-gate,threshold-scope,adaptive-window,"
                         "burstiness)")
    ap.add_argument("--straggler", type=int, default=None, metavar="NODES",
                    help="only dump the straggler drill-down at this size")
    ap.add_argument("--gate", type=float, default=0.5,
                    help="flush_gate for --straggler")
    ap.add_argument("--csv", default=None,
                    help="also write the sweep table to this CSV (atomic)")
    args = ap.parse_args(argv)

    batch = build_load(args.total_bytes)
    if args.straggler is not None:
        straggler_report(batch, args.straggler, flush_gate=args.gate)
        return 0

    nodes_list = [int(n) for n in args.nodes.split(",")]
    vnodes = [int(n) for n in args.variant_nodes.split(",")]
    skip = set(filter(None, args.skip.split(",")))
    axes = {"base", "flush-gate", "threshold-scope", "adaptive-window",
            "burstiness"}
    if skip - axes:
        ap.error(f"unknown --skip axes {sorted(skip - axes)}; "
                 f"choose from {sorted(axes)}")
    rows: list[dict] = []
    if "base" not in skip:
        rows += hunt_base(batch, nodes_list, args.schemes.split(","),
                          args.policies.split(","))
    if "flush-gate" not in skip:
        rows += hunt_gates(batch, vnodes,
                           [float(g) for g in args.gates.split(",")])
    if "threshold-scope" not in skip:
        rows += hunt_scopes(batch, vnodes)
    if "adaptive-window" not in skip:
        rows += hunt_windows(batch, vnodes,
                             [None if w == "none" else int(w)
                              for w in args.windows.split(",")])
    if "burstiness" not in skip:
        rows += hunt_bursts(args.total_bytes, vnodes,
                            [None if b == "none" else int(b)
                             for b in args.bursts.split(",")])

    _print_rows(rows)
    if args.csv:
        cols = list(rows[0])
        text = ",".join(cols) + "\n" + "\n".join(
            ",".join(str(r[c]) for c in cols) for r in rows) + "\n"
        atomic_write_text(args.csv, text)
        print(f"\nwrote {args.csv}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
