"""Distribution substrate: sharding rules, fault tolerance, elasticity."""

from repro.distributed.sharding import (
    DEFAULT_RULES,
    TRACE_POLICIES,
    assign_nodes,
    constrain,
    named_sharding,
    shard_hash_file,
    shard_range_offset,
    shard_round_robin_app,
    spec_for,
    tree_shardings,
    use_mesh,
)

__all__ = [
    "DEFAULT_RULES",
    "TRACE_POLICIES",
    "assign_nodes",
    "constrain",
    "named_sharding",
    "shard_hash_file",
    "shard_range_offset",
    "shard_round_robin_app",
    "spec_for",
    "tree_shardings",
    "use_mesh",
]
