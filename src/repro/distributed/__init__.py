"""Distribution substrate: sharding rules, fault tolerance, elasticity."""

from repro.distributed.sharding import (
    DEFAULT_RULES,
    constrain,
    named_sharding,
    spec_for,
    tree_shardings,
    use_mesh,
)

__all__ = [
    "DEFAULT_RULES",
    "constrain",
    "named_sharding",
    "spec_for",
    "tree_shardings",
    "use_mesh",
]
