"""Fault-tolerance runtime: heartbeats, straggler detection, elastic remesh.

Designed for the 1000+ node regime (DESIGN.md §5): every host runs a
heartbeat reporter; the (replicated) controller view marks hosts dead after
``timeout`` and flags stragglers by a robust p95 rule on step durations.
Recovery actions compose with the checkpoint substrate:

* dead host       -> restart from the newest committed manifest, possibly
                     under a SMALLER data axis (elastic remesh — batch
                     re-shards because checkpoints store logical arrays)
* straggler       -> the data loader re-issues the slow host's shard to a
                     backup host (work stealing); step commit waits only for
                     the quorum
* torn checkpoint -> invisible by construction (manifest commit point)

Pure-Python state machines (deterministic, unit-testable); the wall-clock
is injected so tests drive time explicitly.
"""

from __future__ import annotations

import dataclasses
import statistics
from typing import Callable


@dataclasses.dataclass
class HostState:
    host_id: int
    last_heartbeat: float
    step_durations: list[float] = dataclasses.field(default_factory=list)
    alive: bool = True

    def record_step(self, seconds: float, window: int = 64) -> None:
        self.step_durations.append(seconds)
        if len(self.step_durations) > window:
            self.step_durations.pop(0)


class HeartbeatTable:
    """Controller-side liveness + straggler view."""

    def __init__(self, timeout: float = 30.0,
                 straggler_factor: float = 1.5,
                 clock: Callable[[], float] | None = None):
        self.timeout = timeout
        self.straggler_factor = straggler_factor
        self.clock = clock or (lambda: 0.0)
        self.hosts: dict[int, HostState] = {}

    def register(self, host_id: int) -> None:
        self.hosts[host_id] = HostState(host_id, self.clock())

    def heartbeat(self, host_id: int, step_seconds: float | None = None) -> None:
        h = self.hosts[host_id]
        h.last_heartbeat = self.clock()
        h.alive = True
        if step_seconds is not None:
            h.record_step(step_seconds)

    def dead_hosts(self) -> list[int]:
        now = self.clock()
        out = []
        for h in self.hosts.values():
            if now - h.last_heartbeat > self.timeout:
                h.alive = False
                out.append(h.host_id)
        return sorted(out)

    def stragglers(self) -> list[int]:
        """Hosts whose median step exceeds straggler_factor x fleet p95-of-
        medians floor (robust to a few noisy samples)."""

        meds = {
            h.host_id: statistics.median(h.step_durations)
            for h in self.hosts.values()
            if h.alive and len(h.step_durations) >= 4
        }
        if len(meds) < 4:
            return []
        fleet = statistics.median(meds.values())
        return sorted(
            hid for hid, m in meds.items() if m > self.straggler_factor * fleet
        )


@dataclasses.dataclass(frozen=True)
class Topology:
    pods: int
    data: int
    model: int

    @property
    def n_hosts(self) -> int:
        return self.pods * self.data * self.model

    def global_batch_shards(self) -> int:
        return self.pods * self.data


class ElasticPlan:
    """Shrink/grow plan when hosts die: keep the model axis intact (TP
    groups must be complete), drop whole data-parallel replicas."""

    def __init__(self, topo: Topology):
        self.topo = topo

    def replan(self, dead: list[int]) -> Topology:
        """Map dead host ids to their data-replica index; drop those
        replicas.  Host ids are laid out (pod, data, model) row-major."""

        if not dead:
            return self.topo
        dead_replicas = set()
        for hid in dead:
            replica = hid // self.topo.model  # (pod, data) flat index
            dead_replicas.add(replica)
        total_replicas = self.topo.pods * self.topo.data
        remaining = total_replicas - len(dead_replicas)
        if remaining <= 0:
            raise RuntimeError("no data replicas left; cannot shrink further")
        # keep the pod structure if divisible, else collapse to one pod
        if remaining % self.topo.pods == 0:
            return Topology(self.topo.pods, remaining // self.topo.pods,
                            self.topo.model)
        return Topology(1, remaining, self.topo.model)


@dataclasses.dataclass
class RecoveryAction:
    kind: str  # "restart_from_checkpoint" | "steal_shard" | "none"
    detail: dict


class FaultToleranceController:
    """Glue: observe table, emit recovery actions (consumed by the trainer)."""

    def __init__(self, table: HeartbeatTable, topo: Topology):
        self.table = table
        self.plan = ElasticPlan(topo)
        self.topo = topo

    def tick(self) -> list[RecoveryAction]:
        actions: list[RecoveryAction] = []
        dead = self.table.dead_hosts()
        if dead:
            new_topo = self.plan.replan(dead)
            actions.append(RecoveryAction(
                "restart_from_checkpoint",
                {"dead_hosts": dead,
                 "old_topology": dataclasses.asdict(self.topo),
                 "new_topology": dataclasses.asdict(new_topo)},
            ))
            self.topo = new_topo
            self.plan = ElasticPlan(new_topo)
        for hid in self.table.stragglers():
            actions.append(RecoveryAction(
                "steal_shard", {"from_host": hid}))
        return actions
