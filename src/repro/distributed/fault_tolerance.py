"""Fault-tolerance runtime: heartbeats, straggler detection, elastic remesh.

Designed for the 1000+ node regime (DESIGN.md §5): every host runs a
heartbeat reporter; the (replicated) controller view marks hosts dead after
``timeout`` and flags stragglers by a robust p95 rule on step durations.
Recovery actions compose with the checkpoint substrate:

* dead host       -> restart from the newest committed manifest, possibly
                     under a SMALLER data axis (elastic remesh — batch
                     re-shards because checkpoints store logical arrays)
* straggler       -> the data loader re-issues the slow host's shard to a
                     backup host (work stealing); step commit waits only for
                     the quorum
* torn checkpoint -> invisible by construction (manifest commit point)

Pure-Python state machines (deterministic, unit-testable); the wall-clock
is injected so tests drive time explicitly.
"""

from __future__ import annotations

import collections
import dataclasses
import statistics
from typing import Callable


@dataclasses.dataclass
class HostState:
    host_id: int
    last_heartbeat: float
    window: int = 64
    step_durations: collections.deque = None  # deque[float], maxlen=window
    alive: bool = True

    def __post_init__(self):
        # step history is an O(1) bounded ring, not a list with pop(0)
        if self.step_durations is None:
            self.step_durations = collections.deque(maxlen=self.window)
        elif not isinstance(self.step_durations, collections.deque):
            self.step_durations = collections.deque(
                self.step_durations, maxlen=self.window
            )

    def record_step(self, seconds: float) -> None:
        self.step_durations.append(seconds)


class HeartbeatTable:
    """Controller-side liveness + straggler view.

    Liveness is a pure function of ``now - last_heartbeat``: a host that
    misses the timeout shows up in :meth:`dead_hosts`, and a LATE heartbeat
    revives it — callers never need to re-register.  (``register`` is only
    for admitting a brand-new host; it resets the step history.)
    """

    def __init__(self, timeout: float = 30.0,
                 straggler_factor: float = 1.5,
                 clock: Callable[[], float] | None = None,
                 step_window: int = 64):
        self.timeout = timeout
        self.straggler_factor = straggler_factor
        self.clock = clock or (lambda: 0.0)
        self.step_window = step_window
        self.hosts: dict[int, HostState] = {}

    def register(self, host_id: int) -> None:
        self.hosts[host_id] = HostState(host_id, self.clock(),
                                        window=self.step_window)

    def heartbeat(self, host_id: int, step_seconds: float | None = None) -> None:
        h = self.hosts[host_id]
        h.last_heartbeat = self.clock()
        h.alive = True  # a late heartbeat revives a declared-dead host
        if step_seconds is not None:
            h.record_step(step_seconds)

    def dead_hosts(self) -> list[int]:
        now = self.clock()
        out = []
        for h in self.hosts.values():
            timed_out = now - h.last_heartbeat > self.timeout
            h.alive = not timed_out
            if timed_out:
                out.append(h.host_id)
        return sorted(out)

    def stragglers(self) -> list[int]:
        """Hosts whose median step exceeds straggler_factor x fleet p95-of-
        medians floor (robust to a few noisy samples)."""

        meds = {
            h.host_id: statistics.median(h.step_durations)
            for h in self.hosts.values()
            if h.alive and len(h.step_durations) >= 4
        }
        if len(meds) < 4:
            return []
        fleet = statistics.median(meds.values())
        return sorted(
            hid for hid, m in meds.items() if m > self.straggler_factor * fleet
        )


@dataclasses.dataclass(frozen=True)
class Topology:
    pods: int
    data: int
    model: int

    @property
    def n_hosts(self) -> int:
        return self.pods * self.data * self.model

    def global_batch_shards(self) -> int:
        return self.pods * self.data


class ElasticPlan:
    """Shrink/grow plan when hosts die: keep the model axis intact (TP
    groups must be complete), drop whole data-parallel replicas.

    The plan is ANCHORED at the original topology: ``replan(dead)`` is a
    pure, idempotent function of the *complete* dead set, with host ids
    always interpreted in the original (pod, data, model) row-major
    layout.  Reporting the same dead set twice yields the same topology
    (the historical bug was a caller rebasing the plan on the shrunken
    topology, so a host reported twice shrank the fleet twice), and a
    SMALLER dead set (a revived host) grows the topology back.
    """

    def __init__(self, topo: Topology):
        self.topo = topo  # the original topology; never rebased

    def dead_replicas(self, dead: list[int]) -> set[int]:
        """Map dead host ids to (pod, data) replica indices."""

        return {hid // self.topo.model for hid in dead}

    def replan(self, dead: list[int]) -> Topology:
        """Topology with every replica holding a dead host dropped."""

        if not dead:
            return self.topo
        dead_replicas = self.dead_replicas(dead)
        total_replicas = self.topo.pods * self.topo.data
        remaining = total_replicas - len(dead_replicas)
        if remaining <= 0:
            raise RuntimeError("no data replicas left; cannot shrink further")
        # keep the pod structure if divisible, else collapse to one pod
        if remaining % self.topo.pods == 0:
            return Topology(self.topo.pods, remaining // self.topo.pods,
                            self.topo.model)
        return Topology(1, remaining, self.topo.model)


@dataclasses.dataclass
class RecoveryAction:
    kind: str  # "restart_from_checkpoint" | "rejoin" | "steal_shard" | "none"
    detail: dict


class FaultToleranceController:
    """Glue: observe table, emit recovery actions.

    Consumed by the trainer (restart-from-checkpoint under a smaller
    mesh) AND by the burst-buffer service layer
    (:mod:`repro.service.loop`), which maps ``restart_from_checkpoint``
    to I/O-node failover (reshard + backlog replay) and ``steal_shard``
    to LBICA-style hot-stream rebalancing off the straggler.

    ``tick`` is safe to call every epoch: the elastic plan stays
    anchored at the original topology (idempotent under a repeated dead
    set), actions fire only when the dead set CHANGES, and a revived
    host (late heartbeat) grows the topology back with a ``rejoin``
    action.
    """

    def __init__(self, table: HeartbeatTable, topo: Topology):
        self.table = table
        self.plan = ElasticPlan(topo)  # anchored; never rebased
        self.initial_topo = topo
        self.topo = topo
        self._dead: tuple[int, ...] = ()

    def tick(self) -> list[RecoveryAction]:
        actions: list[RecoveryAction] = []
        dead = tuple(self.table.dead_hosts())
        if dead != self._dead:
            newly_dead = sorted(set(dead) - set(self._dead))
            revived = sorted(set(self._dead) - set(dead))
            new_topo = self.plan.replan(list(dead))
            if newly_dead:
                actions.append(RecoveryAction(
                    "restart_from_checkpoint",
                    {"dead_hosts": list(dead),
                     "newly_dead": newly_dead,
                     "old_topology": dataclasses.asdict(self.topo),
                     "new_topology": dataclasses.asdict(new_topo)},
                ))
            if revived:
                actions.append(RecoveryAction(
                    "rejoin",
                    {"hosts": revived,
                     "old_topology": dataclasses.asdict(self.topo),
                     "new_topology": dataclasses.asdict(new_topo)},
                ))
            self.topo = new_topo
            self._dead = dead
        for hid in self.table.stragglers():
            actions.append(RecoveryAction(
                "steal_shard", {"from_host": hid}))
        return actions
