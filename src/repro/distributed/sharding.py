"""Logical-axis sharding rules (DP / FSDP / TP / EP / SP on one rule table).

Weights and activations are annotated with *logical* axis names; this module
maps them onto the active mesh.  The mapping enforces divisibility: a logical
axis whose dimension does not divide the mesh axis size falls back to
replication (e.g. starcoder2's 24 heads on a 16-way "model" axis, grok-1's 8
experts), which is exactly the per-arch behaviour documented in DESIGN.md §5.

Rule table (logical -> mesh axes, first fit that divides wins):

    batch      -> ("pod", "data")   activations' batch dim (DP; pod = outer DP)
    embed      -> "data"            weights' d_model dim (FSDP / ZeRO-3)
    vocab      -> "model"           embedding/vocab dim (TP)
    heads      -> "model"           attention heads (TP)
    kv_heads   -> "model"           KV heads (TP; GQA often replicates)
    mlp        -> "model"           FFN hidden (TP)
    experts    -> "model"           MoE experts (EP)
    inner      -> "model"           Mamba d_inner (TP)
    cache_seq  -> "model"           decode KV-cache sequence dim (SP /
                                    flash-decoding-style sharded softmax)
    frames     -> None              encoder frames (replicated)
    layers/state/conv/head_dim/dt_rank -> None

Use :func:`use_mesh` (context manager) to activate a mesh + rules; inside it,
:func:`constrain` applies ``with_sharding_constraint`` and the param/input
builders return concrete ``NamedSharding`` pytrees.  Outside any context all
of this degrades to no-ops so the same model code runs single-device.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any, Iterable, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "cache_batch": ("pod", "data"),  # decode caches keep batch sharded even
                                     # when activations replicate ("batch"
                                     # overridden to ())
    "embed": ("data",),
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "experts": ("model",),
    "inner": ("model",),
    "cache_seq": ("model",),
    "seq": (),
    "frames": (),
    "layers": (),
    "state": (),
    "conv": (),
    "head_dim": (),
    "dt_rank": (),
}


class _Ctx(threading.local):
    mesh: Mesh | None = None
    rules: dict[str, tuple[str, ...]] | None = None


_CTX = _Ctx()


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: dict[str, tuple[str, ...]] | None = None):
    """Activate a mesh (+ optional rule overrides) for logical sharding."""

    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh = mesh
    _CTX.rules = {**DEFAULT_RULES, **(rules or {})}
    try:
        with mesh:
            yield mesh
    finally:
        _CTX.mesh, _CTX.rules = prev


def active_mesh() -> Mesh | None:
    return _CTX.mesh


def _mesh_axis_size(mesh: Mesh, names: Iterable[str]) -> int:
    size = 1
    for n in names:
        size *= mesh.shape[n]
    return size


def spec_for(
    shape: Sequence[int],
    logical: Sequence[str | None],
    mesh: Mesh | None = None,
    rules: dict[str, tuple[str, ...]] | None = None,
) -> P:
    """PartitionSpec for ``shape`` under the rule table, divisibility-safe."""

    mesh = mesh or _CTX.mesh
    rules = rules or _CTX.rules or DEFAULT_RULES
    if mesh is None:
        return P(*([None] * len(shape)))
    assert len(shape) == len(logical), (shape, logical)
    out: list[Any] = []
    used: set[str] = set()  # a mesh axis may appear at most once per spec
    for dim, name in zip(shape, logical):
        if name is None:
            out.append(None)
            continue
        axes = tuple(
            a for a in rules.get(name, ())
            if a in mesh.shape and a not in used
        )
        chosen: tuple[str, ...] | None = None
        for k in range(len(axes), 0, -1):
            if axes[:k] and dim % _mesh_axis_size(mesh, axes[:k]) == 0:
                chosen = axes[:k]
                break
        if chosen:
            used.update(chosen)
            out.append(chosen if len(chosen) > 1 else chosen[0])
        else:
            out.append(None)
    return P(*out)


def constrain(x: jax.Array, *logical: str | None) -> jax.Array:
    """with_sharding_constraint by logical names (no-op without a mesh)."""

    mesh = _CTX.mesh
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec_for(x.shape, logical, mesh))
    )


def named_sharding(shape: Sequence[int], logical: Sequence[str | None],
                   mesh: Mesh | None = None) -> NamedSharding:
    mesh = mesh or _CTX.mesh
    assert mesh is not None, "named_sharding requires an active or given mesh"
    return NamedSharding(mesh, spec_for(shape, logical, mesh))


def tree_shardings(abstract_tree, logical_tree, mesh: Mesh | None = None):
    """Nested-dict tree of ShapeDtypeStructs + matching tree of logical-axis
    tuples -> tree of NamedShardings.  (Param trees here are nested dicts
    with tuple leaves, so explicit recursion avoids pytree ambiguity.)"""

    mesh = mesh or _CTX.mesh

    def rec(a, l):
        if isinstance(a, dict):
            return {k: rec(a[k], l[k]) for k in a}
        return named_sharding(a.shape, l, mesh)

    return rec(abstract_tree, logical_tree)
