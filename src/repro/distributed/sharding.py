"""Sharding: logical-axis rules for tensors + trace partitioning for traces.

Two layers live here:

1. **Tensor sharding** — logical-axis rules mapping weights/activations onto
   the active mesh (DP / FSDP / TP / EP / SP on one rule table), below.
2. **Trace sharding** — request-level partitioning policies that split one
   server-side arrival trace across N I/O nodes for the fleet simulator
   (:mod:`repro.core.fleet`).  These mirror how a parallel file system
   actually distributes clients over I/O servers:

   * ``round-robin-app``   — whole applications pinned to nodes round-robin
     (OrangeFS-style server assignment per client group; keeps each app's
     access pattern intact on its node).
   * ``hash-file``         — files hashed to nodes (object/handle hashing;
     different files never share a node's queue).
   * ``range-offset``      — the global byte range striped into N equal
     extents (Lustre-style range partitioning; one file's traffic spreads
     over all nodes).

   Policy functions are pure array transforms ``(offsets, file_ids,
   app_ids, num_nodes) -> node assignment`` so they stay import-light (no
   dependency on :mod:`repro.core`).

Weights and activations are annotated with *logical* axis names; this module
maps them onto the active mesh.  The mapping enforces divisibility: a logical
axis whose dimension does not divide the mesh axis size falls back to
replication (e.g. starcoder2's 24 heads on a 16-way "model" axis, grok-1's 8
experts), which is exactly the per-arch behaviour documented in DESIGN.md §5.

Rule table (logical -> mesh axes, first fit that divides wins):

    batch      -> ("pod", "data")   activations' batch dim (DP; pod = outer DP)
    embed      -> "data"            weights' d_model dim (FSDP / ZeRO-3)
    vocab      -> "model"           embedding/vocab dim (TP)
    heads      -> "model"           attention heads (TP)
    kv_heads   -> "model"           KV heads (TP; GQA often replicates)
    mlp        -> "model"           FFN hidden (TP)
    experts    -> "model"           MoE experts (EP)
    inner      -> "model"           Mamba d_inner (TP)
    cache_seq  -> "model"           decode KV-cache sequence dim (SP /
                                    flash-decoding-style sharded softmax)
    frames     -> None              encoder frames (replicated)
    layers/state/conv/head_dim/dt_rank -> None

Use :func:`use_mesh` (context manager) to activate a mesh + rules; inside it,
:func:`constrain` applies ``with_sharding_constraint`` and the param/input
builders return concrete ``NamedSharding`` pytrees.  Outside any context all
of this degrades to no-ops so the same model code runs single-device.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any, Iterable, Sequence

try:  # the trace-sharding policies below are numpy-only; keep the module
    # (and therefore repro.core.fleet / repro.core) importable without jax
    import jax
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
except Exception:  # pragma: no cover - jax is installed in this repo
    jax = None
    Mesh = NamedSharding = P = None  # tensor-sharding API unusable

DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "cache_batch": ("pod", "data"),  # decode caches keep batch sharded even
                                     # when activations replicate ("batch"
                                     # overridden to ())
    "embed": ("data",),
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "experts": ("model",),
    "inner": ("model",),
    "cache_seq": ("model",),
    "seq": (),
    "frames": (),
    "layers": (),
    "state": (),
    "conv": (),
    "head_dim": (),
    "dt_rank": (),
}


class _Ctx(threading.local):
    mesh: Mesh | None = None
    rules: dict[str, tuple[str, ...]] | None = None


_CTX = _Ctx()


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: dict[str, tuple[str, ...]] | None = None):
    """Activate a mesh (+ optional rule overrides) for logical sharding."""

    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh = mesh
    _CTX.rules = {**DEFAULT_RULES, **(rules or {})}
    try:
        with mesh:
            yield mesh
    finally:
        _CTX.mesh, _CTX.rules = prev


def active_mesh() -> Mesh | None:
    return _CTX.mesh


def _mesh_axis_size(mesh: Mesh, names: Iterable[str]) -> int:
    size = 1
    for n in names:
        size *= mesh.shape[n]
    return size


def spec_for(
    shape: Sequence[int],
    logical: Sequence[str | None],
    mesh: Mesh | None = None,
    rules: dict[str, tuple[str, ...]] | None = None,
) -> P:
    """PartitionSpec for ``shape`` under the rule table, divisibility-safe."""

    mesh = mesh or _CTX.mesh
    rules = rules or _CTX.rules or DEFAULT_RULES
    if mesh is None:
        return P(*([None] * len(shape)))
    if len(shape) != len(logical):
        raise ValueError(
            f"shape rank {len(shape)} != logical rank {len(logical)}: "
            f"{shape} vs {logical}"
        )
    out: list[Any] = []
    used: set[str] = set()  # a mesh axis may appear at most once per spec
    for dim, name in zip(shape, logical):
        if name is None:
            out.append(None)
            continue
        axes = tuple(
            a for a in rules.get(name, ())
            if a in mesh.shape and a not in used
        )
        chosen: tuple[str, ...] | None = None
        for k in range(len(axes), 0, -1):
            if axes[:k] and dim % _mesh_axis_size(mesh, axes[:k]) == 0:
                chosen = axes[:k]
                break
        if chosen:
            used.update(chosen)
            out.append(chosen if len(chosen) > 1 else chosen[0])
        else:
            out.append(None)
    return P(*out)


def constrain(x: jax.Array, *logical: str | None) -> jax.Array:
    """with_sharding_constraint by logical names (no-op without a mesh)."""

    mesh = _CTX.mesh
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec_for(x.shape, logical, mesh))
    )


def named_sharding(shape: Sequence[int], logical: Sequence[str | None],
                   mesh: Mesh | None = None) -> NamedSharding:
    mesh = mesh or _CTX.mesh
    if mesh is None:
        raise RuntimeError("named_sharding requires an active or given mesh")
    return NamedSharding(mesh, spec_for(shape, logical, mesh))


def tree_shardings(abstract_tree, logical_tree, mesh: Mesh | None = None):
    """Nested-dict tree of ShapeDtypeStructs + matching tree of logical-axis
    tuples -> tree of NamedShardings.  (Param trees here are nested dicts
    with tuple leaves, so explicit recursion avoids pytree ambiguity.)"""

    mesh = mesh or _CTX.mesh

    def rec(a, l):
        if isinstance(a, dict):
            return {k: rec(a[k], l[k]) for k in a}
        return named_sharding(a.shape, l, mesh)

    return rec(abstract_tree, logical_tree)


# ---------------------------------------------------------------------------
# trace sharding: request -> I/O node assignment (fleet simulator)
# ---------------------------------------------------------------------------

import numpy as np  # noqa: E402  (trace policies are NumPy-only)


def shard_round_robin_app(offsets, file_ids, app_ids, num_nodes: int) -> np.ndarray:
    """Pin whole applications to nodes round-robin (by first appearance).

    Every request of one app lands on one node, so the app's access
    pattern — and therefore its random percentage — survives sharding
    unchanged.  Apps are ranked by first appearance in the arrival order,
    making the assignment deterministic for a given trace.
    """

    app_ids = np.asarray(app_ids, dtype=np.int64)
    _, first_pos, inverse = np.unique(app_ids, return_index=True,
                                      return_inverse=True)
    # rank apps by arrival (np.unique sorts by id; re-rank by first_pos)
    rank_of_sorted = np.argsort(np.argsort(first_pos, kind="stable"),
                                kind="stable")
    return (rank_of_sorted[inverse] % num_nodes).astype(np.int64)


def _splitmix64(x: np.ndarray) -> np.ndarray:
    """Deterministic 64-bit mix (SplitMix64 finalizer), vectorized."""

    z = x.astype(np.uint64) + np.uint64(0x9E3779B97F4A7C15)
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


def shard_hash_file(offsets, file_ids, app_ids, num_nodes: int) -> np.ndarray:
    """Hash each file handle to a node (object-store / handle hashing)."""

    file_ids = np.asarray(file_ids, dtype=np.int64)
    return (_splitmix64(file_ids) % np.uint64(num_nodes)).astype(np.int64)


def shard_range_offset(offsets, file_ids, app_ids, num_nodes: int) -> np.ndarray:
    """Stripe the global logical byte range into ``num_nodes`` equal extents.

    Request at offset ``o`` goes to ``(o - lo) // extent`` where the
    ``[lo, hi]`` span is taken over the whole trace — Lustre-style range
    partitioning.  Spreads one hot file across every node at the cost of
    splitting sequential runs at extent boundaries.
    """

    offsets = np.asarray(offsets, dtype=np.int64)
    if offsets.size == 0:
        return np.zeros(0, dtype=np.int64)
    lo = int(offsets.min())
    hi = int(offsets.max())
    extent = max((hi - lo) // num_nodes + 1, 1)
    return np.minimum((offsets - lo) // extent, num_nodes - 1).astype(np.int64)


TRACE_POLICIES = {
    "round-robin-app": shard_round_robin_app,
    "hash-file": shard_hash_file,
    "range-offset": shard_range_offset,
}


def reshard_to_survivors(policy: str, offsets, file_ids, app_ids,
                         assignment, survivors) -> np.ndarray:
    """Reassign requests stranded on dead nodes onto the survivors.

    Requests whose current ``assignment`` already names a surviving node
    stay put (that node holds their buffered state and detector
    history); every other request is re-policied over the survivor set:
    the named policy runs with ``num_nodes = len(survivors)`` and its
    output indexes the sorted survivor list.  Pure and deterministic —
    repeated failover of the same dead set yields the same assignment.
    """

    assignment = np.asarray(assignment, dtype=np.int64)
    surv = np.asarray(sorted(set(int(s) for s in survivors)), dtype=np.int64)
    if surv.size == 0:
        raise ValueError("no surviving nodes to reshard onto")
    out = assignment.copy()
    dead_mask = ~np.isin(assignment, surv)
    if not dead_mask.any():
        return out
    idx = np.nonzero(dead_mask)[0]
    sub = assign_nodes(
        policy,
        np.asarray(offsets)[idx],
        np.asarray(file_ids)[idx],
        np.asarray(app_ids)[idx],
        int(surv.size),
    )
    out[idx] = surv[sub]
    return out


def assign_nodes(policy: str, offsets, file_ids, app_ids,
                 num_nodes: int) -> np.ndarray:
    """Per-request node assignment under a named trace-sharding policy."""

    if num_nodes < 1:
        raise ValueError(f"num_nodes must be >= 1, got {num_nodes}")
    try:
        fn = TRACE_POLICIES[policy]
    except KeyError:
        raise ValueError(
            f"unknown trace sharding policy {policy!r}; "
            f"choose from {sorted(TRACE_POLICIES)}"
        ) from None
    out = fn(offsets, file_ids, app_ids, num_nodes)
    if out.shape[0] != np.asarray(offsets).shape[0]:
        raise ValueError(
            f"policy {policy!r} returned {out.shape[0]} assignments for "
            f"{np.asarray(offsets).shape[0]} requests"
        )
    return out
