"""Data pipeline substrate."""

from repro.data.pipeline import DataConfig, ShardedLoader, SyntheticTokenSource

__all__ = ["DataConfig", "ShardedLoader", "SyntheticTokenSource"]
