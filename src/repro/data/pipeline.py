"""Deterministic synthetic token pipeline with sharded host loading.

Real deployments stream tokenized shards from the slow tier (optionally
through the burst buffer — see ``spill_through_buffer``); here the token
source is a seeded generator so training runs are reproducible and
self-contained.  The loader yields per-host batches: host h of H gets rows
[h*B/H, (h+1)*B/H) of the global batch, matching the "batch" logical axis.

Straggler mitigation hook: ``reissue(shard)`` returns the same rows for a
backup host (work stealing) — deterministic by construction.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1


class SyntheticTokenSource:
    """Zipfian token stream (LM-ish marginals), deterministic per (seed,
    step, row)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = 1.0 / ranks**1.1
        self.probs = probs / probs.sum()

    def batch(self, step: int, rows: range) -> dict[str, np.ndarray]:
        cfg = self.cfg
        out_tokens = np.empty((len(rows), cfg.seq_len + 1), np.int32)
        for i, row in enumerate(rows):
            rng = np.random.default_rng(
                np.random.SeedSequence([cfg.seed, step, row]))
            out_tokens[i] = rng.choice(
                cfg.vocab_size, size=cfg.seq_len + 1, p=self.probs)
        return {
            "tokens": out_tokens[:, :-1],
            "labels": out_tokens[:, 1:].astype(np.int32),
        }


class ShardedLoader:
    """Per-host loader over the global batch."""

    def __init__(self, cfg: DataConfig, host_id: int):
        if cfg.global_batch % cfg.n_hosts != 0:
            raise ValueError(
                f"global_batch {cfg.global_batch} not divisible by "
                f"n_hosts {cfg.n_hosts}"
            )
        self.cfg = cfg
        self.host_id = host_id
        self.source = SyntheticTokenSource(cfg)
        per = cfg.global_batch // cfg.n_hosts
        self.rows = range(host_id * per, (host_id + 1) * per)

    def get(self, step: int) -> dict[str, np.ndarray]:
        return self.source.batch(step, self.rows)

    def reissue(self, step: int, straggler_host: int) -> dict[str, np.ndarray]:
        """Work stealing: produce the straggler's shard deterministically."""

        per = self.cfg.global_batch // self.cfg.n_hosts
        rows = range(straggler_host * per, (straggler_host + 1) * per)
        return self.source.batch(step, rows)

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.get(step)
            step += 1
