"""Pallas TPU kernel: batched random-factor scoring of request streams.

The paper's hot loop (sort 128 offsets, count non-contiguous neighbours) as
a TPU data-plane op.  GPU ports of sorting lean on warp shuffles; the TPU
adaptation (DESIGN.md §2) maps the fixed-size sort onto a **bitonic
sorting network over the 128-lane minor axis** — no data-dependent control
flow, every compare-exchange is a full-width vector op, and the partner
exchange for stride j is a reshape to (..., groups, 2, j) + flip of the
pair axis, which Mosaic lowers to lane shuffles.  Sizes ride along as a
payload through the same network.

Tiling: one VMEM block = (BLOCK_STREAMS, N) int32 for offsets + sizes plus
a (BLOCK_STREAMS,) output tile; with BLOCK_STREAMS=256 and N=128 that is
2 x 128 KiB in + 1 KiB out per grid step — far under the ~16 MiB VMEM
budget, sized to keep the (8, 128) VPU tiles saturated.

N must be a power of two (the stream length is the CFQ window, 128 by
default; the host groups partial tails before calling in).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_STREAMS = 256


def _compare_exchange(keys, payload, j: int, up_mask):
    """One bitonic stage: partner = lane XOR j via reshape+flip."""

    bs, n = keys.shape
    g = n // (2 * j)

    def partner(x):
        return jnp.flip(x.reshape(bs, g, 2, j), axis=2).reshape(bs, n)

    pk = partner(keys)
    pp = partner(payload)
    lane = jax.lax.broadcasted_iota(jnp.int32, (bs, n), 1)
    first = (lane & j) == 0  # lower element of each pair
    take_max = up_mask != first  # see bitonic min/max selection rule
    a_is_small = keys <= pk
    small_k = jnp.where(a_is_small, keys, pk)
    big_k = jnp.where(a_is_small, pk, keys)
    small_p = jnp.where(a_is_small, payload, pp)
    big_p = jnp.where(a_is_small, pp, payload)
    new_k = jnp.where(take_max, big_k, small_k)
    new_p = jnp.where(take_max, big_p, small_p)
    return new_k, new_p


def _bitonic_sort_with_payload(keys, payload):
    """Ascending bitonic sort along the minor axis (power-of-two length)."""

    bs, n = keys.shape
    lane = jax.lax.broadcasted_iota(jnp.int32, (bs, n), 1)
    k = 2
    while k <= n:
        up = (lane & k) == 0
        j = k // 2
        while j >= 1:
            keys, payload = _compare_exchange(keys, payload, j, up)
            j //= 2
        k *= 2
    return keys, payload


def _stream_rf_kernel(off_ref, size_ref, out_ref):
    offs = off_ref[...]
    szs = size_ref[...]
    so, ss = _bitonic_sort_with_payload(offs, szs)
    gaps = so[:, 1:] - so[:, :-1]
    rf = (gaps != ss[:, :-1]).astype(jnp.int32)
    out_ref[...] = jnp.sum(rf, axis=1)


def _stream_stats_kernel(off_ref, size_ref, rf_ref, dist_ref):
    """Fused variant: Eq. 1 seek count + Eq. 6 seek-distance aggregate.

    One bitonic sort feeds both reductions; the distance rides float32
    lanes because 127 residuals of up to 2 GiB overflow int32.
    """

    offs = off_ref[...]
    szs = size_ref[...]
    so, ss = _bitonic_sort_with_payload(offs, szs)
    resid = so[:, 1:] - so[:, :-1] - ss[:, :-1]
    rf_ref[...] = jnp.sum((resid != 0).astype(jnp.int32), axis=1)
    dist_ref[...] = jnp.sum(jnp.abs(resid).astype(jnp.float32), axis=1)


@functools.partial(jax.jit, static_argnames=("block_streams", "interpret"))
def stream_rf(offsets: jax.Array, sizes: jax.Array,
              block_streams: int = BLOCK_STREAMS,
              interpret: bool = False) -> jax.Array:
    """Batched RF sums: (M, N) int32 offsets/sizes -> (M,) int32.

    M is padded up to a multiple of ``block_streams``; N must be a power of
    two (assignment default 128 = the CFQ queue window).
    """

    m, n = offsets.shape
    if n & (n - 1) != 0:
        raise ValueError(f"stream length {n} must be a power of two")
    offsets = jnp.asarray(offsets, jnp.int32)
    sizes = jnp.broadcast_to(jnp.asarray(sizes, jnp.int32), offsets.shape)

    bs = min(block_streams, m) if m else block_streams
    pad = (-m) % bs
    if pad:
        # padded rows are contiguous streams -> rf 0; sliced off below
        offsets = jnp.pad(offsets, ((0, pad), (0, 0)))
        sizes = jnp.pad(sizes, ((0, pad), (0, 0)))
    mp = offsets.shape[0]

    out = pl.pallas_call(
        _stream_rf_kernel,
        grid=(mp // bs,),
        in_specs=[
            pl.BlockSpec((bs, n), lambda i: (i, 0)),
            pl.BlockSpec((bs, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bs,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((mp,), jnp.int32),
        interpret=interpret,
    )(offsets, sizes)
    return out[:m]


@functools.partial(jax.jit, static_argnames=("block_streams", "interpret"))
def stream_stats(offsets: jax.Array, sizes: jax.Array,
                 block_streams: int = BLOCK_STREAMS,
                 interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """Fused RF + seek-distance: (M, N) int32 -> ((M,) int32, (M,) float32).

    Same tiling and padding contract as :func:`stream_rf`, with a second
    per-stream output tile (the float32 seek-distance sum) written from the
    same sorted block — the flush-cost model (Eq. 6) needs both and the
    sort dominates, so fusing halves the kernel work vs two dispatches.
    """

    m, n = offsets.shape
    if n & (n - 1) != 0:
        raise ValueError(f"stream length {n} must be a power of two")
    offsets = jnp.asarray(offsets, jnp.int32)
    sizes = jnp.broadcast_to(jnp.asarray(sizes, jnp.int32), offsets.shape)

    bs = min(block_streams, m) if m else block_streams
    pad = (-m) % bs
    if pad:
        # padded rows are contiguous streams -> rf 0, dist 0; sliced below
        offsets = jnp.pad(offsets, ((0, pad), (0, 0)))
        sizes = jnp.pad(sizes, ((0, pad), (0, 0)))
    mp = offsets.shape[0]

    rf, dist = pl.pallas_call(
        _stream_stats_kernel,
        grid=(mp // bs,),
        in_specs=[
            pl.BlockSpec((bs, n), lambda i: (i, 0)),
            pl.BlockSpec((bs, n), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bs,), lambda i: (i,)),
            pl.BlockSpec((bs,), lambda i: (i,)),
        ],
        out_shape=(
            jax.ShapeDtypeStruct((mp,), jnp.int32),
            jax.ShapeDtypeStruct((mp,), jnp.float32),
        ),
        interpret=interpret,
    )(offsets, sizes)
    return rf[:m], dist[:m]
