"""jit'd public wrapper for the stream_rf kernel.

``stream_rf_op`` auto-selects interpret mode off-TPU so the same call works
in this CPU container (correctness) and on real TPUs (performance).  The
random *percentage* variant matches ``repro.core.random_factor``'s
S/(N-1) definition.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.stream_rf.kernel import stream_rf


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def stream_rf_op(offsets, sizes, block_streams: int = 256,
                 interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = not _on_tpu()
    return stream_rf(jnp.asarray(offsets), jnp.asarray(sizes),
                     block_streams=block_streams, interpret=interpret)


def random_percentage_op(offsets, sizes, **kw) -> jax.Array:
    offsets = jnp.asarray(offsets)
    n = offsets.shape[-1]
    s = stream_rf_op(offsets, sizes, **kw)
    return s.astype(jnp.float32) / max(n - 1, 1)


def stream_stats_op(offsets, sizes, **kw) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Kernel-backed per-stream stats: ``(M, N) -> (rf, pct, dist)``.

    The Eq. 1 seek count comes from the bitonic-sort Pallas kernel; the
    seek-distance aggregate (which the kernel does not emit) is one extra
    sorted-residual reduction in plain jnp, accumulated in float32 so it
    cannot wrap int32 (see ``stream_stats_batch``'s dtype notes).  Matches
    ``repro.core.random_factor.stream_stats_batch`` elementwise.
    """

    offsets = jnp.asarray(offsets, jnp.int32)
    szs = jnp.broadcast_to(jnp.asarray(sizes, jnp.int32), offsets.shape)
    n = offsets.shape[-1]
    rf = stream_rf_op(offsets, szs, **kw)
    pct = rf.astype(jnp.float32) / max(n - 1, 1)
    order = jnp.argsort(offsets, axis=-1, stable=True)
    so = jnp.take_along_axis(offsets, order, axis=-1)
    ss = jnp.take_along_axis(szs, order, axis=-1)
    resid = so[..., 1:] - so[..., :-1] - ss[..., :-1]
    dist = jnp.sum(jnp.abs(resid).astype(jnp.float32), axis=-1)
    return rf, pct, dist
