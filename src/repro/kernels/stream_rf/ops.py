"""jit'd public wrapper for the stream_rf kernel.

``stream_rf_op`` auto-selects interpret mode off-TPU so the same call works
in this CPU container (correctness) and on real TPUs (performance).  The
random *percentage* variant matches ``repro.core.random_factor``'s
S/(N-1) definition.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.stream_rf.kernel import stream_rf


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def stream_rf_op(offsets, sizes, block_streams: int = 256,
                 interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = not _on_tpu()
    return stream_rf(jnp.asarray(offsets), jnp.asarray(sizes),
                     block_streams=block_streams, interpret=interpret)


def random_percentage_op(offsets, sizes, **kw) -> jax.Array:
    offsets = jnp.asarray(offsets)
    n = offsets.shape[-1]
    s = stream_rf_op(offsets, sizes, **kw)
    return s.astype(jnp.float32) / max(n - 1, 1)
