"""jit'd public wrapper for the stream_rf kernel.

``stream_rf_op`` auto-selects interpret mode off-TPU so the same call works
in this CPU container (correctness) and on real TPUs (performance).  The
random *percentage* variant matches ``repro.core.random_factor``'s
S/(N-1) definition.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.stream_rf.kernel import stream_rf, stream_stats


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def stream_rf_op(offsets, sizes, block_streams: int = 256,
                 interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = not _on_tpu()
    return stream_rf(jnp.asarray(offsets), jnp.asarray(sizes),
                     block_streams=block_streams, interpret=interpret)


def random_percentage_op(offsets, sizes, **kw) -> jax.Array:
    offsets = jnp.asarray(offsets)
    n = offsets.shape[-1]
    s = stream_rf_op(offsets, sizes, **kw)
    return s.astype(jnp.float32) / max(n - 1, 1)


def stream_stats_op(offsets, sizes, block_streams: int = 256,
                    interpret: bool | None = None,
                    ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Kernel-backed per-stream stats: ``(M, N) -> (rf, pct, dist)``.

    Both the Eq. 1 seek count and the Eq. 6 seek-distance aggregate come
    out of ONE fused bitonic-sort dispatch (``kernel.stream_stats``) — the
    sort dominates and is shared, so there is no second jnp argsort pass.
    The distance is float32-accumulated so it cannot wrap int32 (see
    ``stream_stats_batch``'s dtype notes).  Matches
    ``repro.core.random_factor.stream_stats_batch`` elementwise.
    """

    if interpret is None:
        interpret = not _on_tpu()
    offsets = jnp.asarray(offsets, jnp.int32)
    szs = jnp.broadcast_to(jnp.asarray(sizes, jnp.int32), offsets.shape)
    n = offsets.shape[-1]
    rf, dist = stream_stats(offsets, szs, block_streams=block_streams,
                            interpret=interpret)
    pct = rf.astype(jnp.float32) / max(n - 1, 1)
    return rf, pct, dist
