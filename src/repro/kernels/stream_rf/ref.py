"""Pure-jnp oracle for the stream_rf kernel.

Semantics = paper Eq. 1 over a batch of request streams: sort each stream's
(offset, size) records by offset, count sorted-adjacent pairs whose gap is
not exactly the lower record's size (each such pair costs one disk seek).

This matches ``repro.core.random_factor.random_factor_batch`` (cross-checked
in tests) and is the correctness reference for every kernel shape/dtype in
the sweep.
"""

from __future__ import annotations

import jax.numpy as jnp


def stream_rf_ref(offsets: jnp.ndarray, sizes: jnp.ndarray) -> jnp.ndarray:
    """offsets, sizes: (M, N) int32 -> rf sums (M,) int32."""

    offsets = jnp.asarray(offsets, jnp.int32)
    sizes = jnp.broadcast_to(jnp.asarray(sizes, jnp.int32), offsets.shape)
    order = jnp.argsort(offsets, axis=-1, stable=True)
    so = jnp.take_along_axis(offsets, order, axis=-1)
    ss = jnp.take_along_axis(sizes, order, axis=-1)
    gaps = so[..., 1:] - so[..., :-1]
    return jnp.sum((gaps != ss[..., :-1]).astype(jnp.int32), axis=-1)


def threshold_quantile_ref(percentages: jnp.ndarray, avgper: jnp.ndarray) -> jnp.ndarray:
    """Adaptive-threshold quantile pick (paper Eq. 2) over a sorted window:
    sort the window, index floor((1-avgper)*N), clamp.  (M, W) -> (M,)."""

    w = percentages.shape[-1]
    srt = jnp.sort(percentages, axis=-1)
    idx = jnp.clip(((1.0 - avgper) * w).astype(jnp.int32), 0, w - 1)
    return jnp.take_along_axis(srt, idx[..., None], axis=-1)[..., 0]
