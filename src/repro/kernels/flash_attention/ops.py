"""jit'd public wrapper for the flash_attention kernel.

Accepts the model layout (B, S, H, hd) / (B, S, KV, hd) (what
``repro.models.layers`` produces) and handles the transpose to the kernel's
(B, H, S, hd).  Auto-selects interpret mode off-TPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention_op(q, k, v, *, causal: bool = True,
                       scale: float | None = None,
                       block_q: int = 256, block_k: int = 256,
                       interpret: bool | None = None) -> jax.Array:
    """(B, H, Sq, hd) x (B, KV, Sk, hd)^2 -> (B, H, Sq, hd)."""

    if interpret is None:
        interpret = not _on_tpu()
    return flash_attention(q, k, v, causal=causal, scale=scale,
                           block_q=block_q, block_k=block_k,
                           interpret=interpret)


def flash_attention_bshd(q, k, v, *, causal: bool = True,
                         scale: float | None = None,
                         interpret: bool | None = None) -> jax.Array:
    """Model layout: q (B, S, H, hd), k/v (B, S, KV, hd) -> (B, S, H, hd)."""

    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    # block sizes must divide the sequence; shrink for short sequences
    s = qt.shape[2]
    blk = 256
    while s % blk:
        blk //= 2
    out = flash_attention_op(qt, kt, vt, causal=causal, scale=scale,
                             block_q=blk, block_k=blk, interpret=interpret)
    return jnp.swapaxes(out, 1, 2)
