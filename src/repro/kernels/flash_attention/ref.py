"""Pure-jnp oracle for the flash_attention kernel.

Exact (non-streamed) attention with f32 softmax, GQA via KV-head grouping,
optional causal mask.  Layout matches the kernel: q (B, H, Sq, hd),
k/v (B, KV, Sk, hd) -> out (B, H, Sq, hd).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        scale: float | None = None) -> jnp.ndarray:
    b, h, sq, hd = q.shape
    kv = k.shape[1]
    if h % kv != 0:
        raise ValueError(f"heads {h} not divisible by kv heads {kv}")
    n_rep = h // kv
    if scale is None:
        scale = 1.0 / np.sqrt(hd)
    qg = q.reshape(b, kv, n_rep, sq, hd).astype(jnp.float32)
    s = jnp.einsum("bgrqd,bgkd->bgrqk", qg * scale, k.astype(jnp.float32))
    if causal:
        sk = k.shape[2]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bgrqk,bgkd->bgrqd", p, v.astype(jnp.float32))
    return o.reshape(b, h, sq, hd).astype(q.dtype)
