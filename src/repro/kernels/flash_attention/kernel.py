"""Pallas TPU kernel: FlashAttention-2 forward with GQA and causal masking.

Tiling (the TPU adaptation of the CUDA original): grid is
(B, H, Sq/bq, Sk/bk) with the KV dimension INNERMOST, so each (b, h, iq)
output tile is revisited across ik steps while the online-softmax running
statistics (m, l) and the f32 accumulator live in VMEM scratch.  Block
shapes default to (bq, hd) = (256, head_dim) and (bk, hd) = (256, head_dim):
with hd=128 that is 256x128 f32 accumulator + two 256x128 operand tiles ≈
0.4 MiB — VMEM-safe while keeping the 128x128 MXU fully tiled (both matmul
dims are multiples of 128 for every assigned arch except whisper's hd=64,
which still maps onto the MXU half-tiles).

Causal handling: kv blocks entirely above the diagonal are skipped via
``pl.when`` (no wasted MXU work — this is the FA-2 trick that halves causal
FLOPs); the diagonal block applies an elementwise mask.

GQA: the k/v BlockSpec index maps head h -> h // n_rep, so grouped queries
stream the same KV tiles without materializing repeated heads.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 256
DEFAULT_BLOCK_K = 256
NEG_INF = float(np.finfo(np.float32).min)


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale: float, causal: bool, block_q: int, block_k: int,
               num_k_blocks: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * block_q
    k_start = ik * block_k

    def compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)  # (bq, bk)
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = k_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])  # (bq, bk)
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        v = v_ref[0, 0].astype(jnp.float32)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[...] = m_new
        l_ref[...] = l_new

    if causal:
        # skip kv blocks strictly above the diagonal (no query attends them)
        needed = k_start <= q_start + block_q - 1
        pl.when(needed)(compute)
    else:
        compute()

    @pl.when(ik == num_k_blocks - 1)
    def finalize():
        l = l_ref[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe_l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: float | None = None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False) -> jax.Array:
    """q: (B, H, Sq, hd); k/v: (B, KV, Sk, hd) -> (B, H, Sq, hd)."""

    b, h, sq, hd = q.shape
    _, kvh, sk, _ = k.shape
    if h % kvh != 0:
        raise ValueError(f"heads {h} not divisible by kv heads {kvh}")
    n_rep = h // kvh
    if scale is None:
        scale = 1.0 / float(np.sqrt(hd))
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    if sq % bq != 0 or sk % bk != 0:
        raise ValueError(
            f"seq lens ({sq}, {sk}) not divisible by blocks ({bq}, {bk})"
        )
    nq, nk = sq // bq, sk // bk

    from jax.experimental.pallas import tpu as pltpu  # noqa: PLC0415

    try:
        scratch = [
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ]
    except Exception:  # pragma: no cover - older pallas
        scratch = [
            pl.VMEM((bq,), jnp.float32),
            pl.VMEM((bq,), jnp.float32),
            pl.VMEM((bq, hd), jnp.float32),
        ]

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, block_q=bq, block_k=bk,
        num_k_blocks=nk)
    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd),
                         lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda ib, ih, iq, ik, n_rep=n_rep: (ib, ih // n_rep, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda ib, ih, iq, ik, n_rep=n_rep: (ib, ih // n_rep, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, hd), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(q, k, v)
