"""Pallas TPU kernel: Mamba-1 selective scan with VMEM-resident state.

The §Perf analysis of falcon-mamba train_4k (EXPERIMENTS.md) showed the XLA
path's memory term is dominated by the (B, chunk, d_inner, state) expansion
that ``associative_scan`` sweeps through HBM ~log2(chunk) times.  This
kernel is the TPU-native fix: the recurrent state lives in a VMEM scratch
tile that PERSISTS across sequence-chunk grid steps, the (d_inner, state)
expansion happens in registers inside a ``fori_loop`` over time, and HBM
sees only the inputs (delta, B, C, x) once and the outputs (y, h_last)
once — the h trajectory never leaves the chip.

Tiling: grid = (batch, d_inner / BLOCK_D, seq / CHUNK) with the sequence
dimension INNERMOST, so the (BLOCK_D, N) state scratch carries across
chunks of the same (b, d-block) row and re-initializes at chunk 0.  With
BLOCK_D=512, N=16, CHUNK=128: state tile 32 KiB; per-step working set
(delta/x/y chunk tiles + B/C) ≈ 0.5 MiB — far under VMEM, and the
sequential time loop is VPU elementwise work at full (8,128) lane width.

HBM traffic: S·DI·(delta 4B + x 2B + y 2B) + S·N·8B per batch row versus
the XLA path's ~log2(chunk)·S·DI·N·8B — a ~16x reduction for falcon-mamba
(N=16); this is the quantitative basis for the "beyond-XLA" row in
EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_D = 512
CHUNK = 128


def _ssm_kernel(delta_ref, b_ref, c_ref, x_ref, a_ref, y_ref, hlast_ref,
                h_scratch, *, chunk: int, num_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def init():
        h_scratch[...] = jnp.zeros_like(h_scratch)

    a_log = a_ref[...]  # (bd, N) — A itself (negative values)

    def step(t, h):
        d = delta_ref[0, t, :]  # (bd,)
        a = jnp.exp(d[:, None] * a_log)  # (bd, N)
        bx = d[:, None] * b_ref[0, t, :][None, :] * (
            x_ref[0, t, :].astype(jnp.float32)[:, None])
        h = a * h + bx
        y = jnp.sum(h * c_ref[0, t, :][None, :], axis=1)  # (bd,)
        y_ref[0, t, :] = y.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scratch[...])
    h_scratch[...] = h

    @pl.when(ic == num_chunks - 1)
    def finalize():
        hlast_ref[0, :, :] = h


@functools.partial(
    jax.jit, static_argnames=("block_d", "chunk", "interpret"))
def ssm_scan(delta: jax.Array, B_ssm: jax.Array, C_ssm: jax.Array,
             x: jax.Array, A: jax.Array, *, block_d: int = BLOCK_D,
             chunk: int = CHUNK, interpret: bool = False):
    """delta (B,S,DI) f32; B/C (B,S,N) f32; x (B,S,DI); A (DI,N) f32
    -> (y (B,S,DI) x.dtype, h_last (B,DI,N) f32).

    S must divide by ``chunk`` and DI by ``block_d`` (shrunk automatically
    when the dims are smaller).
    """

    b, s, di = delta.shape
    n = B_ssm.shape[-1]
    bd = min(block_d, di)
    ck = min(chunk, s)
    if di % bd != 0 or s % ck != 0:
        raise ValueError(
            f"dims ({di}, {s}) not divisible by blocks ({bd}, {ck})"
        )
    nd, nc = di // bd, s // ck

    kernel = functools.partial(_ssm_kernel, chunk=ck, num_chunks=nc)
    y, h_last = pl.pallas_call(
        kernel,
        grid=(b, nd, nc),
        in_specs=[
            pl.BlockSpec((1, ck, bd), lambda ib, idd, ic: (ib, ic, idd)),
            pl.BlockSpec((1, ck, n), lambda ib, idd, ic: (ib, ic, 0)),
            pl.BlockSpec((1, ck, n), lambda ib, idd, ic: (ib, ic, 0)),
            pl.BlockSpec((1, ck, bd), lambda ib, idd, ic: (ib, ic, idd)),
            pl.BlockSpec((bd, n), lambda ib, idd, ic: (idd, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, ck, bd), lambda ib, idd, ic: (ib, ic, idd)),
            pl.BlockSpec((1, bd, n), lambda ib, idd, ic: (ib, idd, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, di), x.dtype),
            jax.ShapeDtypeStruct((b, di, n), jnp.float32),
        ],
        scratch_shapes=[_vmem((bd, n), jnp.float32)],
        interpret=interpret,
    )(delta.astype(jnp.float32), B_ssm.astype(jnp.float32),
      C_ssm.astype(jnp.float32), x, A.astype(jnp.float32))
    return y, h_last


def _vmem(shape, dtype):
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.VMEM(shape, dtype)
    except Exception:  # pragma: no cover - older pallas
        return pl.VMEM(shape, dtype)
