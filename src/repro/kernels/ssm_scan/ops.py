"""jit'd public wrapper for the ssm_scan kernel (auto-interpret off-TPU)."""

from __future__ import annotations

import jax

from repro.kernels.ssm_scan.kernel import ssm_scan


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def ssm_scan_op(delta, B_ssm, C_ssm, x, A, *, block_d: int = 512,
                chunk: int = 128, interpret: bool | None = None):
    if interpret is None:
        interpret = not _on_tpu()
    return ssm_scan(delta, B_ssm, C_ssm, x, A, block_d=block_d, chunk=chunk,
                    interpret=interpret)
