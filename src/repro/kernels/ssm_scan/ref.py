"""Pure-jnp oracle for the ssm_scan kernel (Mamba-1 selective scan).

Semantics (matches ``repro.models.layers._ssm_scan`` with A_full):

    a_t  = exp(delta_t ⊗ A)                    (B, DI, N)
    h_t  = a_t * h_{t-1} + delta_t * B_t * x_t
    y_t  = <h_t, C_t>                           (B, DI)

Computed with a plain lax.scan over time in f32 — the exact (if slow)
reference for every kernel shape in the sweep.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_scan_ref(delta, B_ssm, C_ssm, x, A, h0=None):
    """delta: (B,S,DI) f32; B/C: (B,S,N) f32; x: (B,S,DI); A: (DI,N) f32.
    Returns (y (B,S,DI) in x.dtype, h_last (B,DI,N) f32)."""

    b, s, di = delta.shape
    n = B_ssm.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((b, di, n), jnp.float32)

    def step(h, inp):
        d, bm, cm, xc = inp  # (B,DI), (B,N), (B,N), (B,DI)
        a = jnp.exp(d[..., None] * A[None])
        bx = d[..., None] * bm[:, None, :] * xc.astype(jnp.float32)[..., None]
        h = a * h + bx
        y = jnp.einsum("bdn,bn->bd", h, cm)
        return h, y

    xs = (
        jnp.moveaxis(delta.astype(jnp.float32), 1, 0),
        jnp.moveaxis(B_ssm.astype(jnp.float32), 1, 0),
        jnp.moveaxis(C_ssm.astype(jnp.float32), 1, 0),
        jnp.moveaxis(x, 1, 0),
    )
    h_last, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h_last
