"""Baseline files — park known findings, burn them down over time.

A baseline is a JSON multiset of finding fingerprints
(``rule::path::code`` — line-number independent, so unrelated edits that
shift a finding do not churn the file).  ``--baseline FILE`` subtracts
the baseline from the current findings; ``--write-baseline`` snapshots
the current state.  The diff also reports *stale* entries (baselined
findings that no longer occur) so the file shrinks as violations are
fixed.
"""

from __future__ import annotations

import collections
import json
import pathlib
from typing import Sequence

from .engine import Finding

SCHEMA = "simlint-baseline/v1"


def write_baseline(path: pathlib.Path, findings: Sequence[Finding]) -> None:
    """Snapshot ``findings`` as a fingerprint multiset at ``path``."""

    counts = collections.Counter(f.fingerprint for f in findings)
    payload = {
        "schema": SCHEMA,
        "fingerprints": {fp: counts[fp] for fp in sorted(counts)},
    }
    path.write_text(json.dumps(payload, indent=2) + "\n")


def load_baseline(path: pathlib.Path) -> collections.Counter[str]:
    """Load a baseline written by :func:`write_baseline`."""

    payload = json.loads(path.read_text())
    if payload.get("schema") != SCHEMA:
        raise ValueError(
            f"{path}: unknown baseline schema {payload.get('schema')!r} "
            f"(expected {SCHEMA!r})"
        )
    fingerprints = payload.get("fingerprints", {})
    if not isinstance(fingerprints, dict):
        raise ValueError(f"{path}: 'fingerprints' must be an object")
    counts: collections.Counter[str] = collections.Counter()
    for fp, n in fingerprints.items():
        if not isinstance(n, int) or n < 1:
            raise ValueError(f"{path}: bad count {n!r} for {fp!r}")
        counts[fp] = n
    return counts


def diff_baseline(
    findings: Sequence[Finding], baseline: collections.Counter[str]
) -> tuple[list[Finding], list[str]]:
    """Split findings against a baseline.

    Returns ``(new, stale)``: findings not covered by the baseline, and
    baselined fingerprints that no longer occur (candidates for removal).
    """

    budget = collections.Counter(baseline)
    new: list[Finding] = []
    for f in findings:
        if budget[f.fingerprint] > 0:
            budget[f.fingerprint] -= 1
        else:
            new.append(f)
    stale = sorted(fp for fp, n in budget.items() if n > 0)
    return new, stale
