"""simlint rule set — simulator/JAX-specific hazard classes.

Each rule targets a failure mode this codebase has actually been bitten
by (or is one hand-audit away from): nondeterministic RNG, global x64
toggles, Python control flow on traced values, unordered iteration
feeding simulation state, in-place mutation of frozen trace columns,
``assert``-guarded accounting that ``python -O`` strips, unit-suffix
mix-ups, undocumented engine accuracy contracts, shared mutable
defaults, swallowed exceptions, and per-instance-leaking method caches.

Rules are intentionally syntactic and conservative: they flag the
*pattern*, and an inline ``# simlint: disable=SLxxx`` records a reviewed
exemption.  See :mod:`repro.analysis.engine` for the engine and
:mod:`tests.test_analysis` for one known-bad snippet per rule.
"""

from __future__ import annotations

import ast
from typing import Iterable, Iterator

from .engine import Finding, ModuleContext, Rule

# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------


def _dotted(node: ast.AST) -> str:
    """Best-effort dotted name of an expression (``jax.lax.scan`` ->
    ``"jax.lax.scan"``; non-name parts collapse to ``""``)."""

    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    return ""


def _walk_no_lambda(node: ast.AST) -> Iterator[ast.AST]:
    """``ast.walk`` that does not descend into nested lambdas (their
    params shadow the enclosing traced params)."""

    stack = [node]
    while stack:
        n = stack.pop()
        yield n
        for child in ast.iter_child_nodes(n):
            if not isinstance(child, ast.Lambda):
                stack.append(child)


# ---------------------------------------------------------------------------
# SL101 — unseeded legacy numpy RNG
# ---------------------------------------------------------------------------


class UnseededRandomRule(Rule):
    id = "SL101"
    name = "unseeded-random"
    description = (
        "legacy np.random.* module-level calls draw from hidden global "
        "state; traces stop being a pure function of their seed. Use "
        "np.random.default_rng(seed)."
    )

    _ALLOWED = frozenset({
        "default_rng", "SeedSequence", "Generator", "BitGenerator",
        "PCG64", "PCG64DXSM", "Philox", "SFC64", "MT19937",
    })

    def check(self, ctx: ModuleContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)):
                continue
            func = node.func
            if func.attr in self._ALLOWED:
                continue
            if _dotted(func.value) in ("np.random", "numpy.random"):
                f = ctx.finding(
                    self, node,
                    f"np.random.{func.attr}() uses the hidden global RNG; "
                    "draw from np.random.default_rng(seed) instead",
                )
                if f:
                    yield f


# ---------------------------------------------------------------------------
# SL102 — x64 mutation outside the scoped context manager
# ---------------------------------------------------------------------------


class UnscopedX64Rule(Rule):
    id = "SL102"
    name = "unscoped-x64"
    description = (
        "global jax_enable_x64 toggles leak float64 into every caller "
        "and invalidate jit caches; use the scoped "
        "jax.experimental.enable_x64() context manager."
    )

    def check(self, ctx: ModuleContext) -> Iterable[Finding]:
        with_items = {
            item.context_expr
            for node in ast.walk(ctx.tree) if isinstance(node, ast.With)
            for item in node.items
        }
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call):
                callee = _dotted(node.func)
                if callee.endswith("config.update") and node.args:
                    arg0 = node.args[0]
                    if (isinstance(arg0, ast.Constant)
                            and arg0.value == "jax_enable_x64"):
                        f = ctx.finding(
                            self, node,
                            "global jax.config.update('jax_enable_x64', ...)"
                            " — use the scoped enable_x64() context manager",
                        )
                        if f:
                            yield f
                elif (callee.split(".")[-1] == "enable_x64"
                        and node not in with_items):
                    f = ctx.finding(
                        self, node,
                        "enable_x64() called outside a `with` statement — "
                        "the toggle never scopes back",
                    )
                    if f:
                        yield f
            elif isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (
                    node.targets if isinstance(node, ast.Assign)
                    else [node.target]
                )
                for t in targets:
                    if (isinstance(t, ast.Attribute)
                            and t.attr == "jax_enable_x64"):
                        f = ctx.finding(
                            self, node,
                            "direct assignment to jax_enable_x64 — use the "
                            "scoped enable_x64() context manager",
                        )
                        if f:
                            yield f


# ---------------------------------------------------------------------------
# SL103 — Python branches on traced values inside jit/scan/vmap bodies
# ---------------------------------------------------------------------------


_JIT_WRAPPERS = frozenset({
    "jit", "vmap", "pmap", "jax.jit", "jax.vmap", "jax.pmap",
    "checkify.checkify",
})
_SCAN_CALLS = frozenset({
    "scan", "lax.scan", "jax.lax.scan",
    "fori_loop", "lax.fori_loop", "jax.lax.fori_loop",
    "while_loop", "lax.while_loop", "jax.lax.while_loop",
})


class TracedBranchRule(Rule):
    id = "SL103"
    name = "traced-branch"
    description = (
        "Python if/while on a traced value inside a jit/scan/vmap body "
        "raises (or silently specializes) at trace time; use jnp.where / "
        "lax.cond / lax.select."
    )

    def _static_params(self, call: ast.Call, fn: ast.FunctionDef) -> set[str]:
        """Params named static via static_argnums/static_argnames on a
        ``partial(jax.jit, ...)``-style wrapper call."""

        static: set[str] = set()
        names = [a.arg for a in fn.args.posonlyargs + fn.args.args]
        for kw in call.keywords:
            if kw.arg == "static_argnames":
                for el in ast.walk(kw.value):
                    if isinstance(el, ast.Constant) and isinstance(el.value, str):
                        static.add(el.value)
            elif kw.arg == "static_argnums":
                for el in ast.walk(kw.value):
                    if (isinstance(el, ast.Constant)
                            and isinstance(el.value, int)
                            and 0 <= el.value < len(names)):
                        static.add(names[el.value])
        return static

    def _traced_functions(
        self, ctx: ModuleContext
    ) -> Iterator[tuple[ast.AST, set[str]]]:
        """(function node, traced param names) for every function that is
        jitted/vmapped (decorator) or passed to jit/vmap/scan (call)."""

        defs: dict[str, ast.FunctionDef] = {
            n.name: n for n in ast.walk(ctx.tree)
            if isinstance(n, ast.FunctionDef)
        }

        def params(fn: ast.FunctionDef | ast.Lambda) -> set[str]:
            a = fn.args
            return {
                x.arg
                for x in a.posonlyargs + a.args + a.kwonlyargs
                + ([a.vararg] if a.vararg else [])
                + ([a.kwarg] if a.kwarg else [])
            }

        for fn in defs.values():
            for dec in fn.decorator_list:
                target = dec.func if isinstance(dec, ast.Call) else dec
                name = _dotted(target)
                if name in _JIT_WRAPPERS:
                    yield fn, params(fn)
                elif (isinstance(dec, ast.Call) and name.endswith("partial")
                        and dec.args and _dotted(dec.args[0]) in _JIT_WRAPPERS):
                    yield fn, params(fn) - self._static_params(dec, fn)

        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            callee = _dotted(node.func)
            if callee in _JIT_WRAPPERS or callee in _SCAN_CALLS:
                fn_args = [a for a in node.args]
                if callee in _SCAN_CALLS and not fn_args:
                    continue
                cand = fn_args[0] if fn_args else None
                if isinstance(cand, ast.Lambda):
                    yield cand, params(cand)
                elif isinstance(cand, ast.Name) and cand.id in defs:
                    fn = defs[cand.id]
                    yield fn, params(fn) - self._static_params(node, fn)

    def check(self, ctx: ModuleContext) -> Iterable[Finding]:
        seen: set[tuple[int, int]] = set()
        for fn, traced in self._traced_functions(ctx):
            body = fn.body if isinstance(fn.body, list) else [fn.body]
            for stmt in body:
                for node in _walk_no_lambda(stmt):
                    if not isinstance(node, (ast.If, ast.While)):
                        continue
                    used = {
                        n.id for n in ast.walk(node.test)
                        if isinstance(n, ast.Name)
                    }
                    hit = used & traced
                    key = (node.lineno, node.col_offset)
                    if hit and key not in seen:
                        seen.add(key)
                        kind = "if" if isinstance(node, ast.If) else "while"
                        f = ctx.finding(
                            self, node,
                            f"Python `{kind}` on traced value(s) "
                            f"{sorted(hit)} inside a jit/scan/vmap body — "
                            "use jnp.where or lax.cond",
                        )
                        if f:
                            yield f


# ---------------------------------------------------------------------------
# SL104 — iteration over unordered sets feeding simulation state
# ---------------------------------------------------------------------------


class UnorderedIterationRule(Rule):
    id = "SL104"
    name = "unordered-iteration"
    description = (
        "iterating a set feeds hash-order nondeterminism into whatever "
        "consumes it; wrap in sorted() to pin the order."
    )

    _CONSUMERS = frozenset({"list", "tuple", "enumerate", "sum", "min", "max"})

    def _is_set_expr(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Set) or isinstance(node, ast.SetComp):
            return True
        if isinstance(node, ast.Call):
            return _dotted(node.func) in ("set", "frozenset")
        return False

    def check(self, ctx: ModuleContext) -> Iterable[Finding]:
        iters: list[ast.AST] = []
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.For, ast.AsyncFor)):
                iters.append(node.iter)
            elif isinstance(node, ast.comprehension):
                iters.append(node.iter)
            elif (isinstance(node, ast.Call)
                    and _dotted(node.func) in self._CONSUMERS and node.args):
                iters.append(node.args[0])
        for it in iters:
            if self._is_set_expr(it):
                f = ctx.finding(
                    self, it,
                    "iteration over a set is hash-ordered "
                    "(nondeterministic across runs/versions); "
                    "wrap in sorted()",
                )
                if f:
                    yield f


# ---------------------------------------------------------------------------
# SL105 — in-place mutation of frozen trace/tape columns
# ---------------------------------------------------------------------------


class TapeColumnMutationRule(Rule):
    id = "SL105"
    name = "tape-column-mutation"
    description = (
        "TraceBatch/StreamScores columns are shared, frozen-by-contract "
        "arrays (fixtures, tape caches, shards alias them); in-place "
        "stores corrupt every aliasing view. Copy, then mutate."
    )

    # the columnar fields of TraceBatch / StreamScores (core/trace.py)
    COLUMNS = frozenset({
        "offsets", "sizes", "file_ids", "app_ids", "times",
        "gap_positions", "gap_seconds",
        "rf_sum", "percentage", "seek_distance", "nbytes", "offset_sum",
    })
    _MUTATORS = frozenset({"sort", "fill", "resize", "partition", "put"})

    def check(self, ctx: ModuleContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            targets: list[ast.AST] = []
            if isinstance(node, ast.Assign):
                targets = list(node.targets)
            elif isinstance(node, ast.AugAssign):
                targets = [node.target]
            for t in targets:
                if (isinstance(t, ast.Subscript)
                        and isinstance(t.value, ast.Attribute)
                        and t.value.attr in self.COLUMNS):
                    f = ctx.finding(
                        self, node,
                        f"in-place store into `.{t.value.attr}[...]` — "
                        "trace/tape columns are frozen by contract; "
                        "build a new array instead",
                    )
                    if f:
                        yield f
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in self._MUTATORS
                    and isinstance(node.func.value, ast.Attribute)
                    and node.func.value.attr in self.COLUMNS):
                f = ctx.finding(
                    self, node,
                    f"in-place `.{node.func.attr}()` on column "
                    f"`.{node.func.value.attr}` — trace/tape columns are "
                    "frozen by contract (use np.sort(...) etc.)",
                )
                if f:
                    yield f


# ---------------------------------------------------------------------------
# SL106 — load-bearing assert in library code
# ---------------------------------------------------------------------------


class LoadBearingAssertRule(Rule):
    id = "SL106"
    name = "load-bearing-assert"
    description = (
        "`assert` in library code vanishes under `python -O`; accounting "
        "and state-machine invariants must raise ValueError/RuntimeError "
        "(or go through the sanitizer) so optimization cannot disable "
        "them."
    )

    def check(self, ctx: ModuleContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Assert):
                f = ctx.finding(
                    self, node,
                    "assert is stripped under python -O; raise "
                    "ValueError/RuntimeError or use repro.analysis."
                    "sanitize.check",
                )
                if f:
                    yield f


# ---------------------------------------------------------------------------
# SL107 — unit-suffix mismatches
# ---------------------------------------------------------------------------


_SUFFIX_FAMILIES: dict[str, str] = {}
for _fam, _sufs in (
    ("bytes", ("_bytes",)),
    ("megabytes", ("_mb", "_mbs", "_mib")),
    ("seconds", ("_seconds", "_secs", "_sec")),
    ("milliseconds", ("_ms",)),
    ("microseconds", ("_us",)),
):
    for _s in _sufs:
        _SUFFIX_FAMILIES[_s] = _fam


def _unit_family(node: ast.AST) -> str | None:
    if isinstance(node, ast.Name):
        name = node.id
    elif isinstance(node, ast.Attribute):
        name = node.attr
    else:
        return None
    lowered = name.lower()
    for suffix, family in _SUFFIX_FAMILIES.items():
        if lowered.endswith(suffix):
            return family
    return None


class UnitSuffixRule(Rule):
    id = "SL107"
    name = "unit-suffix-mismatch"
    description = (
        "a `*_bytes` name bound to (or added against) a `*_seconds`/"
        "`*_mb`/`*_us` name with no conversion is a unit bug waiting in "
        "the accounting."
    )

    def check(self, ctx: ModuleContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                tf = _unit_family(node.targets[0])
                vf = _unit_family(node.value)
                if tf and vf and tf != vf:
                    f = ctx.finding(
                        self, node,
                        f"{tf} name assigned directly from a {vf} name "
                        "with no conversion",
                    )
                    if f:
                        yield f
            elif (isinstance(node, ast.AugAssign)
                    and isinstance(node.op, (ast.Add, ast.Sub))):
                tf = _unit_family(node.target)
                vf = _unit_family(node.value)
                if tf and vf and tf != vf:
                    f = ctx.finding(
                        self, node,
                        f"{tf} name incremented by a {vf} name "
                        "with no conversion",
                    )
                    if f:
                        yield f
            elif (isinstance(node, ast.BinOp)
                    and isinstance(node.op, (ast.Add, ast.Sub))):
                lf = _unit_family(node.left)
                rf = _unit_family(node.right)
                if lf and rf and lf != rf:
                    f = ctx.finding(
                        self, node,
                        f"{lf} name added/subtracted against a {rf} name "
                        "with no conversion",
                    )
                    if f:
                        yield f


# ---------------------------------------------------------------------------
# SL108 — public engine entry points must state their accuracy contract
# ---------------------------------------------------------------------------


class EngineContractRule(Rule):
    id = "SL108"
    name = "missing-engine-contract"
    description = (
        "public run*/simulate*/replay* entry points in repro.core must "
        "say what accuracy they promise (bit-exact vs the oracle, or a "
        "documented tolerance tier) — that contract is what the golden "
        "fixtures enforce."
    )

    _PREFIXES = ("run", "simulate", "replay")
    _TOKENS = (
        "exact", "oracle", "tolerance", "accuracy contract",
        "bit-identical",
    )

    def check(self, ctx: ModuleContext) -> Iterable[Finding]:
        parts = ctx.rel.split("/")
        if "core" not in parts[:-1]:
            return
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.FunctionDef):
                continue
            name = node.name
            if name.startswith("_") or not name.startswith(self._PREFIXES):
                continue
            doc = (ast.get_docstring(node) or "").lower()
            if not any(tok in doc for tok in self._TOKENS):
                f = ctx.finding(
                    self, node,
                    f"`{name}` is a public engine entry point but its "
                    "docstring states no accuracy contract "
                    "(bit-exact / oracle / tolerance)",
                )
                if f:
                    yield f


# ---------------------------------------------------------------------------
# SL109 — shared mutable default arguments
# ---------------------------------------------------------------------------


class MutableDefaultRule(Rule):
    id = "SL109"
    name = "mutable-default-arg"
    description = (
        "a mutable default is one object shared across every call — "
        "state leaks between runs; default to None and construct inside."
    )

    _CTORS = frozenset({
        "list", "dict", "set", "deque", "collections.deque",
        "np.array", "numpy.array", "np.zeros", "np.empty", "np.ones",
    })

    def _is_mutable(self, node: ast.AST) -> bool:
        if isinstance(node, (ast.List, ast.Dict, ast.Set)):
            return True
        return (isinstance(node, ast.Call)
                and _dotted(node.func) in self._CTORS)

    def check(self, ctx: ModuleContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.Lambda)):
                continue
            defaults = list(node.args.defaults) + [
                d for d in node.args.kw_defaults if d is not None
            ]
            for d in defaults:
                if self._is_mutable(d):
                    f = ctx.finding(
                        self, d,
                        "mutable default argument is shared across calls; "
                        "use None and construct in the body",
                    )
                    if f:
                        yield f


# ---------------------------------------------------------------------------
# SL110 — silently swallowed exceptions
# ---------------------------------------------------------------------------


class SilentExceptionRule(Rule):
    id = "SL110"
    name = "silent-exception"
    description = (
        "a bare `except:` (or `except Exception: pass`) hides the "
        "accounting bug it catches; catch the specific error or at "
        "least record it."
    )

    def check(self, ctx: ModuleContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if node.type is None:
                f = ctx.finding(
                    self, node,
                    "bare `except:` also swallows KeyboardInterrupt/"
                    "SystemExit; name the exception",
                )
                if f:
                    yield f
                continue
            broad = _dotted(node.type) in ("Exception", "BaseException")
            silent = all(
                isinstance(s, (ast.Pass, ast.Continue)) for s in node.body
            )
            if broad and silent:
                f = ctx.finding(
                    self, node,
                    "`except Exception` with an empty body silently "
                    "swallows every bug; narrow it or handle it",
                )
                if f:
                    yield f


# ---------------------------------------------------------------------------
# SL111 — lru_cache on methods leaks instances
# ---------------------------------------------------------------------------


class MethodLruCacheRule(Rule):
    id = "SL111"
    name = "method-lru-cache"
    description = (
        "functools.lru_cache on a method keys the cache on `self`: "
        "instances never free, and two simulators with equal args share "
        "nothing; cache at module level or on frozen keys."
    )

    _CACHES = frozenset({
        "lru_cache", "cache", "functools.lru_cache", "functools.cache",
    })

    def check(self, ctx: ModuleContext) -> Iterable[Finding]:
        for cls in ast.walk(ctx.tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            for node in cls.body:
                if not isinstance(node, ast.FunctionDef):
                    continue
                args = node.args.posonlyargs + node.args.args
                if not args or args[0].arg not in ("self", "cls"):
                    continue
                for dec in node.decorator_list:
                    target = dec.func if isinstance(dec, ast.Call) else dec
                    if _dotted(target) in self._CACHES:
                        f = ctx.finding(
                            self, dec,
                            f"lru_cache on method `{node.name}` pins every "
                            "instance in the cache key",
                        )
                        if f:
                            yield f


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


_REGISTRY: tuple[Rule, ...] = (
    UnseededRandomRule(),
    UnscopedX64Rule(),
    TracedBranchRule(),
    UnorderedIterationRule(),
    TapeColumnMutationRule(),
    LoadBearingAssertRule(),
    UnitSuffixRule(),
    EngineContractRule(),
    MutableDefaultRule(),
    SilentExceptionRule(),
    MethodLruCacheRule(),
)


def all_rules() -> tuple[Rule, ...]:
    """The full registry, id-ordered."""

    return _REGISTRY


def rules_by_id(ids: Iterable[str]) -> tuple[Rule, ...]:
    wanted = {i.strip().upper() for i in ids}
    known = {r.id for r in _REGISTRY}
    unknown = sorted(wanted - known)
    if unknown:
        raise ValueError(f"unknown rule id(s) {unknown}; known: {sorted(known)}")
    return tuple(r for r in _REGISTRY if r.id in wanted)
