"""Runtime sanitizer mode — the static checker's runtime twin.

simlint (:mod:`repro.analysis.engine`) proves invariants that are visible
in the source; this module carries the ones that are only visible at run
time: monotonic clocks, non-negative byte deltas, ledger closure, tape
validity.  The engines compile these checks into their hot paths **only
when sanitize mode is on**, so the default replay stays at full speed and
CI can run the entire golden matrix with every invariant armed.

Enablement, in precedence order:

1. :func:`sanitizing` — a context manager / explicit override, used by
   tests and the ``--sanitize`` flags of the golden CLI;
2. a ``sanitize=`` constructor argument on the engines (``True``/``False``
   pins the instance, ``None`` defers);
3. the ``REPRO_SANITIZE`` environment variable (``1``/``true``/``yes``
   /``on``), read at engine construction — ``REPRO_SANITIZE=1 pytest``
   replays the whole suite with checks on.

A failed check raises :class:`SanitizerError` naming the violated
invariant — never an ``assert``, so ``python -O`` cannot strip it.
"""

from __future__ import annotations

import contextlib
import os
from typing import Iterator

ENV_VAR = "REPRO_SANITIZE"

_TRUTHY = ("1", "true", "yes", "on")

# Explicit override; None = fall back to the environment variable.
_override: bool | None = None


class SanitizerError(RuntimeError):
    """A runtime simulator invariant was violated (sanitize mode)."""


def enabled() -> bool:
    """Is sanitize mode on (override first, then ``REPRO_SANITIZE``)?"""

    if _override is not None:
        return _override
    return os.environ.get(ENV_VAR, "").strip().lower() in _TRUTHY


def resolve(sanitize: bool | None) -> bool:
    """Resolve an engine's ``sanitize=`` argument: explicit wins, ``None``
    defers to :func:`enabled`."""

    return enabled() if sanitize is None else bool(sanitize)


@contextlib.contextmanager
def sanitizing(on: bool = True) -> Iterator[None]:
    """Force sanitize mode on (or off) for the dynamic extent of the
    ``with`` block, overriding the environment variable."""

    global _override
    prev = _override
    _override = bool(on)
    try:
        yield
    finally:
        _override = prev


def check(cond: bool, message: str, *args: object) -> None:
    """Raise :class:`SanitizerError` with ``message % args`` unless
    ``cond``.  Callers gate the *computation* of expensive conditions on
    their own ``sanitize`` flag; this helper only formats and raises."""

    if not cond:
        raise SanitizerError(message % args if args else message)
