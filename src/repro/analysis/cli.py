"""``python -m repro.analysis`` — the simlint command line.

Exit status is 0 when no unbaselined findings remain, 1 otherwise, so CI
can gate on it directly::

    python -m repro.analysis --check src/repro
    python -m repro.analysis --check src/repro --baseline simlint.json
    python -m repro.analysis --check src/repro --write-baseline simlint.json
    python -m repro.analysis --list-rules
"""

from __future__ import annotations

import argparse
import pathlib
import sys
from typing import Sequence

from . import baseline as baseline_mod
from .engine import check_paths
from .rules import all_rules, rules_by_id


def _build_parser() -> argparse.ArgumentParser:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="simlint: simulator-invariant static checker",
    )
    parser.add_argument(
        "--check", nargs="+", metavar="PATH", default=None,
        help="files or directories to scan (e.g. src/repro)",
    )
    parser.add_argument(
        "--baseline", metavar="FILE", default=None,
        help="subtract this baseline file from the findings",
    )
    parser.add_argument(
        "--write-baseline", metavar="FILE", default=None,
        help="snapshot current findings to FILE and exit 0",
    )
    parser.add_argument(
        "--rules", metavar="IDS", default=None,
        help="comma-separated rule ids to run (default: all)",
    )
    parser.add_argument(
        "--root", metavar="DIR", default=None,
        help="directory paths are reported relative to (default: cwd)",
    )
    parser.add_argument(
        "--list-rules", action="store_true",
        help="print the rule registry and exit",
    )
    return parser


def main(argv: Sequence[str] | None = None) -> int:
    args = _build_parser().parse_args(argv)

    if args.list_rules:
        for rule in all_rules():
            print(f"{rule.id} [{rule.name}]")
            print(f"    {rule.description}")
        return 0

    if not args.check:
        print("error: --check PATH... is required (or --list-rules)",
              file=sys.stderr)
        return 2

    rules = (
        rules_by_id(args.rules.split(",")) if args.rules else None
    )
    root = pathlib.Path(args.root) if args.root else None
    findings = check_paths(args.check, rules=rules, root=root)

    if args.write_baseline:
        path = pathlib.Path(args.write_baseline)
        baseline_mod.write_baseline(path, findings)
        print(f"simlint: wrote {len(findings)} finding(s) to {path}")
        return 0

    stale: list[str] = []
    if args.baseline:
        counts = baseline_mod.load_baseline(pathlib.Path(args.baseline))
        findings, stale = baseline_mod.diff_baseline(findings, counts)

    for f in findings:
        print(f.render())
    for fp in stale:
        print(f"stale baseline entry (fixed — remove it): {fp}")

    n = len(findings)
    if n or stale:
        label = "new " if args.baseline else ""
        print(
            f"simlint: {n} {label}finding(s)"
            + (f", {len(stale)} stale baseline entr(y/ies)" if stale else "")
        )
        return 1
    print("simlint: clean")
    return 0
