"""repro.analysis — static checker (simlint) + runtime sanitizer.

Two halves of one contract:

- **simlint** (:mod:`.engine`, :mod:`.rules`, :mod:`.cli`) statically
  checks the source for simulator-invariant hazards — run it with
  ``python -m repro.analysis --check src/repro``;
- **sanitize mode** (:mod:`.sanitize`) arms runtime invariant checks in
  the engines, service loop, and golden harness — enable with
  ``REPRO_SANITIZE=1`` or the :func:`sanitizing` context manager.
"""

from .engine import Finding, ModuleContext, Rule, check_paths, check_source
from .rules import all_rules, rules_by_id
from .sanitize import SanitizerError, enabled, resolve, sanitizing

__all__ = [
    "Finding",
    "ModuleContext",
    "Rule",
    "SanitizerError",
    "all_rules",
    "check_paths",
    "check_source",
    "enabled",
    "resolve",
    "rules_by_id",
    "sanitizing",
]
