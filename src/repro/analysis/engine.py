"""simlint — AST-based simulator-invariant checker (rule engine).

The headline claims of this repo rest on invariants the test suite can
only spot-check: bit-exactness of the numpy oracle engines, Eq. 6 seek
charging on every drain path, byte-conservation ledgers, deterministic
seeded traces.  The hazard classes that break them are *visible in the
source* — an unseeded ``np.random`` call, a Python branch on a traced
value, a load-bearing ``assert`` that ``python -O`` strips.  This module
is the engine that hunts them: it parses every file once, hands the
shared :class:`ModuleContext` to each registered :class:`Rule`, and
collects :class:`Finding`\\ s.

Rules live in :mod:`repro.analysis.rules`; the CLI is
``python -m repro.analysis --check src/repro`` (see
:mod:`repro.analysis.cli`); known/accepted findings can be parked in a
baseline file (:mod:`repro.analysis.baseline`) and burned down over
time.

Inline suppression: append ``# simlint: disable=SL103`` (comma-separated
ids, or ``all``) to the offending line.  Suppressions are deliberate,
reviewable exemptions — prefer fixing the code.
"""

from __future__ import annotations

import ast
import dataclasses
import pathlib
import re
from typing import Iterable, Sequence

_SUPPRESS_RE = re.compile(r"#\s*simlint:\s*disable=([A-Za-z0-9_,\s]+)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str  # rule id, e.g. "SL106"
    name: str  # rule slug, e.g. "load-bearing-assert"
    path: str  # posix path as scanned (baseline key component)
    line: int  # 1-indexed
    message: str
    code: str  # stripped source line (baseline key component)

    @property
    def fingerprint(self) -> str:
        """Line-number-independent identity used by the baseline: a
        finding survives unrelated edits that only shift it."""

        return f"{self.rule}::{self.path}::{self.code}"

    def render(self) -> str:
        return (
            f"{self.path}:{self.line}: {self.rule} [{self.name}] "
            f"{self.message}\n    {self.code}"
        )


class Rule:
    """Base class for simlint rules.

    Subclasses set ``id``/``name``/``description`` and implement
    :meth:`check`, yielding findings via ``ctx.finding``.
    """

    id: str = "SL000"
    name: str = "abstract-rule"
    description: str = ""

    def check(self, ctx: "ModuleContext") -> Iterable[Finding]:
        raise NotImplementedError


class ModuleContext:
    """One parsed module, shared by every rule (parse once, check many)."""

    def __init__(self, path: pathlib.Path, rel: str, source: str):
        self.path = path
        self.rel = rel
        self.source = source
        self.tree = ast.parse(source, filename=str(path))
        self.lines = source.splitlines()
        self._suppressed: dict[int, set[str]] | None = None
        self._parents: dict[ast.AST, ast.AST] | None = None

    # -- lazy shared views ---------------------------------------------
    @property
    def parents(self) -> dict[ast.AST, ast.AST]:
        """Child node -> parent node map (built on first use)."""

        if self._parents is None:
            self._parents = {
                child: parent
                for parent in ast.walk(self.tree)
                for child in ast.iter_child_nodes(parent)
            }
        return self._parents

    def code_at(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1].strip()
        return ""

    def _suppressions(self) -> dict[int, set[str]]:
        if self._suppressed is None:
            table: dict[int, set[str]] = {}
            for i, text in enumerate(self.lines, start=1):
                m = _SUPPRESS_RE.search(text)
                if m:
                    table[i] = {
                        t.strip().upper()
                        for t in m.group(1).split(",") if t.strip()
                    }
            self._suppressed = table
        return self._suppressed

    def suppressed(self, line: int, rule_id: str) -> bool:
        ids = self._suppressions().get(line)
        return bool(ids) and (rule_id.upper() in ids or "ALL" in ids)

    # -- finding constructor -------------------------------------------
    def finding(
        self, rule: Rule, node: ast.AST, message: str
    ) -> Finding | None:
        """Build a finding at ``node`` unless suppressed inline."""

        line = getattr(node, "lineno", 0)
        if self.suppressed(line, rule.id):
            return None
        return Finding(
            rule=rule.id,
            name=rule.name,
            path=self.rel,
            line=line,
            message=message,
            code=self.code_at(line),
        )


def iter_py_files(paths: Sequence[pathlib.Path]) -> list[pathlib.Path]:
    """Expand files/directories into a sorted, de-duplicated .py list."""

    out: set[pathlib.Path] = set()
    for p in paths:
        p = pathlib.Path(p)
        if p.is_dir():
            out.update(p.rglob("*.py"))
        elif p.suffix == ".py":
            out.add(p)
        else:
            raise ValueError(f"{p}: not a .py file or directory")
    return sorted(out)


def _rel(path: pathlib.Path, root: pathlib.Path | None) -> str:
    base = root or pathlib.Path.cwd()
    try:
        return path.resolve().relative_to(base.resolve()).as_posix()
    except ValueError:
        return path.as_posix()


def check_paths(
    paths: Sequence[pathlib.Path | str],
    rules: Sequence[Rule] | None = None,
    root: pathlib.Path | None = None,
) -> list[Finding]:
    """Run ``rules`` (default: the full registry) over every .py file
    under ``paths``; findings are ordered by (path, line, rule)."""

    from .rules import all_rules

    active = list(rules) if rules is not None else all_rules()
    findings: list[Finding] = []
    for path in iter_py_files([pathlib.Path(p) for p in paths]):
        source = path.read_text()
        ctx = ModuleContext(path, _rel(path, root), source)
        for rule in active:
            findings.extend(f for f in rule.check(ctx) if f is not None)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def check_source(
    source: str,
    rules: Sequence[Rule] | None = None,
    rel: str = "core/snippet.py",
) -> list[Finding]:
    """Check an in-memory snippet (the per-rule unit tests' entry point).

    ``rel`` is the pretend path — rules that scope by location (e.g. the
    engine-contract rule keys on ``core/``) see it as the module's
    address.
    """

    from .rules import all_rules

    active = list(rules) if rules is not None else all_rules()
    ctx = ModuleContext(pathlib.Path(rel), rel, source)
    findings = [
        f for rule in active for f in rule.check(ctx) if f is not None
    ]
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings
