"""Launchers: mesh construction, step builders, dry-run, train/serve CLIs."""

from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step

__all__ = [
    "make_production_mesh",
    "make_host_mesh",
    "make_train_step",
    "make_prefill_step",
    "make_serve_step",
]
