"""Production meshes (assignment: 16x16 single-pod, 2x16x16 multi-pod).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state.  The dry-run environment exposes 512 host-platform
placeholder devices; the single-pod mesh takes the first 256.
"""

from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < ndev:
        raise RuntimeError(
            f"need {ndev} devices for mesh {shape}, have {len(devices)} — "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512"
        )
    # jax.sharding.AxisType and make_mesh's kwargs vary across jax versions;
    # evaluate the optional pieces defensively.
    axis_type = getattr(jax.sharding, "AxisType", None)
    kw = {"axis_types": (axis_type.Auto,) * len(axes)} if axis_type else {}
    try:
        return jax.make_mesh(shape, axes, devices=devices[:ndev], **kw)
    except TypeError:  # older jax without the devices kwarg
        from jax.sharding import Mesh

        return Mesh(np.asarray(devices[:ndev]).reshape(shape), axes)


def make_host_mesh(model_axis: int = 1):
    """A tiny mesh over the real local devices (tests / examples)."""

    import jax

    n = len(jax.devices())
    data = n // model_axis
    axis_type = getattr(jax.sharding, "AxisType", None)
    kw = {"axis_types": (axis_type.Auto,) * 2} if axis_type else {}
    return jax.make_mesh((data, model_axis), ("data", "model"), **kw)
