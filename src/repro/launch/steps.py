"""jit-able step functions (train / prefill / serve) over a ModelApi."""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.registry import ModelApi
from repro.optim import (
    AdamWConfig,
    CompressionConfig,
    apply_updates,
    compress_tree,
)

Tree = Any


def make_train_step(model: ModelApi, opt_cfg: AdamWConfig | None = None,
                    comp_cfg: CompressionConfig | None = None):
    opt_cfg = opt_cfg or AdamWConfig()
    comp_cfg = comp_cfg or CompressionConfig()
    mb = model.cfg.microbatch

    def grads_of(params: Tree, batch: dict):
        if not mb:
            return jax.value_and_grad(model.loss_fn)(params, batch)
        # gradient accumulation over microbatches (activation memory ~ mb/B;
        # also the natural unit for compute/comm overlap — each microbatch's
        # reduce-scatter pipelines behind the next microbatch's compute)
        from repro.models.layers import scan as _scan  # unroll-aware

        b = batch["tokens"].shape[0]
        if b % mb != 0:
            raise ValueError(f"batch {b} not divisible by microbatch {mb}")
        a = b // mb
        resh = jax.tree.map(lambda x: x.reshape(a, mb, *x.shape[1:]), batch)
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def acc(carry, mbatch):
            loss_sum, gsum = carry
            l, g = jax.value_and_grad(model.loss_fn)(params, mbatch)
            gsum = jax.tree.map(
                lambda s, x: s + x.astype(jnp.float32), gsum, g)
            return (loss_sum + l, gsum), None

        (loss_sum, gsum), _ = _scan(acc, (jnp.float32(0.0), zeros), resh)
        inv = 1.0 / a
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, gsum)

    def train_step(params: Tree, opt_state: Tree, batch: dict):
        loss, grads = grads_of(params, batch)
        # cross-pod gradient compression (identity when disabled)
        grads, _err = compress_tree(grads, None, comp_cfg)
        params, opt_state, metrics = apply_updates(opt_cfg, params, grads, opt_state)
        metrics = dict(metrics, loss=loss)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(model: ModelApi):
    def prefill_step(params: Tree, batch: dict):
        return model.prefill(params, batch)

    return prefill_step


def make_serve_step(model: ModelApi):
    def serve_step(params: Tree, cache: Tree, tokens: jax.Array,
                   pos: jax.Array):
        logits, new_cache = model.decode_step(params, cache, tokens, pos)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok[:, None], logits, new_cache

    return serve_step
