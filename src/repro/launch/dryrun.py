import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e) + roofline extraction (deliverable g).

Lowers + compiles every (arch x shape-cell x mesh) combination against the
production meshes — 16x16 single-pod and 2x16x16 multi-pod — using
ShapeDtypeStruct inputs only (no allocation), and records:

* ``compiled.memory_analysis()``  (bytes per device — proves it fits)
* ``compiled.cost_analysis()``    (per-device FLOPs / bytes)
* the collective-bytes breakdown parsed from the post-SPMD HLO

into ``experiments/dryrun/<arch>__<cell>__<mesh>.json`` (idempotent).

Loop-trip-count calibration: XLA's HLO cost analysis counts a while-loop
body ONCE, so scanned-layer models under-report FLOPs/bytes/collectives by
~n_layers.  We therefore lower each cell twice more at small depths with
every scan UNROLLED (repro.models.layers.unroll_scans) and extrapolate
linearly to the real depth — all numbers still come from compiled
artifacts.  ``roofline`` holds the corrected terms; ``roofline_raw`` the
uncorrected ones; ``calibration`` the two measured points.

Usage:
    python -m repro.launch.dryrun --arch qwen3-1.7b --cell train_4k --mesh single
    python -m repro.launch.dryrun --all [--mesh both] [--force]
"""

import argparse
import dataclasses
import json
import time
import traceback


def _depth_plan(cfg):
    """(make_cfg(depth), d1, d2, L_eff) for linear FLOP extrapolation."""

    fam = cfg.family
    if fam == "hybrid":
        e = cfg.shared_attn_every

        def mk(g):
            return dataclasses.replace(cfg, n_layers=g * e)

        return mk, 1, 2, cfg.n_layers // e
    if fam == "encdec":
        def mk(d):
            return dataclasses.replace(cfg, n_layers=d, encoder_layers=d)

        return mk, 1, 2, cfg.n_layers
    # dense / moe / vlm / ssm: depth = n_layers
    def mk(d):
        return dataclasses.replace(cfg, n_layers=d)

    return mk, 2, 4, cfg.n_layers


def _build_jit(cfg, cell, mesh):
    """Build the jitted step + abstract args for one cell under a mesh.

    Must be called inside ``use_mesh(mesh)``.
    """

    import jax
    from jax.sharding import NamedSharding

    from repro.distributed.sharding import spec_for, tree_shardings
    from repro.launch.steps import (
        make_prefill_step,
        make_serve_step,
        make_train_step,
    )
    from repro.models import get_model, input_axes, input_specs
    from repro.optim import abstract_state

    model = get_model(cfg)
    aparams = model.abstract_params()
    paxes = model.param_axes()
    pshard = tree_shardings(aparams, paxes, mesh)
    binputs = input_specs(cfg, cell.kind, cell.global_batch, cell.seq_len)
    baxes = input_axes(cfg, cell.kind)
    bshard = {
        k: NamedSharding(mesh, spec_for(binputs[k].shape, baxes[k], mesh))
        for k in binputs
    }

    if cell.kind == "train":
        ostate = abstract_state(aparams)
        oshard = tree_shardings(
            {"m": aparams, "v": aparams}, {"m": paxes, "v": paxes}, mesh)
        oshard["step"] = NamedSharding(mesh, spec_for((), (), mesh))
        jf = jax.jit(
            make_train_step(model),
            in_shardings=(pshard, oshard, bshard),
            donate_argnums=(0, 1),
        )
        args = (aparams, ostate, binputs)
    elif cell.kind == "prefill":
        jf = jax.jit(make_prefill_step(model), in_shardings=(pshard, bshard))
        args = (aparams, binputs)
    else:  # decode
        acache = model.abstract_cache(cell.global_batch, cell.seq_len)
        cshard = tree_shardings(acache, model.cache_axes(), mesh)
        jf = jax.jit(
            make_serve_step(model),
            in_shardings=(
                pshard, cshard, bshard["tokens"],
                NamedSharding(mesh, spec_for((), (), mesh)),
            ),
            donate_argnums=(1,),
        )
        args = (aparams, acache, binputs["tokens"],
                jax.ShapeDtypeStruct((), jax.numpy.int32))
    return jf, args


def _rules(cfg):
    return dict(cfg.shard_rules_override) if cfg.shard_rules_override else None


def _measure(cfg, cell, mesh):
    """Lower+compile one cell; return (compiled, flops, bytes, link_bytes)."""

    from repro.distributed.sharding import use_mesh
    from repro.launch.roofline import parse_collectives

    with use_mesh(mesh, rules=_rules(cfg)):
        jf, args = _build_jit(cfg, cell, mesh)
        compiled = jf.lower(*args).compile()
    ca = compiled.cost_analysis() or {}
    colls = parse_collectives(compiled.as_text())
    return compiled, float(ca.get("flops", 0.0)), float(
        ca.get("bytes accessed", 0.0)), colls


def _measure_lowered_flops(cfg, cell, mesh) -> float:
    """GLOBAL (pre-SPMD) flops from the unoptimized lowering — cheap
    (seconds), exact for flop counting; used for the heavy ssm/hybrid
    calibrations where the unrolled backend compile takes minutes."""

    from repro.distributed.sharding import use_mesh

    with use_mesh(mesh, rules=_rules(cfg)):
        jf, args = _build_jit(cfg, cell, mesh)
        ca = jf.lower(*args).cost_analysis() or {}
    return float(ca.get("flops", 0.0))


def run_cell(arch: str, cell_name: str, mesh_kind: str, out_dir: str,
             force: bool = False, save_hlo: bool = False,
             override_cfg=None, tag: str = "", calibrate: bool = True) -> dict:
    from repro.configs import SHAPE_CELLS, get_config
    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import (
        RooflineTerms,
        derive_terms,
        model_flops_per_step,
        HBM_BW,
        ICI_BW,
        PEAK_FLOPS,
    )
    from repro.models.layers import unroll_scans

    name = f"{arch}__{cell_name}__{mesh_kind}{tag}"
    out_path = os.path.join(out_dir, name + ".json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    cfg = override_cfg if override_cfg is not None else get_config(arch)
    cell = SHAPE_CELLS[cell_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    record: dict = {
        "arch": arch, "cell": cell_name, "mesh": mesh_kind,
        "mesh_shape": dict(mesh.shape), "status": "running",
    }

    t0 = time.time()
    compiled, flops_raw, bytes_raw, colls = _measure(cfg, cell, mesh)
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    print(mem)  # proves it fits
    hlo_len = len(compiled.as_text())
    raw_terms = derive_terms(
        {"flops": flops_raw, "bytes accessed": bytes_raw}, colls)

    # ---- loop-trip calibration (small unrolled depths, extrapolated) ----
    calib_rec = None
    terms = raw_terms
    if calibrate:
        from repro.models.layers import attn_q_chunk

        mk, d1, d2, L_eff = _depth_plan(cfg)
        # widen chunk sizes during calibration: same FLOPs (chunk-size
        # invariant), negligibly fewer boundary-state bytes, but far fewer
        # unrolled bodies -> tractable compiles for the 32k/500k cells
        calib_scan_chunk = max(cfg.scan_chunk, cell.seq_len // 4)
        calib_q_chunk = max(512, cell.seq_len // 4)
        n_chips_ = 1
        for v in mesh.shape.values():
            n_chips_ *= v

        # ssm/hybrid train/prefill: the unrolled chunk-scan bodies make the
        # backend compile take minutes, so calibrate FLOPs from the cheap
        # unoptimized lowering (exact) and scale bytes/link by the same
        # loop-multiplier (trunk layers are homogeneous -> first-order
        # correct); everything else gets the full compiled 2-point method.
        heavy = cfg.family in ("ssm", "hybrid") and cell.kind in (
            "train", "prefill")
        if heavy:
            pts = {}
            with unroll_scans(), attn_q_chunk(calib_q_chunk):
                for d in (d1, d2):
                    ccfg = dataclasses.replace(
                        mk(d), scan_chunk=calib_scan_chunk)
                    pts[d] = _measure_lowered_flops(ccfg, cell, mesh)
            slope = (pts[d2] - pts[d1]) / (d2 - d1)
            flops_global = max(pts[d2] + (L_eff - d2) * slope, 0.0)
            flops_c = flops_global / n_chips_
            ratio = flops_c / flops_raw if flops_raw else 1.0
            bytes_c = bytes_raw * ratio
            link_c = colls.link_bytes * ratio
            calib_rec = {
                "method": "flops-ratio-scaled",
                "depths": [d1, d2], "L_eff": L_eff,
                "points": {str(d): {"flops_global": pts[d]} for d in pts},
                "loop_multiplier": ratio,
            }
        else:
            pts = {}
            with unroll_scans(), attn_q_chunk(calib_q_chunk):
                for d in (d1, d2):
                    ccfg = dataclasses.replace(
                        mk(d), scan_chunk=calib_scan_chunk)
                    _, fl, by, cl = _measure(ccfg, cell, mesh)
                    pts[d] = (fl, by, cl.link_bytes)

            def extrap(i):
                v1, v2 = pts[d1][i], pts[d2][i]
                slope = (v2 - v1) / (d2 - d1)
                return max(v2 + (L_eff - d2) * slope, 0.0)

            flops_c, bytes_c, link_c = extrap(0), extrap(1), extrap(2)
            calib_rec = {
                "method": "unrolled-2pt",
                "depths": [d1, d2], "L_eff": L_eff,
                "points": {str(d): {"flops": pts[d][0], "bytes": pts[d][1],
                                    "link_bytes": pts[d][2]} for d in pts},
            }
        terms = RooflineTerms(
            flops=flops_c, bytes_accessed=bytes_c, link_bytes=link_c,
            compute_s=flops_c / PEAK_FLOPS,
            memory_s=bytes_c / HBM_BW,
            collective_s=link_c / ICI_BW,
        )

    n_chips = 1
    for v in mesh.shape.values():
        n_chips *= v
    model_flops = model_flops_per_step(cfg, cell)
    hlo_flops_global = terms.flops * n_chips
    record.update(
        status="ok",
        compile_s=round(t_compile, 2),
        memory={
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_live_bytes_est": mem.temp_size_in_bytes
            + mem.argument_size_in_bytes,
        },
        collectives=colls.as_dict(),
        roofline=terms.as_dict(),
        roofline_raw=raw_terms.as_dict(),
        calibration=calib_rec,
        model_flops=model_flops,
        useful_flops_ratio=(
            model_flops / hlo_flops_global if hlo_flops_global else None
        ),
        hlo_bytes=hlo_len,
    )
    if save_hlo:
        with open(os.path.join(out_dir, name + ".hlo.txt"), "w") as f:
            f.write(compiled.as_text())

    os.makedirs(out_dir, exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)
    return record


def main() -> None:
    from repro.configs import ARCHITECTURES, applicable_cells, get_config

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--cell", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--no-calibrate", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        archs = ARCHITECTURES
    else:
        if not args.arch:
            raise SystemExit("--arch or --all required")
        archs = [args.arch]

    failures = []
    for arch in archs:
        cfg = get_config(arch)
        cells = [args.cell] if args.cell else applicable_cells(cfg)
        for cell in cells:
            for mesh_kind in meshes:
                label = f"{arch} x {cell} x {mesh_kind}"
                t0 = time.time()
                try:
                    rec = run_cell(arch, cell, mesh_kind, args.out,
                                   force=args.force, save_hlo=args.save_hlo,
                                   calibrate=not args.no_calibrate)
                    dom = rec.get("roofline", {}).get("dominant", "?")
                    print(f"[dryrun] OK   {label:55s} {time.time()-t0:7.1f}s "
                          f"dominant={dom}", flush=True)
                except Exception as e:  # noqa: BLE001 — record and continue
                    failures.append((label, repr(e)))
                    traceback.print_exc()
                    print(f"[dryrun] FAIL {label:55s} {time.time()-t0:7.1f}s "
                          f"{e!r:.120}", flush=True)
    if failures:
        print(f"\n[dryrun] {len(failures)} FAILURES:")
        for label, err in failures:
            print("  ", label, err[:160])
        raise SystemExit(1)
    print("\n[dryrun] all requested cells compiled OK")


if __name__ == "__main__":
    main()
