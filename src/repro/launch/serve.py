"""Batched serving driver: prefill a prompt batch, decode N tokens.

Exercises the serve path the decode_* dry-run cells lower: prefill emits a
KV cache padded to the decode horizon, then serve_step appends one token at
a time (greedy).

    PYTHONPATH=src python -m repro.launch.serve --preset tiny --batch 4 \
        --prompt-len 32 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.launch.train import PRESETS
from repro.models import get_model


def pad_cache(cache, extra: int):
    """Grow attention caches' sequence axis (axis 2) by ``extra`` slots."""

    def pad(path_key, x):
        if path_key in ("k", "v", "attn_k", "attn_v"):
            return jnp.pad(x, [(0, extra) if i == 2 else (0, 0)
                               for i in range(x.ndim)])
        return x

    return {k: pad(k, v) for k, v in cache.items()}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--arch", default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.arch else PRESETS[args.preset]
    model = get_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init_params(key)
    print(f"[serve] model={cfg.name} family={cfg.family} "
          f"batch={args.batch} prompt={args.prompt_len} gen={args.gen}")

    prompts = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)
    batch = {"tokens": prompts}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.zeros(
            (args.batch, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frames"] = jnp.zeros(
            (args.batch, cfg.n_frames, cfg.d_model), jnp.bfloat16)

    prefill = jax.jit(make_prefill_step(model))
    serve = jax.jit(make_serve_step(model), donate_argnums=(1,))

    t0 = time.time()
    logits, cache = prefill(params, batch)
    if cfg.family in ("dense", "moe", "vlm", "encdec", "hybrid"):
        cache = pad_cache(cache, args.gen)
    tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
    t_prefill = time.time() - t0

    out = [np.asarray(tok)]
    t0 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.int32(args.prompt_len + i)
        tok, _logits, cache = serve(params, cache, tok, pos)
        out.append(np.asarray(tok))
    t_decode = time.time() - t0

    gen = np.concatenate(out, axis=1)
    print(f"[serve] prefill {t_prefill*1e3:.1f} ms; "
          f"decode {t_decode*1e3:.1f} ms "
          f"({args.batch*(args.gen-1)/max(t_decode,1e-9):.0f} tok/s)")
    for b in range(min(args.batch, 2)):
        print(f"[serve] seq{b}: {gen[b][:16].tolist()}...")
    if gen.shape != (args.batch, args.gen):
        raise RuntimeError(f"bad generation shape {gen.shape}")
    if not (np.all(gen >= 0) and np.all(gen < cfg.padded_vocab)):
        raise RuntimeError("generated token ids out of vocab range")
    print("[serve] ok")


if __name__ == "__main__":
    main()
