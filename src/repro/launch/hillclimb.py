import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Perf hillclimb driver (§Perf): re-lower a cell with config variants and
compare calibrated roofline terms against the recorded baseline.

Each variant is a named dict of ModelConfig overrides; results land in
``experiments/hillclimb/<arch>__<cell>__<variant>.json`` and a comparison
table prints at the end.  The hypothesis -> change -> before/after ->
verdict log lives in EXPERIMENTS.md §Perf.

    PYTHONPATH=src python -m repro.launch.hillclimb --target falcon_train
"""

import argparse
import dataclasses
import json


TARGETS = {
    # worst roofline fraction (memory-dominated SSM training)
    "falcon_train": ("falcon-mamba-7b", "train_4k", [
        ("rebaseline", {}),  # current code, no levers (comparability)
        ("fused_proj", dict(mamba_fused_proj=True)),
        ("fused_chunk512", dict(mamba_fused_proj=True, scan_chunk=512)),
        ("fused_chunk1024", dict(mamba_fused_proj=True, scan_chunk=1024)),
        ("chunk512_only", dict(scan_chunk=512)),
        ("fused_mb64", dict(mamba_fused_proj=True, microbatch=64)),
        ("fused_mb64_c512", dict(mamba_fused_proj=True, microbatch=64,
                                 scan_chunk=512)),
        # round 2: scan traffic ~ log2(chunk) (confirmed by chunk512 +11%)
        # -> SHRINK the chunk
        ("chunk64", dict(scan_chunk=64)),
        ("chunk32", dict(scan_chunk=32)),
        ("chunk16", dict(scan_chunk=16)),  # round 3: verify the floor
        ("mb64_c64", dict(microbatch=64, scan_chunk=64)),
    ]),
    # footprint demonstration on a cheap-compile arch: microbatching brings
    # every train cell under the HBM budget (large-scale runnability)
    "qwen3_train": ("qwen3-1.7b", "train_4k", [
        ("rebaseline", {}),
        ("mb64", dict(microbatch=64)),
        ("mb32", dict(microbatch=32)),
    ]),
    # largest absolute cell / representative of burst-absorption at ingest
    # (memory-dominated: attention-score traffic at 32k)
    "grok_prefill": ("grok-1-314b", "prefill_32k", [
        ("rebaseline", {}),  # current code, no levers (comparability)
        ("bf16_softmax", dict(softmax_dtype="bfloat16")),
        ("fp8_gather", dict(matmul_weight_dtype="float8_e4m3fn")),
        ("bf16smax_fp8", dict(softmax_dtype="bfloat16",
                              matmul_weight_dtype="float8_e4m3fn")),
        ("onehot_embed", dict(embed_onehot=True)),
        # round 2: the memory elephant is the f32 one-hot dispatch/combine
        # (T x E x C x 4B = 168 GB/layer/device at g=256)
        ("moe_g64", dict(moe_group_size=64)),
        ("moe_g64_bf16d", dict(moe_group_size=64,
                               moe_dispatch_dtype="bfloat16")),
        ("bf16d_only", dict(moe_dispatch_dtype="bfloat16")),
    ]),
    # most collective-bound cell: serving a 314B MoE re-gathers every FSDP
    # weight shard per token — replicate the (tiny) activation batch over
    # the data axis instead, so contracting-dim sharded matmuls psum small
    # activations rather than gathering huge weights
    "grok_decode": ("grok-1-314b", "decode_32k", [
        ("rebaseline", {}),  # current code, no levers (comparability)
        ("replicate_act", dict(shard_rules_override=(("batch", ()),))),
        ("fp8_weights", dict(matmul_weight_dtype="float8_e4m3fn")),
        ("replicate_fp8", dict(shard_rules_override=(("batch", ()),),
                               matmul_weight_dtype="float8_e4m3fn")),
        ("onehot_replicate", dict(embed_onehot=True,
                                  shard_rules_override=(("batch", ()),))),
        # round 2: matmul-time casts get hoisted past the gather (refuted
        # above) -> store the weights in fp8 so the collective moves fp8
        ("fp8_storage", dict(param_dtype="float8_e4m3fn",
                             matmul_weight_dtype="bfloat16")),
    ]),
}


def run_target(name: str, mesh: str = "single",
               out_dir: str = "experiments/hillclimb") -> None:
    from repro.configs import get_config
    from repro.launch.dryrun import run_cell

    arch, cell, variants = TARGETS[name]
    os.makedirs(out_dir, exist_ok=True)

    base_path = f"experiments/dryrun/{arch}__{cell}__{mesh}.json"
    with open(base_path) as f:
        base = json.load(f)
    rows = [("baseline", base)]

    cfg0 = get_config(arch)
    for vname, overrides in variants:
        cfg = dataclasses.replace(cfg0, **overrides)
        rec = run_cell(arch, cell, mesh, out_dir, force=False,
                       override_cfg=cfg, tag=f"__{vname}")
        rows.append((vname, rec))

    print(f"\n==== hillclimb {name}: {arch} x {cell} x {mesh} ====")
    print(f"{'variant':18s} {'compute_ms':>10s} {'memory_ms':>10s} "
          f"{'coll_ms':>9s} {'step_ms':>9s} {'dom':>10s} {'temp GiB':>9s}")
    b = rows[0][1]["roofline"]
    for vname, rec in rows:
        t = rec["roofline"]
        mem = rec["memory"]["temp_bytes"] / 2**30
        delta = ""
        if vname != "baseline":
            dom0 = b["dominant"]
            key = f"{dom0}_s"
            delta = f"  ({(t[key]/b[key]-1)*100:+.1f}% on {dom0})"
        print(f"{vname:18s} {t['compute_s']*1e3:10.2f} "
              f"{t['memory_s']*1e3:10.2f} {t['collective_s']*1e3:9.2f} "
              f"{t['step_time_s']*1e3:9.2f} {t['dominant']:>10s} "
              f"{mem:9.1f}{delta}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--target", choices=sorted(TARGETS) + ["all"],
                    default="all")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    names = sorted(TARGETS) if args.target == "all" else [args.target]
    for n in names:
        run_target(n, args.mesh)


if __name__ == "__main__":
    main()
