"""Roofline-term derivation from compiled SPMD artifacts (deliverable g).

Three terms per (arch x shape x mesh) cell, all per-device (verified:
``compiled.cost_analysis()['flops']`` is per-device on this jax version —
probe in DESIGN.md §6):

    compute_term    = flops / PEAK_FLOPS
    memory_term     = bytes_accessed / HBM_BW
    collective_term = sum(link_bytes per collective) / ICI_BW

collective bytes are parsed from the post-SPMD HLO text: for every
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute
op we take the output tuple's byte size and weight it with the standard
ring-algorithm factor over the parsed replica-group size n:

    all-reduce      2 (n-1)/n        all-gather / reduce-scatter  (n-1)/n
    all-to-all      (n-1)/n          collective-permute           1

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\w+\[[\d,]*\][^\s]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_SOURCE_TARGET_RE = re.compile(r"source_target_pairs=")

_FACTORS = {
    "all-reduce": lambda n: 2.0 * (n - 1) / n,
    "all-gather": lambda n: (n - 1) / n,
    "reduce-scatter": lambda n: (n - 1) / n,
    "all-to-all": lambda n: (n - 1) / n,
    "collective-permute": lambda n: 1.0,
}


def _shape_bytes(text: str) -> int:
    """Byte size of 'bf16[16,64]' or a '(t1, t2, ...)' tuple thereof."""

    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # iota format [num_groups, group_size]<=[N]
        return int(m.group(2))
    return 2  # conservative default (pairwise)


@dataclasses.dataclass
class CollectiveStats:
    counts: dict
    bytes_by_kind: dict
    link_bytes: float  # factor-weighted bytes over the wire (per device)

    def as_dict(self):
        return {
            "counts": self.counts,
            "bytes_by_kind": self.bytes_by_kind,
            "link_bytes": self.link_bytes,
        }


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: dict[str, int] = {}
    raw: dict[str, float] = {}
    link = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None:
            continue
        shape_txt, kind, _start = m.group(1), m.group(2), m.group(3)
        nbytes = _shape_bytes(shape_txt)
        n = _group_size(line)
        if kind == "collective-permute":
            n = 2
        counts[kind] = counts.get(kind, 0) + 1
        raw[kind] = raw.get(kind, 0.0) + nbytes
        link += _FACTORS[kind](max(n, 2)) * nbytes
    return CollectiveStats(counts=counts, bytes_by_kind=raw, link_bytes=link)


@dataclasses.dataclass
class RooflineTerms:
    flops: float
    bytes_accessed: float
    link_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step-time estimate (no overlap: max of the terms)."""

        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self):
        return {
            "flops_per_device": self.flops,
            "bytes_per_device": self.bytes_accessed,
            "link_bytes_per_device": self.link_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "step_time_s": self.step_time_s,
        }


def derive_terms(cost_analysis: dict, collectives: CollectiveStats) -> RooflineTerms:
    flops = float(cost_analysis.get("flops", 0.0))
    bytes_acc = float(cost_analysis.get("bytes accessed", 0.0))
    link = collectives.link_bytes
    return RooflineTerms(
        flops=flops,
        bytes_accessed=bytes_acc,
        link_bytes=link,
        compute_s=flops / PEAK_FLOPS,
        memory_s=bytes_acc / HBM_BW,
        collective_s=link / ICI_BW,
    )


def model_flops_per_step(cfg, cell) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE) per optimizer step; for serve
    cells D = global_batch tokens (one token per sequence), forward-only
    (2*N*D)."""

    n_active = cfg.active_param_count()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n_active * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * cell.global_batch
