"""End-to-end training driver (deliverable b).

Composes every substrate: model zoo (``--arch`` or a size ``--preset``),
sharded synthetic data, AdamW, optional gradient compression, heartbeat /
straggler bookkeeping, and SSDUP+ burst-buffered async checkpointing with
restart (``--resume`` picks up the newest committed manifest).

CPU-sized presets so the driver actually trains in this container:

    tiny   ~7M params   (a few hundred steps in minutes)   [default]
    20m    ~21M params
    100m   ~101M params (the assignment's reference size; a few steps/min
                         on one CPU core — see EXPERIMENTS.md §Driver)

Example:
    PYTHONPATH=src python -m repro.launch.train --preset tiny --steps 200 \
        --ckpt-dir /tmp/ckpt --ckpt-every 50
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import Checkpointer, TieredCheckpointStore
from repro.configs import get_config, get_smoke_config
from repro.configs.base import ModelConfig
from repro.data import DataConfig, ShardedLoader
from repro.distributed.fault_tolerance import HeartbeatTable
from repro.launch.steps import make_train_step
from repro.models import get_model
from repro.optim import AdamWConfig, CompressionConfig, init_state, linear_warmup_cosine

PRESETS: dict[str, ModelConfig] = {
    "tiny": ModelConfig(
        name="tiny", family="dense", n_layers=4, d_model=256, n_heads=4,
        n_kv_heads=2, d_ff=1024, vocab_size=8192, head_dim=64,
        dtype="float32", remat="none"),
    "20m": ModelConfig(
        name="20m", family="dense", n_layers=6, d_model=384, n_heads=6,
        n_kv_heads=2, d_ff=1536, vocab_size=16384, head_dim=64,
        dtype="float32", remat="none"),
    "100m": ModelConfig(
        name="100m", family="dense", n_layers=12, d_model=512, n_heads=8,
        n_kv_heads=8, d_ff=2048, vocab_size=49152, head_dim=64,
        dtype="float32", remat="none"),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--arch", default=None,
                    help="assigned-arch smoke config instead of a preset")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.arch else PRESETS[args.preset]
    model = get_model(cfg)
    print(f"[train] model={cfg.name} params~{cfg.param_count()/1e6:.1f}M "
          f"family={cfg.family}")

    key = jax.random.PRNGKey(args.seed)
    params = model.init_params(key)
    opt_cfg = AdamWConfig(lr=args.lr,
                          schedule=linear_warmup_cosine(args.warmup, args.steps))
    opt_state = init_state(params)
    comp = CompressionConfig(enabled=args.compress_grads)
    step_fn = jax.jit(make_train_step(model, opt_cfg, comp), donate_argnums=(0, 1))

    data = ShardedLoader(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                   global_batch=args.batch, seed=args.seed), host_id=0)

    ckpt = None
    start_step = 0
    if args.ckpt_dir:
        store = TieredCheckpointStore(args.ckpt_dir, host_id=0)
        ckpt = Checkpointer(store)
        if args.resume:
            restored = ckpt.restore_latest(
                like={"params": jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)})
            if restored is not None:
                start_step, tree = restored
                params = jax.tree.map(
                    lambda p, v: jax.numpy.asarray(v, p.dtype),
                    params, tree["params"])
                print(f"[train] resumed from step {start_step}")

    hb = HeartbeatTable(timeout=60.0, clock=time.monotonic)
    hb.register(0)

    losses = []
    t_start = time.time()
    for step in range(start_step, args.steps):
        batch = {k: jax.numpy.asarray(v) for k, v in data.get(step).items()}
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        hb.heartbeat(0, dt)
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            tok_s = args.batch * args.seq / dt
            print(f"[train] step {step:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):7.3f} "
                  f"{dt*1e3:7.1f} ms/step {tok_s:9.0f} tok/s", flush=True)
        if ckpt and args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            ckpt.save_async(step + 1, {"params": params})

    if ckpt:
        ckpt.save_blocking(args.steps, {"params": params})
        stats = ckpt.store  # noqa: F841  (manifest committed)
        ckpt.close()
        print(f"[train] checkpoints committed under {args.ckpt_dir} "
              f"(async saves: {ckpt.saves_completed})")

    wall = time.time() - t_start
    print(f"[train] done: {args.steps - start_step} steps in {wall:.1f}s; "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    if len(losses) > 20:
        if np.mean(losses[-10:]) >= np.mean(losses[:10]):
            raise RuntimeError("no learning: loss did not decrease")


if __name__ == "__main__":
    main()
