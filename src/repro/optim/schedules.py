"""LR schedules (pure functions of the step, jit-safe)."""

from __future__ import annotations

import jax.numpy as jnp


def constant():
    return lambda step: jnp.float32(1.0)


def linear_warmup_cosine(warmup_steps: int, total_steps: int,
                         final_frac: float = 0.1):
    """Warmup to 1.0 then cosine to ``final_frac``."""

    def fn(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(s / max(warmup_steps, 1), 1.0)
        prog = jnp.clip(
            (s - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return warm * cos

    return fn


def inverse_sqrt(warmup_steps: int):
    def fn(step):
        s = jnp.maximum(step.astype(jnp.float32), 1.0)
        return jnp.minimum(s / max(warmup_steps, 1), jnp.sqrt(warmup_steps / s))

    return fn
