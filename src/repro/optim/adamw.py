"""AdamW with bf16 params + f32 moments, global-norm clipping, and optional
gradient compression hooks (see compression.py).

State layout mirrors the param tree: {"m": tree_f32, "v": tree_f32,
"step": scalar}.  Moments inherit each param's sharding (FSDP x TP), so the
optimizer adds 8 bytes/param spread over the whole mesh — the ZeRO-3
arithmetic quoted in DESIGN.md §5.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Tree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: Callable[[jax.Array], jax.Array] | None = None  # step -> scale


def init_state(params: Tree) -> Tree:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros, "v": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def abstract_state(abstract_params: Tree) -> Tree:
    zeros = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), abstract_params)
    return {"m": zeros, "v": zeros,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def state_axes(param_axes: Tree) -> Tree:
    """Moments share the params' logical axes; step is replicated."""

    return {"m": param_axes, "v": param_axes, "step": ()}


def global_norm(tree: Tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads: Tree, max_norm: float) -> tuple[Tree, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def apply_updates(cfg: AdamWConfig, params: Tree, grads: Tree, state: Tree):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""

    grads32, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = cfg.lr * (cfg.schedule(step) if cfg.schedule is not None else 1.0)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads32, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
