"""Optimizer substrate: AdamW, schedules, gradient compression."""

from repro.optim.adamw import (
    AdamWConfig,
    abstract_state,
    apply_updates,
    clip_by_global_norm,
    global_norm,
    init_state,
    state_axes,
)
from repro.optim.compression import (
    CompressionConfig,
    compress_tree,
    decode,
    encode,
    init_error,
)
from repro.optim.schedules import constant, inverse_sqrt, linear_warmup_cosine

__all__ = [
    "AdamWConfig",
    "init_state",
    "abstract_state",
    "state_axes",
    "apply_updates",
    "global_norm",
    "clip_by_global_norm",
    "CompressionConfig",
    "compress_tree",
    "encode",
    "decode",
    "init_error",
    "constant",
    "inverse_sqrt",
    "linear_warmup_cosine",
]
