"""Gradient compression for cross-pod all-reduce (distributed-optimization).

At multi-pod scale the inter-pod (DCN) gradient all-reduce dominates; we
compress with int8 + per-row scales + error feedback (1-bit-Adam style error
accumulation keeps convergence).  The compressor is a pure function pair so
it can wrap any collective:

    compressed, scales = encode(grad + error)
    error = (grad + error) - decode(compressed, scales)
    all_reduce(compressed-as-f32-mean)   # inside jit, via psum/mean

Inside a jit'd SPMD program we cannot literally transmit int8 across a named
axis with psum (XLA would upcast), so the framework applies this at the
*grad-sync boundary*: quantize -> dequantize -> psum.  The quantization
noise then models the real bandwidth saving faithfully while keeping the
program SPMD; on real DCN deployments the same encode/decode pair wraps a
jax.experimental.multihost_utils transfer.  EXPERIMENTS.md quantifies the
convergence effect; tests check encode/decode round-trip error bounds and
error-feedback convergence.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Tree = Any


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    enabled: bool = False
    bits: int = 8  # int8 rows
    error_feedback: bool = True


def _rowwise(x: jax.Array) -> jax.Array:
    """View as (rows, cols) for per-row scaling."""

    if x.ndim <= 1:
        return x.reshape(1, -1)
    return x.reshape(x.shape[0], -1)


def encode(x: jax.Array, bits: int = 8) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-row int quantization.  Returns (q, scales)."""

    if bits not in (4, 8):
        raise ValueError(f"bits must be 4 or 8, got {bits}")
    qmax = (1 << (bits - 1)) - 1
    rows = _rowwise(x.astype(jnp.float32))
    scales = jnp.max(jnp.abs(rows), axis=1, keepdims=True) / qmax
    scales = jnp.maximum(scales, 1e-12)
    q = jnp.clip(jnp.round(rows / scales), -qmax, qmax).astype(jnp.int8)
    return q.reshape(x.shape), scales.squeeze(1)


def decode(q: jax.Array, scales: jax.Array) -> jax.Array:
    rows = _rowwise(q.astype(jnp.float32))
    return (rows * scales[:, None]).reshape(q.shape)


def compress_tree(grads: Tree, error: Tree | None, cfg: CompressionConfig):
    """Quantize-dequantize each leaf with error feedback.

    Returns (grads_for_allreduce, new_error).  With cfg.enabled=False this
    is the identity (and error stays zero), so the train step has a single
    code path.
    """

    if not cfg.enabled:
        return grads, error

    def one(g, e):
        g32 = g.astype(jnp.float32)
        if cfg.error_feedback and e is not None:
            g32 = g32 + e
        q, s = encode(g32, cfg.bits)
        deq = decode(q, s)
        new_e = (g32 - deq) if cfg.error_feedback else jnp.zeros_like(g32)
        return deq, new_e

    if error is None:
        error = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    out = jax.tree.map(one, grads, error)
    deq = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return deq, new_err


def init_error(params: Tree) -> Tree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
