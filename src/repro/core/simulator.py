"""Event-level I/O-node simulator (reproduces the paper's evaluation).

Replays a request trace against one I/O node under four schemes:

* ``orangefs``     — no buffer; every stream goes to the HDD (CFQ-sorted).
* ``orangefs-bb``  — plain burst buffer: ALL data to the SSD; when the SSD is
                     full, incoming data goes straight to HDD while the SSD
                     flushes (the paper's OrangeFS-BB).
* ``ssdup``        — SSDUP (ICS'17): static watermark thresholds (45/30),
                     two-region pipeline, IMMEDIATE flushing.
* ``ssdup+``       — SSDUP+: adaptive threshold + traffic-aware flushing.

Timing model:

* Every foreground stream is bounded by BOTH the network ingest link
  (GbE ≈ 110 MB/s per node on the paper's testbed) and the device:
  ``wall = max(net_time, device_time)``.
* HDD device time = CFQ-sorted seeks × seek_time + sweep distance × coeff
  + bytes / seq_bw  (see ``device_model`` calibration notes).
* Flushes are charged per the paper's Eq. 6: a flush job of ``bytes``
  with ``seeks`` residual (post-sort) head movements drains in
  ``seeks × seek_time + bytes / seq_bw`` of exclusive HDD time — the
  seek cost is amortized into :meth:`FlushJob.effective_rate` so EVERY
  drain path pays it: foreground-overlapped flushing, the
  interference-shared path, compute gaps, the blocked-writer drain, and
  the end-of-trace drain.
* The background flusher shares the HDD with foreground HDD writes through
  :class:`InterferenceModel` (fair share + inflation phi, paper Eq. 7); it
  runs at the job's effective rate while the foreground is on the SSD or
  during compute gaps.
* A ``Gap`` item models a compute phase (paper Fig. 14): only the flusher
  runs, continuing through the flush backlog until the gap budget or the
  backlog is exhausted.

Two replay engines produce bit-identical :class:`SimResult`\\ s:

* ``engine="batched"`` (default) — routes and accounts WHOLE streams
  against precomputed :class:`repro.core.trace.StreamScores`; SSD-bound
  streams are appended via :meth:`LogRegion.append_batch` and timed in
  vectorized runs that only drop to Python at state boundaries (region
  swap, writer block, flush-job completion).  No per-request Python in
  the hot path.
* ``engine="per-request"`` — the seed's request-at-a-time loop, kept as
  the oracle (``tests/test_batched_replay.py`` asserts equality).

Vectorized accounting preserves bit-exactness by construction: per-request
walls are elementwise IEEE ops, clock accumulation uses the strictly
sequential ``np.add.accumulate`` (not pairwise ``np.sum``), and flush
quanta truncate per request exactly like the scalar ``int(rate * wall)``.

Accounting matches the paper's measurements: reported throughput uses the
**application-visible I/O time** (``io_seconds``: last foreground byte
absorbed, compute gaps excluded); the final background drain is tracked
separately in ``total_seconds`` (the paper's burst buffer likewise hides the
final flush in the next compute phase).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Sequence

import numpy as np

from ..analysis import sanitize as _sanitize
from .adaptive import AdaptiveThreshold, StaticWatermarkThreshold
from .device_model import (
    HDDModel,
    IngestLink,
    InterferenceModel,
    SSDModel,
    StorageModel,
    clone_storage,
    make_storage_model,
)
from .log_store import LogRegion
from .pipeline import SingleRegionBuffer, TwoRegionPipeline
from .random_factor import (
    DEFAULT_STREAM_LEN,
    Request,
    StreamGrouper,
    random_factor_sum,
    seek_distance_np,
    sorted_seek_distance,
    stream_percentage,
    stream_stats_batch_np,
)
from .redirector import DataRedirector, Device
from .trace import (
    Gap,
    StreamScores,
    TraceBatch,
    TraceItem,
    compute_stream_scores,
)

ENGINES = ("batched", "per-request", "device")


def _seq_add(start: float, values: np.ndarray) -> float:
    """Left-to-right float accumulation — bit-identical to looping
    ``start += v`` (``np.add.accumulate`` is strictly sequential, unlike
    ``np.sum``'s pairwise reduction)."""

    n = len(values)
    if n == 0:
        return start
    arr = np.empty(n + 1, dtype=np.float64)
    arr[0] = start
    arr[1:] = values
    return float(np.add.accumulate(arr)[-1])


@dataclasses.dataclass
class SimResult:
    scheme: str
    io_seconds: float  # application-visible I/O time (gaps excluded)
    total_seconds: float  # includes compute gaps and the final drain
    total_bytes: int
    bytes_to_ssd: int
    bytes_to_hdd_direct: int
    flushes: int
    flush_paused_seconds: float
    blocked_seconds: float
    peak_ssd_occupancy: int
    metadata_bytes: int
    per_app_bytes: dict[int, int]

    @property
    def throughput_mbs(self) -> float:
        return self.total_bytes / self.io_seconds / 1e6 if self.io_seconds else 0.0

    @property
    def ssd_byte_ratio(self) -> float:
        return self.bytes_to_ssd / self.total_bytes if self.total_bytes else 0.0

    def app_throughput_mbs(self, app_id: int) -> float:
        if not self.io_seconds:  # gap-only / empty traces: no I/O time
            return 0.0
        return self.per_app_bytes.get(app_id, 0) / self.io_seconds / 1e6


@dataclasses.dataclass
class _ReplayState:
    """Mutable per-run accounting shared by both engines."""

    clock: float = 0.0
    gap_seconds: float = 0.0
    bytes_ssd: int = 0
    bytes_hdd: int = 0
    blocked_seconds: float = 0.0
    peak_ssd: int = 0
    per_app: dict[int, int] = dataclasses.field(default_factory=dict)


class IONodeSimulator:
    """One I/O node running one of the four schemes."""

    def __init__(
        self,
        scheme: str = "ssdup+",
        ssd_capacity: int = 8 << 30,
        hdd: HDDModel | None = None,
        ssd: StorageModel | str | None = None,
        link: IngestLink | None = None,
        interference: InterferenceModel | None = None,
        stream_len: int = DEFAULT_STREAM_LEN,
        flush_gate: float | str = 0.5,
        adaptive_window: int | None = 64,
        index_backend: str = "numpy",
        engine: str = "batched",
        threshold_warmup: Sequence[float] | None = None,
        sanitize: bool | None = None,
    ):
        if scheme not in ("orangefs", "orangefs-bb", "ssdup", "ssdup+"):
            raise ValueError(f"unknown scheme {scheme}")
        if engine not in ENGINES:
            raise ValueError(f"engine must be one of {ENGINES}, got {engine!r}")
        if threshold_warmup is not None and scheme not in ("ssdup", "ssdup+"):
            raise ValueError(
                "threshold_warmup requires a threshold scheme "
                f"(ssdup/ssdup+), got {scheme!r}"
            )
        if isinstance(flush_gate, str) and flush_gate != "device":
            raise ValueError(
                f"flush_gate must be a float or 'device', got {flush_gate!r}"
            )
        self.scheme = scheme
        self.engine = engine
        # runtime invariant checks: True/False pins the instance, None
        # defers to REPRO_SANITIZE / the sanitizing() override
        self.sanitize = _sanitize.resolve(sanitize)
        self.hdd = hdd or HDDModel()
        # pluggable storage backend: "constant" (stateless, the default)
        # or "ftl" (page-mapped, GC + write amplification) or an instance
        self.ssd = make_storage_model(ssd, logical_bytes=ssd_capacity)
        self.ssd_stateful = bool(getattr(self.ssd, "stateful", False))
        # stateful models cap the flusher's SSD-read side and receive
        # trim() calls; None keeps the constant path bit-exact
        self._flush_storage: StorageModel | None = (
            self.ssd if self.ssd_stateful else None
        )
        self._fg_ssd = False  # foreground device of the running stream
        self.link = link or IngestLink()
        self.interference = interference or InterferenceModel()
        self.stream_len = stream_len
        self.ssd_capacity = ssd_capacity
        # kept for the device engine, which rebuilds its lane state from
        # these instead of the host pipeline/redirector objects below
        self.flush_gate = flush_gate
        self.adaptive_window = adaptive_window
        self.threshold_warmup = (
            None if threshold_warmup is None else list(threshold_warmup)
        )

        self._last_pct = 0.0
        self._session: _ReplayState | None = None
        if scheme == "ssdup+":
            policy = AdaptiveThreshold(window=adaptive_window)
            self.pipeline = TwoRegionPipeline(
                ssd_capacity // 2, traffic_aware=True, flush_gate=flush_gate,
                percentage_source=lambda: self._last_pct,
                index_backend=index_backend,
                storage=self._flush_storage,
                fg_ssd_source=lambda: self._fg_ssd,
            )
            self.redirector: DataRedirector | None = DataRedirector(policy, stream_len)
        elif scheme == "ssdup":
            policy = StaticWatermarkThreshold()
            self.pipeline = TwoRegionPipeline(
                ssd_capacity // 2, traffic_aware=False,
                percentage_source=lambda: self._last_pct,
                index_backend=index_backend,
                storage=self._flush_storage,
            )
            self.redirector = DataRedirector(policy, stream_len)
        elif scheme == "orangefs-bb":
            self.pipeline = SingleRegionBuffer(
                ssd_capacity,
                percentage_source=lambda: self._last_pct,
                index_backend=index_backend,
                storage=self._flush_storage,
            )
            self.redirector = None
        else:  # orangefs
            self.pipeline = None  # type: ignore[assignment]
            self.redirector = None

        if threshold_warmup is not None and self.redirector is not None:
            # warm detector history (e.g. fleet-scope PercentList) — seeded
            # before replay so the first stream already sees an adapted
            # threshold instead of the cold default
            self.redirector.policy.seed(threshold_warmup)

    # -- shared timing primitives (both engines) -----------------------
    def _advance_fg(
        self, st: _ReplayState, device_dt: float, nbytes: int,
        hdd_foreground: bool,
    ) -> None:
        """One foreground operation: device time ``device_dt`` alone,
        network-capped, with the background flush sharing the HDD."""

        self._fg_ssd = not hdd_foreground  # flush-gate v2 device signal
        flushing = (
            self.pipeline is not None and self.pipeline.flush_job is not None
        )
        allowed = flushing and self.pipeline.flush_allowed()
        net_dt = self.link.time(nbytes)
        if not flushing or not allowed:
            wall = max(net_dt, device_dt)
            if flushing:
                self.pipeline.note_pause(wall)
            st.clock += wall
            return
        job = self.pipeline.flush_job
        if hdd_foreground:
            disk_dt = device_dt * self.interference.foreground_slowdown()
            wall = max(net_dt, disk_dt)
            rate = (
                job.effective_rate(self.hdd, self._flush_storage)
                * self.interference.flush_rate_fraction()
            )
        else:
            wall = max(net_dt, device_dt)
            rate = job.effective_rate(self.hdd, self._flush_storage)
        self.pipeline.flush_progress(int(rate * wall))
        st.clock += wall

    def _drain_current_flush(self, st: _ReplayState) -> float:
        """Block the writer until the active flush finishes (Eq. 6 rate)."""

        if self.pipeline is None or self.pipeline.flush_job is None:
            raise RuntimeError("no active flush job to drain")
        self.pipeline.force_flush()
        job = self.pipeline.flush_job
        dt = job.bytes_left / job.effective_rate(self.hdd, self._flush_storage)
        self.pipeline.flush_progress(job.bytes_left)
        st.clock += dt
        return dt

    def _gap(self, st: _ReplayState, seconds: float) -> None:
        """Compute phase: the flusher gets the HDD to itself and keeps
        draining through the backlog until the gap budget runs out."""

        if self.sanitize:
            _sanitize.check(
                seconds >= 0.0 and np.isfinite(seconds),
                "compute gap must be a finite non-negative duration, got %r",
                seconds,
            )
        if self.pipeline is not None:
            budget = seconds
            while budget > 0 and self.pipeline.flush_job is not None:
                job = self.pipeline.flush_job
                rate = job.effective_rate(self.hdd, self._flush_storage)
                need = job.bytes_left / rate
                if need <= budget:
                    self.pipeline.flush_progress(job.bytes_left)
                    budget -= need
                else:
                    self.pipeline.flush_progress(int(rate * budget))
                    break
        st.clock += seconds
        st.gap_seconds += seconds

    def _finalize(self, st: _ReplayState, drain: bool = True) -> SimResult:
        io_seconds = st.clock - st.gap_seconds  # application-visible I/O time

        # -- drain: flush whatever is still buffered (overlaps the NEXT
        #    compute phase in a real deployment; excluded from io_seconds).
        #    ``drain=False`` models a crashed node: buffered bytes stay in
        #    the pipeline for the caller to salvage (or count as stranded).
        if drain and self.pipeline is not None:
            self.pipeline.drain()
            while self.pipeline.flush_job is not None:
                job = self.pipeline.flush_job
                st.clock += job.bytes_left / job.effective_rate(
                    self.hdd, self._flush_storage
                )
                self.pipeline.flush_progress(job.bytes_left)

        total_bytes = st.bytes_ssd + st.bytes_hdd
        if self.sanitize:
            self._sanitize_final(st, io_seconds, drain)
        return SimResult(
            scheme=self.scheme,
            io_seconds=io_seconds,
            total_seconds=st.clock,
            total_bytes=total_bytes,
            bytes_to_ssd=st.bytes_ssd,
            bytes_to_hdd_direct=st.bytes_hdd,
            flushes=self.pipeline.flushes_completed if self.pipeline else 0,
            flush_paused_seconds=(
                self.pipeline.total_paused_seconds if self.pipeline else 0.0
            ),
            blocked_seconds=st.blocked_seconds,
            peak_ssd_occupancy=st.peak_ssd,
            metadata_bytes=self.pipeline.metadata_bytes if self.pipeline else 0,
            per_app_bytes=st.per_app,
        )

    def _sanitize_final(
        self, st: _ReplayState, io_seconds: float, drained: bool
    ) -> None:
        """End-of-replay invariants (sanitize mode): finite monotone
        clocks, non-negative byte ledgers that close against the per-app
        split, and — after a drain — an empty pipeline."""

        _sanitize.check(
            np.isfinite(st.clock) and st.clock >= 0.0,
            "total_seconds non-finite or negative: %r", st.clock,
        )
        _sanitize.check(
            np.isfinite(io_seconds) and 0.0 <= io_seconds <= st.clock,
            "io_seconds %r outside [0, total_seconds=%r]",
            io_seconds, st.clock,
        )
        _sanitize.check(
            st.bytes_ssd >= 0 and st.bytes_hdd >= 0,
            "negative byte ledger (ssd=%d, hdd=%d)",
            st.bytes_ssd, st.bytes_hdd,
        )
        total = st.bytes_ssd + st.bytes_hdd
        per_app = sum(st.per_app.values())
        _sanitize.check(
            total == per_app,
            "byte ledger does not close: ssd+hdd=%d but per-app sum=%d",
            total, per_app,
        )
        if self.pipeline is not None:
            _sanitize.check(
                self.pipeline.total_flushed_bytes <= st.bytes_ssd,
                "flushed %d B from an SSD that only absorbed %d B",
                self.pipeline.total_flushed_bytes, st.bytes_ssd,
            )
            if drained:
                _sanitize.check(
                    self.pipeline.flush_job is None,
                    "drain left an active flush job",
                )
                left = sum(r.used_bytes for r in self.pipeline.regions)
                _sanitize.check(
                    left == 0, "drain left %d B buffered on the SSD", left
                )
        if self.ssd_stateful:
            check_fn = getattr(self.ssd, "sanitize_check", None)
            if check_fn is not None:
                check_fn()  # FTL page/byte conservation ledgers

    # -- online session API (consumed by repro.service) -----------------
    #
    # The offline engines replay a COMPLETE trace; the service layer
    # instead streams scored windows into the simulator as clients
    # arrive.  A session is the exact same state machine as
    # ``_run_batched`` — same _ReplayState, same _replay_stream, same
    # scoring math — just driven one window at a time, so a no-fault
    # session replaying the same windows in the same order produces a
    # bit-identical SimResult (asserted in tests/test_service.py).

    def begin_session(self) -> None:
        """Start an incremental replay (requires ``engine="batched"``)."""

        if self.engine != "batched":
            raise ValueError(
                f"sessions require engine='batched', got {self.engine!r}"
            )
        if self._session is not None:
            raise RuntimeError("session already open; call end_session first")
        self._session = _ReplayState()

    @property
    def session(self) -> _ReplayState:
        if self._session is None:
            raise RuntimeError("no open session; call begin_session first")
        return self._session

    def feed_window(
        self,
        offsets: np.ndarray,
        sizes: np.ndarray,
        file_ids: np.ndarray,
        app_ids: np.ndarray,
        *,
        force_hdd: bool = False,
    ) -> float:
        """Score and replay one request window; returns the service time
        (clock delta) it consumed.

        The window is scored with the same numpy oracle call the offline
        engine uses (full windows and the <``stream_len`` trailing
        partial alike), so session replay stays bit-exact.  ``force_hdd``
        is admission control's redirect-to-HDD: the detector still sees
        the stream, but its bytes bypass the burst buffer.
        """

        st = self.session
        if len(sizes) == 0:
            return 0.0
        if len(sizes) > self.stream_len:
            raise ValueError(
                f"window of {len(sizes)} requests exceeds "
                f"stream_len={self.stream_len}"
            )
        offsets = np.asarray(offsets, dtype=np.int64)
        sizes = np.asarray(sizes, dtype=np.int64)
        file_ids = np.asarray(file_ids, dtype=np.int64)
        rf, pct, dist = stream_stats_batch_np(offsets[None, :], sizes[None, :])
        nbytes = int(sizes.sum())
        apps, inverse = np.unique(np.asarray(app_ids), return_inverse=True)
        sums = np.zeros(len(apps), dtype=np.int64)
        np.add.at(sums, inverse, sizes)
        for a_id, a_sum in zip(apps, sums):
            st.per_app[int(a_id)] = st.per_app.get(int(a_id), 0) + int(a_sum)
        t0 = st.clock
        self._replay_stream(
            st, offsets, sizes, file_ids,
            nbytes=nbytes,
            pct=float(pct[0]),
            seeks=int(rf[0]),
            dist=int(dist[0]),
            force_hdd=force_hdd,
        )
        return st.clock - t0

    def feed_gap(self, seconds: float) -> float:
        """Replay a compute gap (flusher-only time); returns the delta."""

        st = self.session
        t0 = st.clock
        self._gap(st, float(seconds))
        return st.clock - t0

    def end_session(self, drain: bool = True) -> SimResult:
        """Close the session and return its :class:`SimResult`.

        ``drain=False`` models a crashed node: the final background
        flush never happens, so buffered-but-unflushed bytes stay in
        ``self.pipeline`` for the failover path to enumerate (replay on
        a takeover node, or account as stranded data loss).
        """

        st = self.session
        self._session = None
        return self._finalize(st, drain=drain)

    # ------------------------------------------------------------------
    def run(
        self,
        trace: TraceBatch | Sequence[TraceItem],
        scores: StreamScores | None = None,
    ) -> SimResult:
        """Replay ``trace``; ``scores`` (from
        :func:`repro.core.trace.compute_stream_scores`, same ``stream_len``)
        supplies every stream's random percentage / seek count / seek
        distance so the hot loop never re-sorts a stream on the host.  The
        batched engine computes them itself when omitted.

        Accuracy contract: ``engine="batched"`` is bit-identical to the
        ``engine="per-request"`` oracle; ``engine="device"`` matches the
        oracle to the ``DEVICE_TOLERANCES`` tiers."""

        if scores is not None and scores.stream_len != self.stream_len:
            raise ValueError(
                f"scores computed for stream_len={scores.stream_len}, "
                f"simulator uses {self.stream_len}"
            )
        if self.engine in ("batched", "device"):
            batch = (
                trace if isinstance(trace, TraceBatch)
                else TraceBatch.from_items(trace)
            )
            if scores is None:
                scores = compute_stream_scores(batch, self.stream_len)
            if self.sanitize:
                batch.validate()
                scores.validate()
            if self.engine == "device":
                from . import engine_device  # deferred: needs jax

                return engine_device.simulate_device(
                    batch,
                    scores,
                    sanitize=self.sanitize,
                    scheme=self.scheme,
                    ssd_capacity=self.ssd_capacity,
                    hdd=self.hdd,
                    ssd=self.ssd,
                    link=self.link,
                    interference=self.interference,
                    stream_len=self.stream_len,
                    flush_gate=self.flush_gate,
                    adaptive_window=self.adaptive_window,
                    threshold_warmup=self.threshold_warmup,
                )
            return self._run_batched(batch, scores)
        items = trace.to_items() if isinstance(trace, TraceBatch) else trace
        return self._run_scalar(items, scores)

    # -- per-request engine (the oracle) -------------------------------
    def _hdd_stream_time(
        self,
        stream: Sequence[Request],
        seeks: int | None = None,
        dist: int | None = None,
    ) -> float:
        nbytes = sum(r.size for r in stream)
        if seeks is None:
            offs = [r.offset for r in stream]
            szs = [r.size for r in stream]
            seeks = random_factor_sum(offs, szs)
        if dist is None:
            dist = sorted_seek_distance(stream)
        return self.hdd.write_time(nbytes, seeks, dist)

    def _run_scalar(
        self,
        trace: Sequence[TraceItem],
        scores: StreamScores | None,
    ) -> SimResult:
        st = _ReplayState()
        grouper = StreamGrouper(self.stream_len)
        stream_idx = 0

        def handle_stream(stream: list[Request]) -> None:
            nonlocal stream_idx
            idx = stream_idx
            stream_idx += 1
            seeks: int | None = None
            dist: int | None = None
            nbytes = sum(r.size for r in stream)
            if scores is not None:
                if (
                    idx >= len(scores)
                    or int(scores.nbytes[idx]) != nbytes
                    or int(scores.offset_sum[idx])
                    != sum(r.offset for r in stream)
                ):
                    raise ValueError(
                        f"stream {idx} does not match the precomputed scores "
                        "(wrong trace or stream grouping?)"
                    )
                pct = float(scores.percentage[idx])
                seeks = int(scores.rf_sum[idx])
                dist = int(scores.seek_distance[idx])
            else:
                pct = stream_percentage(stream)
            for r in stream:
                st.per_app[r.app_id] = st.per_app.get(r.app_id, 0) + r.size

            if self.scheme == "orangefs":
                self._advance_fg(
                    st, self._hdd_stream_time(stream, seeks, dist), nbytes,
                    hdd_foreground=True,
                )
                st.bytes_hdd += nbytes
                self._last_pct = pct
                return

            if self.scheme == "orangefs-bb":
                device = Device.SSD  # plain BB caches everything it can
            else:
                if self.redirector is None:
                    raise RuntimeError(f"scheme {self.scheme} needs a redirector")
                routed = self.redirector.route_stream(stream, percentage=pct)
                device = routed.device
            self._last_pct = pct

            if device is Device.SSD:
                overflow: list[Request] = []
                for r in stream:
                    out = self.pipeline.append(r.file_id, r.offset, r.size)
                    if out.blocked:
                        if self.scheme == "orangefs-bb":
                            # plain BB overflow goes straight to HDD while
                            # the SSD flushes (paper Section 1, option 1);
                            # it still passes through the server queue, so
                            # it gets CFQ-sorted with its stream peers.
                            overflow.append(r)
                            continue
                        # SSDUP/SSDUP+: wait for a region to free up
                        st.blocked_seconds += self._drain_current_flush(st)
                        out = self.pipeline.append(r.file_id, r.offset, r.size)
                        if not out.ok:
                            raise RuntimeError(
                                "append rejected after a full drain"
                            )
                    if self.ssd_stateful:
                        # charge the FTL at the LBA the append landed on
                        reg = self.pipeline.active_region
                        lba = np.array(
                            [reg.base_lba + reg.tail - r.size], dtype=np.int64
                        )
                        dev_dt = float(self.ssd.charge_write(
                            lba, np.array([r.size], dtype=np.int64),
                            t=st.clock,
                        )[0])
                    else:
                        dev_dt = self.ssd.write_time(r.size)
                    self._advance_fg(st, dev_dt, r.size, hdd_foreground=False)
                    st.bytes_ssd += r.size
                if overflow:
                    # overflow is a subset of the stream — no precomputed
                    # score exists for it, so fall back to scalar scoring
                    ob = sum(r.size for r in overflow)
                    self._advance_fg(
                        st, self._hdd_stream_time(overflow), ob,
                        hdd_foreground=True,
                    )
                    st.bytes_hdd += ob
                st.peak_ssd = max(st.peak_ssd, self.pipeline.buffered_bytes)
            else:
                self._advance_fg(
                    st, self._hdd_stream_time(stream, seeks, dist), nbytes,
                    hdd_foreground=True,
                )
                st.bytes_hdd += nbytes

        # -- main loop ----------------------------------------------------
        for item in trace:
            if isinstance(item, Gap):
                self._gap(st, item.seconds)
                continue
            full = grouper.push(item)
            if full is not None:
                handle_stream(full)
        tail = grouper.flush()
        if tail is not None:
            handle_stream(tail)
        if scores is not None and stream_idx != len(scores):
            raise ValueError(
                f"precomputed scores cover {len(scores)} streams but the "
                f"trace produced {stream_idx} (wrong trace?)"
            )
        return self._finalize(st)

    # -- batched engine -------------------------------------------------
    def _run_batched(self, batch: TraceBatch, scores: StreamScores) -> SimResult:
        st = _ReplayState()
        stream_len = self.stream_len
        bounds = batch.stream_bounds(stream_len)
        n_streams = len(bounds) - 1
        if len(scores) != n_streams:
            raise ValueError(
                f"precomputed scores cover {len(scores)} streams but the "
                f"trace produced {n_streams} (wrong trace?)"
            )
        if n_streams:
            nb, osum = batch.stream_sums(stream_len)
            bad = np.nonzero((nb != scores.nbytes) | (osum != scores.offset_sum))[0]
            if len(bad):
                raise ValueError(
                    f"stream {int(bad[0])} does not match the precomputed "
                    "scores (wrong trace or stream grouping?)"
                )

        num_requests = batch.num_requests
        # per-app byte totals are order-independent: one whole-trace pass
        # instead of per-stream dict updates
        if num_requests:
            apps, inverse = np.unique(batch.app_ids, return_inverse=True)
            sums = np.zeros(len(apps), dtype=np.int64)
            np.add.at(sums, inverse, batch.sizes)
            st.per_app = {int(a): int(s) for a, s in zip(apps, sums)}
        gap_pos = batch.gap_positions
        gap_sec = batch.gap_seconds
        n_gaps = len(gap_pos)
        gi = 0
        for s in range(n_streams):
            a, b = int(bounds[s]), int(bounds[s + 1])
            # a full stream completes AT its last request, i.e. before any
            # gap marker at position b; the trailing partial stream is only
            # flushed at end-of-trace, i.e. after ALL remaining gaps.
            fire_before = b if b - a == stream_len else num_requests + 1
            while gi < n_gaps and gap_pos[gi] < fire_before:
                self._gap(st, float(gap_sec[gi]))
                gi += 1
            self._handle_stream_batched(st, batch, scores, s, a, b)
        while gi < n_gaps:
            self._gap(st, float(gap_sec[gi]))
            gi += 1
        return self._finalize(st)

    def _advance_ssd_run(self, st: _ReplayState, walls: np.ndarray) -> None:
        """Vectorized counterpart of per-request ``_advance_fg(...,
        hdd_foreground=False)`` over a run of SSD writes: one numpy pass
        per flush-state segment, dropping to Python only when a flush job
        completes mid-run."""

        self._fg_ssd = True  # flush-gate v2 device signal
        i, m = 0, len(walls)
        while i < m:
            job = self.pipeline.flush_job
            if job is None or not self.pipeline.flush_allowed():
                seg = walls[i:]
                if job is not None:  # paused: same pause accounting
                    job.paused_seconds = _seq_add(job.paused_seconds, seg)
                    self.pipeline.total_paused_seconds = _seq_add(
                        self.pipeline.total_paused_seconds, seg
                    )
                st.clock = _seq_add(st.clock, seg)
                return
            rate = job.effective_rate(self.hdd, self._flush_storage)
            quanta = (rate * walls[i:]).astype(np.int64)
            cq = np.cumsum(quanta)
            j = int(np.searchsorted(cq, job.bytes_left, side="left"))
            if j >= m - i:  # job survives the whole run
                self.pipeline.flush_progress(int(cq[-1]))
                st.clock = _seq_add(st.clock, walls[i:])
                return
            # requests i..i+j drain the job dry (overshoot in the final
            # quantum is discarded, like the scalar per-request call)
            self.pipeline.flush_progress(int(cq[j]))
            st.clock = _seq_add(st.clock, walls[i:i + j + 1])
            i += j + 1

    def _handle_stream_batched(
        self,
        st: _ReplayState,
        batch: TraceBatch,
        scores: StreamScores,
        s: int,
        a: int,
        b: int,
    ) -> None:
        self._replay_stream(
            st,
            batch.offsets[a:b],
            batch.sizes[a:b],
            batch.file_ids[a:b],
            nbytes=int(scores.nbytes[s]),
            pct=float(scores.percentage[s]),
            seeks=int(scores.rf_sum[s]),
            dist=int(scores.seek_distance[s]),
        )

    def _replay_stream(
        self,
        st: _ReplayState,
        offsets: np.ndarray,
        sizes: np.ndarray,
        file_ids: np.ndarray,
        *,
        nbytes: int,
        pct: float,
        seeks: int,
        dist: int,
        force_hdd: bool = False,
    ) -> None:
        """Replay one scored stream against ``st`` (shared by the offline
        batched engine and the online session API).  ``force_hdd`` is the
        service layer's admission-control override: the detector still
        observes the stream (identical policy evolution), but its bytes
        are written HDD-direct regardless of the routing decision.

        With ``sanitize`` on, stream inputs (scores consistent with the
        raw arrays, sane ranges) and the wall clock (monotonic, finite)
        are checked around the replay."""

        if not self.sanitize:
            self._replay_stream_impl(
                st, offsets, sizes, file_ids, nbytes=nbytes, pct=pct,
                seeks=seeks, dist=dist, force_hdd=force_hdd,
            )
            return
        t0 = st.clock
        # one fused branch on the happy path; the per-condition checks
        # re-run only on failure to produce a precise message
        smin = int(sizes.min()) if len(sizes) else 0
        ssum = int(sizes.sum())
        if not (smin >= 0 and nbytes == ssum and 0.0 <= pct <= 1.0
                and seeks >= 0 and dist >= 0):
            _sanitize.check(smin >= 0, "negative request size in stream")
            _sanitize.check(
                nbytes == ssum,
                "stream score nbytes=%d disagrees with sizes.sum()=%d",
                nbytes, ssum,
            )
            _sanitize.check(
                0.0 <= pct <= 1.0, "random percentage %r outside [0, 1]", pct
            )
            _sanitize.check(
                seeks >= 0 and dist >= 0,
                "negative seek score (seeks=%d, dist=%d)", seeks, dist,
            )
        self._replay_stream_impl(
            st, offsets, sizes, file_ids, nbytes=nbytes, pct=pct,
            seeks=seeks, dist=dist, force_hdd=force_hdd,
        )
        if not (st.clock >= t0 and math.isfinite(st.clock)):
            _sanitize.check(
                False,
                "wall clock went backwards or non-finite across a stream "
                "(%r -> %r)", t0, st.clock,
            )

    def _replay_stream_impl(
        self,
        st: _ReplayState,
        offsets: np.ndarray,
        sizes: np.ndarray,
        file_ids: np.ndarray,
        *,
        nbytes: int,
        pct: float,
        seeks: int,
        dist: int,
        force_hdd: bool = False,
    ) -> None:

        if self.scheme == "orangefs":
            self._advance_fg(
                st, self.hdd.write_time(nbytes, seeks, dist), nbytes,
                hdd_foreground=True,
            )
            st.bytes_hdd += nbytes
            self._last_pct = pct
            return

        if self.scheme == "orangefs-bb":
            device = Device.SSD  # plain BB caches everything it can
        else:
            if self.redirector is None:
                raise RuntimeError(f"scheme {self.scheme} needs a redirector")
            device = self.redirector.route_scored(nbytes, pct)
        self._last_pct = pct
        if force_hdd:
            device = Device.HDD

        if device is not Device.SSD:
            self._advance_fg(
                st, self.hdd.write_time(nbytes, seeks, dist), nbytes,
                hdd_foreground=True,
            )
            st.bytes_hdd += nbytes
            return

        net = sizes / self.link.bw
        # stateless models: one vectorized wall per request (bit-exact with
        # the pre-refactor inline math).  Stateful models (walls=None):
        # device times depend on mapping state, so the run helpers charge
        # request-by-request with the landed LBAs.
        walls = (
            None if self.ssd_stateful
            else np.maximum(net, self.ssd.charge_write(None, sizes))
        )
        csum = np.cumsum(sizes)
        if isinstance(self.pipeline, SingleRegionBuffer):
            self._ssd_stream_single_region(
                st, offsets, sizes, file_ids, walls, net, csum
            )
        else:
            self._ssd_stream_two_region(
                st, offsets, sizes, file_ids, walls, net, csum
            )
        st.peak_ssd = max(st.peak_ssd, self.pipeline.buffered_bytes)

    def _charge_ssd_run(
        self,
        st: _ReplayState,
        region: "LogRegion",
        log_offsets: np.ndarray,
        sizes: np.ndarray,
        net: np.ndarray,
        walls: np.ndarray | None,
    ) -> None:
        """Advance the clock over one appended run of SSD writes.

        Stateless models (``walls`` given) ride the vectorized pass.
        Stateful models charge request-by-request at the landed LBAs so
        flush-completion trims interleave with device charging exactly
        like the per-request oracle (bit-parity for the FTL backend).
        """

        if walls is not None:
            self._advance_ssd_run(st, walls)
            return
        lbas = region.base_lba + log_offsets
        for i in range(len(sizes)):
            dev = self.ssd.charge_write(
                lbas[i:i + 1], sizes[i:i + 1], t=st.clock
            )
            self._advance_ssd_run(st, np.maximum(net[i:i + 1], dev))

    def _ssd_stream_two_region(
        self, st, offsets, sizes, file_ids, walls, net, csum
    ) -> None:
        """SSDUP/SSDUP+ SSD path: maximal in-region runs appended and timed
        in one shot; region swaps and writer blocks at run boundaries."""

        n = len(sizes)
        pos = 0
        while pos < n:
            region = self.pipeline.active_region
            base = int(csum[pos - 1]) if pos else 0
            limit = base + region.free_bytes()
            k = int(np.searchsorted(csum, limit, side="right"))
            if k > pos:  # requests [pos, k) fit the active region
                logs = region.tail + (csum[pos:k] - sizes[pos:k]) - base
                region.append_batch(
                    file_ids[pos:k], offsets[pos:k], sizes[pos:k]
                )
                self._charge_ssd_run(
                    st, region, logs, sizes[pos:k], net[pos:k],
                    None if walls is None else walls[pos:k],
                )
                st.bytes_ssd += int(csum[k - 1]) - base
                pos = k
                continue
            # request `pos` does not fit: swap, or block + drain, then retry
            out = self.pipeline.append(
                int(file_ids[pos]), int(offsets[pos]), int(sizes[pos])
            )
            if out.blocked:
                st.blocked_seconds += self._drain_current_flush(st)
                out = self.pipeline.append(
                    int(file_ids[pos]), int(offsets[pos]), int(sizes[pos])
                )
                if not out.ok:
                    raise RuntimeError("append rejected after a full drain")
            landed = self.pipeline.active_region
            self._charge_ssd_run(
                st, landed,
                np.array([landed.tail - int(sizes[pos])], dtype=np.int64),
                sizes[pos:pos + 1], net[pos:pos + 1],
                None if walls is None else walls[pos:pos + 1],
            )
            st.bytes_ssd += int(sizes[pos])
            pos += 1

    def _ssd_stream_single_region(
        self, st, offsets, sizes, file_ids, walls, net, csum
    ) -> None:
        """Plain-BB SSD path: buffer until (nearly) full, then everything
        else in the stream overflows straight to the HDD."""

        n = len(sizes)
        pos = 0
        overflow_from: int | None = None
        region = self.pipeline.regions[0]
        cap_quantum = region.capacity // 256
        while pos < n:
            if self.pipeline.flush_job is not None:
                # region draining: every remaining append is rejected (the
                # per-request path counts each as a blocked event)
                self.pipeline.blocked_events += n - pos
                overflow_from = pos
                break
            base = int(csum[pos - 1]) if pos else 0
            free = region.free_bytes()
            k = int(np.searchsorted(csum, base + free, side="right"))
            if k == pos:
                # doesn't fit: the append schedules the forced flush and
                # rejects; everything from here on overflows
                out = self.pipeline.append(
                    int(file_ids[pos]), int(offsets[pos]), int(sizes[pos])
                )
                if not out.blocked:
                    raise RuntimeError(
                        "over-capacity append unexpectedly accepted"
                    )
                self.pipeline.blocked_events += n - pos - 1
                overflow_from = pos
                break
            # eager-flush trigger: first t in [pos, k) whose append leaves
            # free space below max(size_t, capacity/256)
            rel = csum[pos:k] - base
            trig = (free - rel) < np.maximum(sizes[pos:k], cap_quantum)
            if trig.any():
                t = pos + int(np.argmax(trig))
                if t > pos:
                    logs = region.tail + (csum[pos:t] - sizes[pos:t]) - base
                    region.append_batch(
                        file_ids[pos:t], offsets[pos:t], sizes[pos:t]
                    )
                    self._charge_ssd_run(
                        st, region, logs, sizes[pos:t], net[pos:t],
                        None if walls is None else walls[pos:t],
                    )
                    st.bytes_ssd += int(csum[t - 1]) - base
                # the trigger request goes through the scalar append, which
                # schedules the forced flush exactly like the oracle
                out = self.pipeline.append(
                    int(file_ids[t]), int(offsets[t]), int(sizes[t])
                )
                if not out.ok:
                    raise RuntimeError("eager-flush trigger append rejected")
                self._charge_ssd_run(
                    st, region,
                    np.array([region.tail - int(sizes[t])], dtype=np.int64),
                    sizes[t:t + 1], net[t:t + 1],
                    None if walls is None else walls[t:t + 1],
                )
                st.bytes_ssd += int(sizes[t])
                pos = t + 1
            else:
                logs = region.tail + (csum[pos:k] - sizes[pos:k]) - base
                region.append_batch(
                    file_ids[pos:k], offsets[pos:k], sizes[pos:k]
                )
                self._charge_ssd_run(
                    st, region, logs, sizes[pos:k], net[pos:k],
                    None if walls is None else walls[pos:k],
                )
                st.bytes_ssd += int(csum[k - 1]) - base
                pos = k
        if overflow_from is not None:
            o_offs = offsets[overflow_from:]
            o_szs = sizes[overflow_from:]
            ob = int(o_szs.sum())
            seeks = random_factor_sum(o_offs, o_szs)
            dist = seek_distance_np(o_offs, o_szs)
            self._advance_fg(
                st, self.hdd.write_time(ob, seeks, dist), ob,
                hdd_foreground=True,
            )
            st.bytes_hdd += ob


def run_schemes(
    trace: TraceBatch | Sequence[TraceItem],
    schemes: Iterable[str] = ("orangefs", "orangefs-bb", "ssdup", "ssdup+"),
    scores: StreamScores | None = None,
    **kwargs,
) -> dict[str, SimResult]:
    """Run the same trace under several schemes (paper's comparison set).

    Accuracy contract: same as :meth:`IONodeSimulator.run` — bit-identical
    numpy engines, ``DEVICE_TOLERANCES`` tiers on the device engine.

    ``scores`` precomputed once (they are scheme-independent) is reused
    across every scheme's replay.
    """

    if not isinstance(trace, TraceBatch):
        trace = list(trace)
    out: dict[str, SimResult] = {}
    for s in schemes:
        kw = dict(kwargs)
        if "ssd" in kw:
            # stateful storage (FTL) must not leak mapping state across
            # scheme replays of the same trace
            kw["ssd"] = clone_storage(kw["ssd"])
        out[s] = IONodeSimulator(scheme=s, **kw).run(trace, scores=scores)
    return out
