"""Event-level I/O-node simulator (reproduces the paper's evaluation).

Replays a request trace against one I/O node under four schemes:

* ``orangefs``     — no buffer; every stream goes to the HDD (CFQ-sorted).
* ``orangefs-bb``  — plain burst buffer: ALL data to the SSD; when the SSD is
                     full, incoming data goes straight to HDD while the SSD
                     flushes (the paper's OrangeFS-BB).
* ``ssdup``        — SSDUP (ICS'17): static watermark thresholds (45/30),
                     two-region pipeline, IMMEDIATE flushing.
* ``ssdup+``       — SSDUP+: adaptive threshold + traffic-aware flushing.

Timing model:

* Every foreground stream is bounded by BOTH the network ingest link
  (GbE ≈ 110 MB/s per node on the paper's testbed) and the device:
  ``wall = max(net_time, device_time)``.
* HDD device time = CFQ-sorted seeks × seek_time + sweep distance × coeff
  + bytes / seq_bw  (see ``device_model`` calibration notes).
* The background flusher shares the HDD with foreground HDD writes through
  :class:`InterferenceModel` (fair share + inflation phi, paper Eq. 7); it
  runs at full sequential bandwidth while the foreground is on the SSD or
  during compute gaps.
* A ``Gap`` item models a compute phase (paper Fig. 14): only the flusher
  runs.

Accounting matches the paper's measurements: reported throughput uses the
**application-visible I/O time** (``io_seconds``: last foreground byte
absorbed, compute gaps excluded); the final background drain is tracked
separately in ``total_seconds`` (the paper's burst buffer likewise hides the
final flush in the next compute phase).
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

from .adaptive import AdaptiveThreshold, StaticWatermarkThreshold
from .device_model import HDDModel, IngestLink, InterferenceModel, SSDModel
from .pipeline import SingleRegionBuffer, TwoRegionPipeline
from .random_factor import (
    DEFAULT_STREAM_LEN,
    Request,
    StreamGrouper,
    random_factor_sum,
    sorted_seek_distance,
    stream_percentage,
)
from .redirector import DataRedirector, Device
from .trace import Gap, StreamScores, TraceItem


@dataclasses.dataclass
class SimResult:
    scheme: str
    io_seconds: float  # application-visible I/O time (gaps excluded)
    total_seconds: float  # includes compute gaps and the final drain
    total_bytes: int
    bytes_to_ssd: int
    bytes_to_hdd_direct: int
    flushes: int
    flush_paused_seconds: float
    blocked_seconds: float
    peak_ssd_occupancy: int
    metadata_bytes: int
    per_app_bytes: dict[int, int]

    @property
    def throughput_mbs(self) -> float:
        return self.total_bytes / self.io_seconds / 1e6 if self.io_seconds else 0.0

    @property
    def ssd_byte_ratio(self) -> float:
        return self.bytes_to_ssd / self.total_bytes if self.total_bytes else 0.0

    def app_throughput_mbs(self, app_id: int) -> float:
        return self.per_app_bytes.get(app_id, 0) / self.io_seconds / 1e6


class IONodeSimulator:
    """One I/O node running one of the four schemes."""

    def __init__(
        self,
        scheme: str = "ssdup+",
        ssd_capacity: int = 8 << 30,
        hdd: HDDModel | None = None,
        ssd: SSDModel | None = None,
        link: IngestLink | None = None,
        interference: InterferenceModel | None = None,
        stream_len: int = DEFAULT_STREAM_LEN,
        flush_gate: float = 0.5,
        adaptive_window: int | None = 64,
    ):
        if scheme not in ("orangefs", "orangefs-bb", "ssdup", "ssdup+"):
            raise ValueError(f"unknown scheme {scheme}")
        self.scheme = scheme
        self.hdd = hdd or HDDModel()
        self.ssd = ssd or SSDModel()
        self.link = link or IngestLink()
        self.interference = interference or InterferenceModel()
        self.stream_len = stream_len
        self.ssd_capacity = ssd_capacity

        self._last_pct = 0.0
        if scheme == "ssdup+":
            policy = AdaptiveThreshold(window=adaptive_window)
            self.pipeline = TwoRegionPipeline(
                ssd_capacity // 2, traffic_aware=True, flush_gate=flush_gate,
                percentage_source=lambda: self._last_pct,
            )
            self.redirector: DataRedirector | None = DataRedirector(policy, stream_len)
        elif scheme == "ssdup":
            policy = StaticWatermarkThreshold()
            self.pipeline = TwoRegionPipeline(
                ssd_capacity // 2, traffic_aware=False,
                percentage_source=lambda: self._last_pct,
            )
            self.redirector = DataRedirector(policy, stream_len)
        elif scheme == "orangefs-bb":
            self.pipeline = SingleRegionBuffer(
                ssd_capacity,
                percentage_source=lambda: self._last_pct,
            )
            self.redirector = None
        else:  # orangefs
            self.pipeline = None  # type: ignore[assignment]
            self.redirector = None

    # ------------------------------------------------------------------
    def _hdd_stream_time(
        self,
        stream: Sequence[Request],
        seeks: int | None = None,
        dist: int | None = None,
    ) -> float:
        nbytes = sum(r.size for r in stream)
        if seeks is None:
            offs = [r.offset for r in stream]
            szs = [r.size for r in stream]
            seeks = random_factor_sum(offs, szs)
        if dist is None:
            dist = sorted_seek_distance(stream)
        return self.hdd.write_time(nbytes, seeks, dist)

    def run(
        self,
        trace: Sequence[TraceItem],
        scores: StreamScores | None = None,
    ) -> SimResult:
        """Replay ``trace``; ``scores`` (from
        :func:`repro.core.trace.compute_stream_scores`, same ``stream_len``)
        supplies every stream's random percentage / seek count / seek
        distance so the hot loop never re-sorts a stream on the host."""

        if scores is not None and scores.stream_len != self.stream_len:
            raise ValueError(
                f"scores computed for stream_len={scores.stream_len}, "
                f"simulator uses {self.stream_len}"
            )
        clock = 0.0
        gap_seconds = 0.0
        bytes_ssd = 0
        bytes_hdd = 0
        blocked_seconds = 0.0
        peak_ssd = 0
        per_app: dict[int, int] = {}
        grouper = StreamGrouper(self.stream_len)

        def advance(device_dt: float, nbytes: int, hdd_foreground: bool) -> None:
            """One foreground operation: device time ``device_dt`` alone,
            network-capped, with the background flush sharing the HDD."""

            nonlocal clock
            flushing = (
                self.pipeline is not None
                and self.pipeline.flush_job is not None
            )
            allowed = flushing and self.pipeline.flush_allowed()
            net_dt = self.link.time(nbytes)
            if not flushing or not allowed:
                wall = max(net_dt, device_dt)
                if flushing:
                    self.pipeline.note_pause(wall)
                clock += wall
                return
            if hdd_foreground:
                disk_dt = device_dt * self.interference.foreground_slowdown()
                wall = max(net_dt, disk_dt)
                rate = self.hdd.seq_bw * self.interference.flush_rate_fraction()
            else:
                wall = max(net_dt, device_dt)
                rate = self.hdd.seq_bw
            self.pipeline.flush_progress(int(rate * wall))
            clock += wall

        def drain_current_flush() -> float:
            """Block the writer until the active flush finishes."""

            assert self.pipeline is not None and self.pipeline.flush_job is not None
            self.pipeline.force_flush()
            left = self.pipeline.flush_job.bytes_left
            dt = left / self.hdd.seq_bw
            self.pipeline.flush_progress(left)
            nonlocal clock
            clock += dt
            return dt

        stream_idx = 0

        def handle_stream(stream: list[Request]) -> None:
            nonlocal bytes_ssd, bytes_hdd, peak_ssd, blocked_seconds, stream_idx
            idx = stream_idx
            stream_idx += 1
            seeks: int | None = None
            dist: int | None = None
            nbytes = sum(r.size for r in stream)
            if scores is not None:
                if (
                    idx >= len(scores)
                    or int(scores.nbytes[idx]) != nbytes
                    or int(scores.offset_sum[idx])
                    != sum(r.offset for r in stream)
                ):
                    raise ValueError(
                        f"stream {idx} does not match the precomputed scores "
                        "(wrong trace or stream grouping?)"
                    )
                pct = float(scores.percentage[idx])
                seeks = int(scores.rf_sum[idx])
                dist = int(scores.seek_distance[idx])
            else:
                pct = stream_percentage(stream)
            for r in stream:
                per_app[r.app_id] = per_app.get(r.app_id, 0) + r.size

            if self.scheme == "orangefs":
                advance(self._hdd_stream_time(stream, seeks, dist), nbytes,
                        hdd_foreground=True)
                bytes_hdd += nbytes
                self._last_pct = pct
                return

            if self.scheme == "orangefs-bb":
                device = Device.SSD  # plain BB caches everything it can
            else:
                assert self.redirector is not None
                routed = self.redirector.route_stream(stream, percentage=pct)
                device = routed.device
            self._last_pct = pct

            if device is Device.SSD:
                overflow: list[Request] = []
                for r in stream:
                    out = self.pipeline.append(r.file_id, r.offset, r.size)
                    if out.blocked:
                        if self.scheme == "orangefs-bb":
                            # plain BB overflow goes straight to HDD while
                            # the SSD flushes (paper Section 1, option 1);
                            # it still passes through the server queue, so
                            # it gets CFQ-sorted with its stream peers.
                            overflow.append(r)
                            continue
                        # SSDUP/SSDUP+: wait for a region to free up
                        blocked_seconds += drain_current_flush()
                        out = self.pipeline.append(r.file_id, r.offset, r.size)
                        assert out.ok, "append must succeed after drain"
                    advance(self.ssd.write_time(r.size), r.size, hdd_foreground=False)
                    bytes_ssd += r.size
                if overflow:
                    # overflow is a subset of the stream — no precomputed
                    # score exists for it, so fall back to scalar scoring
                    ob = sum(r.size for r in overflow)
                    advance(self._hdd_stream_time(overflow), ob, hdd_foreground=True)
                    bytes_hdd += ob
                peak_ssd = max(peak_ssd, self.pipeline.buffered_bytes)
            else:
                advance(self._hdd_stream_time(stream, seeks, dist), nbytes,
                        hdd_foreground=True)
                bytes_hdd += nbytes

        # -- main loop ----------------------------------------------------
        for item in trace:
            if isinstance(item, Gap):
                # compute phase: the flusher gets the HDD to itself
                if self.pipeline is not None and self.pipeline.flush_job is not None:
                    self.pipeline.flush_progress(int(item.seconds * self.hdd.seq_bw))
                clock += item.seconds
                gap_seconds += item.seconds
                continue
            full = grouper.push(item)
            if full is not None:
                handle_stream(full)
        tail = grouper.flush()
        if tail is not None:
            handle_stream(tail)
        if scores is not None and stream_idx != len(scores):
            raise ValueError(
                f"precomputed scores cover {len(scores)} streams but the "
                f"trace produced {stream_idx} (wrong trace?)"
            )

        io_seconds = clock - gap_seconds  # application-visible I/O time

        # -- drain: flush whatever is still buffered (overlaps the NEXT
        #    compute phase in a real deployment; excluded from io_seconds) --
        if self.pipeline is not None:
            self.pipeline.drain()
            while self.pipeline.flush_job is not None:
                job = self.pipeline.flush_job
                clock += job.bytes_left / self.hdd.seq_bw
                self.pipeline.flush_progress(job.bytes_left)
                self.pipeline.force_flush()

        total_bytes = bytes_ssd + bytes_hdd
        return SimResult(
            scheme=self.scheme,
            io_seconds=io_seconds,
            total_seconds=clock,
            total_bytes=total_bytes,
            bytes_to_ssd=bytes_ssd,
            bytes_to_hdd_direct=bytes_hdd,
            flushes=self.pipeline.flushes_completed if self.pipeline else 0,
            flush_paused_seconds=(
                self.pipeline.total_paused_seconds if self.pipeline else 0.0
            ),
            blocked_seconds=blocked_seconds,
            peak_ssd_occupancy=peak_ssd,
            metadata_bytes=self.pipeline.metadata_bytes if self.pipeline else 0,
            per_app_bytes=per_app,
        )


def run_schemes(
    trace: Sequence[TraceItem],
    schemes: Iterable[str] = ("orangefs", "orangefs-bb", "ssdup", "ssdup+"),
    scores: StreamScores | None = None,
    **kwargs,
) -> dict[str, SimResult]:
    """Run the same trace under several schemes (paper's comparison set).

    ``scores`` precomputed once (they are scheme-independent) is reused
    across every scheme's replay.
    """

    trace = list(trace)
    return {
        s: IONodeSimulator(scheme=s, **kwargs).run(trace, scores=scores)
        for s in schemes
    }
