"""Log-structured fast-tier store with AVL indexing (paper Section 2.5).

Random writes redirected to the fast tier are *appended* to a per-region log
(sequential SSD writes avoid write amplification; paper cites RIPQ), and an
AVL tree per backing file records ``original offset -> log extent``.  When a
region flushes, an in-order AVL traversal yields the extents in backing-file
order: reads from the log are random, but SSD random reads are ~free, and the
slow-tier writes become sequential — the paper's key asymmetry.

This module is device-agnostic: it tracks extents and byte accounting.  The
timing of the underlying devices is modeled by ``device_model.py`` and the
actual persistence backend (for the framework's checkpoint path) lives in
``repro.checkpoint.tiered_store`` which embeds one of these per region.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Iterator

from .avl import AVLTree, Extent


@dataclasses.dataclass(frozen=True, slots=True)
class LogRecord:
    """One appended record in a region's log."""

    file_id: int
    offset: int  # original offset in the backing file
    size: int
    log_offset: int  # byte position in this region's log


class LogRegion:
    """One append-only region of the fast tier (half of the SSD, §2.4)."""

    def __init__(self, capacity_bytes: int, name: str = "region"):
        if capacity_bytes <= 0:
            raise ValueError("capacity must be positive")
        self.capacity = capacity_bytes
        self.name = name
        self.tail = 0  # next append position
        self.records: list[LogRecord] = []
        self.trees: dict[int, AVLTree] = {}  # one AVL per backing file
        self.write_payload: Callable[[LogRecord, bytes | None], None] | None = None

    # -- write path -------------------------------------------------------
    def free_bytes(self) -> int:
        return self.capacity - self.tail

    def fits(self, size: int) -> bool:
        return self.tail + size <= self.capacity

    def append(self, file_id: int, offset: int, size: int, payload: bytes | None = None) -> LogRecord:
        """Append one request's data to the log and index it."""

        if not self.fits(size):
            raise RegionFullError(
                f"{self.name}: {size} B does not fit ({self.free_bytes()} free)"
            )
        rec = LogRecord(file_id, offset, size, self.tail)
        self.tail += size
        self.records.append(rec)
        self.trees.setdefault(file_id, AVLTree()).insert(offset, size, rec.log_offset)
        if self.write_payload is not None:
            self.write_payload(rec, payload)
        return rec

    # -- flush path ---------------------------------------------------------
    def flush_order(self) -> Iterator[tuple[int, Extent]]:
        """(file_id, extent) pairs in sequential backing-file order.

        In-order AVL traversal per file; files are visited in ascending id so
        the slow tier sees one sequential pass per file.
        """

        for file_id in sorted(self.trees):
            for ext in self.trees[file_id].in_order():
                yield file_id, ext

    def flush_bytes(self) -> int:
        """Live bytes that a flush would write (latest version per offset)."""

        return sum(ext.size for _, ext in self.flush_order())

    def metadata_bytes(self) -> int:
        return sum(t.approx_bytes() for t in self.trees.values())

    def seek_count_if_unsorted(self) -> int:
        """Seeks the flush would cost WITHOUT the AVL order (arrival order).

        Used by benchmarks to quantify the AVL benefit: arrival order vs
        in-order traversal.
        """

        seeks = 0
        prev_end: dict[int, int] = {}
        for rec in self.records:
            if prev_end.get(rec.file_id) != rec.offset:
                seeks += 1
            prev_end[rec.file_id] = rec.offset + rec.size
        return seeks

    def seek_count_sorted(self) -> int:
        """Seeks of the AVL-ordered flush (gaps between live extents only)."""

        seeks = 0
        prev_end: dict[int, int] = {}
        for file_id, ext in self.flush_order():
            if prev_end.get(file_id) != ext.offset:
                seeks += 1
            prev_end[file_id] = ext.end
        return seeks

    def reset(self) -> None:
        """Empty the region after a completed flush."""

        self.tail = 0
        self.records.clear()
        self.trees.clear()

    @property
    def used_bytes(self) -> int:
        return self.tail

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"LogRegion({self.name}, used={self.tail}/{self.capacity}, "
            f"files={len(self.trees)}, records={len(self.records)})"
        )


class RegionFullError(RuntimeError):
    """Raised when an append exceeds the region capacity."""
