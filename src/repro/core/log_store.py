"""Log-structured fast-tier store with a pluggable extent index (§2.5).

Random writes redirected to the fast tier are *appended* to a per-region log
(sequential SSD writes avoid write amplification; paper cites RIPQ), and a
per-backing-file index records ``original offset -> log extent``.  When a
region flushes, an in-order traversal yields the extents in backing-file
order: reads from the log are random, but SSD random reads are ~free, and the
slow-tier writes become sequential — the paper's key asymmetry.

Two index backends implement the same contract (``index_backend``):

* ``"avl"``   — the paper's AVL tree (:class:`repro.core.avl.AVLTree`),
  O(log n) pointer-chasing inserts in Python; the bit-exact oracle.
* ``"numpy"`` — :class:`repro.core.extent_index.ExtentIndex`, append-only
  columnar arrays with one lazy lexsort-style compaction; the fast path
  the batched replay engine rides (``tests/test_extent_index.py``
  property-checks the equivalence).

The write path likewise has two granularities: :meth:`LogRegion.append`
(one request, the control-plane/byte-moving path) and
:meth:`LogRegion.append_batch` (a whole request run as numpy arrays, no
per-request Python — the simulator's hot path).  Record bookkeeping is
columnar either way, so a million-append region never materializes a
million ``LogRecord`` objects unless a caller asks for them.

This module is device-agnostic: it tracks extents and byte accounting.  The
timing of the underlying devices is modeled by ``device_model.py`` and the
actual persistence backend (for the framework's checkpoint path) lives in
``repro.checkpoint.tiered_store`` which embeds one of these per region.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Iterator

import numpy as np

from .avl import Extent
from .extent_index import ColumnarAppender, make_index


@dataclasses.dataclass(frozen=True, slots=True)
class LogRecord:
    """One appended record in a region's log."""

    file_id: int
    offset: int  # original offset in the backing file
    size: int
    log_offset: int  # byte position in this region's log


class LogRegion:
    """One append-only region of the fast tier (half of the SSD, §2.4)."""

    def __init__(
        self,
        capacity_bytes: int,
        name: str = "region",
        index_backend: str = "numpy",
    ):
        if capacity_bytes <= 0:
            raise ValueError("capacity must be positive")
        make_index(index_backend)  # eager validation; per-file indexes are lazy
        self.capacity = capacity_bytes
        self.name = name
        self.index_backend = index_backend
        self.tail = 0  # next append position
        # LBA of this region's first byte on the backing SSD; stateful
        # storage models (FTL) address appends as base_lba + log_offset
        self.base_lba = 0
        # arrival-order record log: (file_id, offset, size, log_offset)
        self._rec = ColumnarAppender(4)
        self.trees: dict[int, object] = {}  # one index per backing file
        self.write_payload: Callable[[LogRecord, bytes | None], None] | None = None

    # -- write path -------------------------------------------------------
    def free_bytes(self) -> int:
        return self.capacity - self.tail

    def fits(self, size: int) -> bool:
        return self.tail + size <= self.capacity

    def _index_for(self, file_id: int):
        idx = self.trees.get(file_id)
        if idx is None:
            idx = self.trees[file_id] = make_index(self.index_backend)
        return idx

    def append(self, file_id: int, offset: int, size: int, payload: bytes | None = None) -> LogRecord:
        """Append one request's data to the log and index it."""

        if not self.fits(size):
            raise RegionFullError(
                f"{self.name}: {size} B does not fit ({self.free_bytes()} free)"
            )
        rec = LogRecord(file_id, offset, size, self.tail)
        self.tail += size
        self._rec.append_row((file_id, offset, size, rec.log_offset))
        self._index_for(file_id).insert(offset, size, rec.log_offset)
        if self.write_payload is not None:
            self.write_payload(rec, payload)
        return rec

    def append_batch(
        self,
        file_ids: np.ndarray,
        offsets: np.ndarray,
        sizes: np.ndarray,
    ) -> None:
        """Append a whole request run at once (arrival order = array order).

        Semantically identical to calling :meth:`append` per element, but
        with O(1) Python calls: one columnar record chunk plus one
        ``insert_batch`` per distinct backing file.  Payload-carrying
        regions (``write_payload`` set) must use the scalar path — batches
        carry metadata only.
        """

        n = len(sizes)
        if n == 0:
            return
        if self.write_payload is not None:
            raise RuntimeError(
                f"{self.name}: append_batch carries no payloads; use append()"
            )
        file_ids = np.asarray(file_ids, dtype=np.int64)
        offsets = np.asarray(offsets, dtype=np.int64)
        sizes = np.asarray(sizes, dtype=np.int64)
        csum = np.cumsum(sizes)
        total = int(csum[-1])
        if not self.fits(total):
            raise RegionFullError(
                f"{self.name}: {total} B does not fit ({self.free_bytes()} free)"
            )
        log_offsets = self.tail + csum - sizes
        self.tail += total
        self._rec.append_chunk(file_ids, offsets, sizes, log_offsets)
        # one insert_batch per backing file, arrival order preserved
        # inside each file's run by the stable sort
        if file_ids[0] == file_ids[-1] and not np.any(file_ids != file_ids[0]):
            self._index_for(int(file_ids[0])).insert_batch(
                offsets, sizes, log_offsets
            )
        else:
            order = np.argsort(file_ids, kind="stable")
            sorted_fids = file_ids[order]
            starts = np.concatenate(
                [[0], np.nonzero(sorted_fids[1:] != sorted_fids[:-1])[0] + 1,
                 [n]]
            )
            for a, b in zip(starts[:-1], starts[1:]):
                idx = order[a:b]
                self._index_for(int(sorted_fids[a])).insert_batch(
                    offsets[idx], sizes[idx], log_offsets[idx]
                )

    @property
    def records(self) -> list[LogRecord]:
        """Arrival-order record list, materialized on demand (diagnostics —
        the columnar arrays are the storage format)."""

        fids, offs, szs, logs = self._rec.columns()
        return [
            LogRecord(int(f), int(o), int(s), int(l))
            for f, o, s, l in zip(fids, offs, szs, logs)
        ]

    @property
    def last_record(self) -> LogRecord | None:
        """The most recently appended record (read-your-writes helper)."""

        row = self._rec.last_row()
        return LogRecord(*row) if row is not None else None

    @property
    def num_records(self) -> int:
        return len(self._rec)

    # -- flush path ---------------------------------------------------------
    def flush_order(self) -> Iterator[tuple[int, Extent]]:
        """(file_id, extent) pairs in sequential backing-file order.

        In-order index traversal per file; files are visited in ascending id
        so the slow tier sees one sequential pass per file.
        """

        for file_id in sorted(self.trees):
            for ext in self.trees[file_id].in_order():
                yield file_id, ext

    def flush_arrays(self) -> Iterator[tuple[int, np.ndarray, np.ndarray, np.ndarray]]:
        """Per-file ``(file_id, offsets, sizes, log_offsets)`` in flush
        order — the zero-Python view the batched flush accounting uses."""

        for file_id in sorted(self.trees):
            offs, szs, logs = self.trees[file_id].in_order_arrays()
            yield file_id, offs, szs, logs

    def flush_bytes(self) -> int:
        """Live bytes that a flush would write (latest version per offset)."""

        return sum(int(szs.sum()) for _, _, szs, _ in self.flush_arrays())

    def metadata_bytes(self) -> int:
        return sum(t.approx_bytes() for t in self.trees.values())

    def seek_count_if_unsorted(self) -> int:
        """Seeks the flush would cost WITHOUT the index order (arrival
        order).

        Used by benchmarks to quantify the sorted-flush benefit: arrival
        order vs in-order traversal.
        """

        fids, offs, szs, _ = self._rec.columns()
        if not len(fids):
            return 0
        # group by file (stable keeps arrival order inside each file), then
        # count arrival-adjacent discontinuities per file + 1 initial seek
        order = np.argsort(fids, kind="stable")
        sf, so, ss = fids[order], offs[order], szs[order]
        same_file = sf[1:] == sf[:-1]
        contiguous = so[1:] == so[:-1] + ss[:-1]
        n_files = len(np.unique(sf))
        return n_files + int(np.count_nonzero(same_file & ~contiguous))

    def seek_count_sorted(self) -> int:
        """Seeks of the index-ordered flush (gaps between live extents)."""

        seeks = 0
        for _, offs, szs, _ in self.flush_arrays():
            if len(offs):
                seeks += 1 + int(
                    np.count_nonzero(offs[1:] != offs[:-1] + szs[:-1])
                )
        return seeks

    def reset(self) -> None:
        """Empty the region after a completed flush."""

        self.tail = 0
        self._rec.clear()
        self.trees.clear()

    @property
    def used_bytes(self) -> int:
        return self.tail

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"LogRegion({self.name}, used={self.tail}/{self.capacity}, "
            f"files={len(self.trees)}, records={len(self._rec)})"
        )


class RegionFullError(RuntimeError):
    """Raised when an append exceeds the region capacity."""
