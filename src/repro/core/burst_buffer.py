"""Production burst-buffer facade: SSDUP+ applied to real bytes.

This is the piece the *framework* uses (checkpoint writes, data-pipeline
spill): a per-host writer that routes write requests between a fast tier
(local burst directory — NVMe/tmpfs) and a slow tier (the shared filesystem
directory), using the paper's full machinery:

* request-stream grouping + random-factor scoring  (``random_factor``)
* adaptive threshold                               (``adaptive``)
* redirection state machine                        (``redirector``)
* two-region log-structured fast tier + AVL index  (``pipeline``/``log_store``)
* background flusher with traffic-aware pausing    (this module)

Unlike :mod:`repro.core.simulator` (timing model for the paper-validation
benchmarks) this module moves actual payload bytes and guarantees
read-your-writes: ``read()`` consults the active region, then the flushing
region, then the slow tier.  ``drain()`` forces all buffered data down to the
slow tier (used before checkpoint manifests are committed).
"""

from __future__ import annotations

import os
import threading
import time
from typing import Callable

from .adaptive import AdaptiveThreshold
from .pipeline import TwoRegionPipeline
from .random_factor import DEFAULT_STREAM_LEN, Request
from .redirector import DataRedirector, Device


class BurstBufferWriter:
    """Write-path facade over a fast-tier directory and a slow-tier directory."""

    def __init__(
        self,
        fast_dir: str,
        slow_dir: str,
        region_bytes: int = 64 << 20,
        stream_len: int = DEFAULT_STREAM_LEN,
        traffic_aware: bool = True,
        flush_gate: float = 0.5,
        adaptive_window: int | None = 64,
        flush_poll_seconds: float = 0.002,
        flush_chunk_bytes: int = 4 << 20,
        index_backend: str = "avl",
    ):
        os.makedirs(fast_dir, exist_ok=True)
        os.makedirs(slow_dir, exist_ok=True)
        self.fast_dir = fast_dir
        self.slow_dir = slow_dir
        self._lock = threading.RLock()
        self._last_pct = 0.0
        # AVL by default: this path interleaves inserts with point lookups
        # (read-your-writes) under the writer lock, where the AVL's
        # incremental O(log n) beats ExtentIndex's recompaction-per-read;
        # the columnar index is for the replay engine's insert-many-then-
        # flush pattern.
        self.pipeline = TwoRegionPipeline(
            region_bytes,
            traffic_aware=traffic_aware,
            flush_gate=flush_gate,
            percentage_source=lambda: self._last_pct,
            index_backend=index_backend,
        )
        self.redirector = DataRedirector(
            AdaptiveThreshold(window=adaptive_window), stream_len
        )
        self._region_files = [
            open(os.path.join(fast_dir, f"region{i}.log"), "w+b") for i in range(2)
        ]
        self._slow_files: dict[int, object] = {}
        self._pending: list[tuple[Request, bytes]] = []
        self._flush_chunk = flush_chunk_bytes
        self._poll = flush_poll_seconds
        self._stop = threading.Event()
        self._flusher = threading.Thread(
            target=self._flush_loop, name="ssdup-flusher", daemon=True
        )
        self._flusher.start()
        # stats
        self.bytes_fast = 0
        self.bytes_slow_direct = 0
        self.flush_stalls = 0

    # -- public API --------------------------------------------------------
    def write(self, file_id: int, offset: int, data: bytes) -> None:
        """Submit one write request.  Routing happens at stream granularity;
        requests buffer host-side until their stream's decision is known
        (the paper's one-stream decision lag)."""

        req = Request(offset=offset, size=len(data), file_id=file_id,
                      time=time.monotonic())
        with self._lock:
            self._pending.append((req, data))
            full = self.redirector.grouper.push(req)
            if full is not None:
                self._dispatch_stream(full)

    def read(self, file_id: int, offset: int, size: int) -> bytes:
        """Read-your-writes across tiers (fast regions first, newest wins)."""

        with self._lock:
            for region, fobj in self._regions_newest_first():
                tree = region.trees.get(file_id)
                if tree is None:
                    continue
                ext = tree.lookup(offset)
                if ext is not None and ext.size >= size:
                    fobj.seek(ext.log_offset)
                    return fobj.read(size)
        f = self._slow_file(file_id)
        with self._lock:
            f.seek(offset)
            return f.read(size)

    def drain(self, timeout: float = 120.0) -> None:
        """Flush the residual stream and force everything to the slow tier."""

        with self._lock:
            tail = self.redirector.grouper.flush()
            if tail is not None:
                self._dispatch_stream(tail)
            self.pipeline.drain()
            self.pipeline.force_flush()
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            with self._lock:
                if self.pipeline.flush_job is None and self.pipeline.buffered_bytes == 0:
                    for f in self._slow_files.values():
                        f.flush()
                    return
                self.pipeline.force_flush()
            time.sleep(self._poll)
        raise TimeoutError("burst buffer drain timed out")

    def close(self) -> None:
        self.drain()
        self._stop.set()
        self._flusher.join(timeout=10)
        for f in self._region_files:
            f.close()
        for f in self._slow_files.values():
            f.close()

    # -- stream dispatch -----------------------------------------------------
    def _dispatch_stream(self, stream: list[Request]) -> None:
        """Route one completed stream; move its payloads to the chosen tier."""

        routed = self.redirector.route_stream(stream)
        self._last_pct = routed.percentage
        stream_set = {id(r) for r in stream}
        batch = [(r, d) for r, d in self._pending if id(r) in stream_set]
        self._pending = [(r, d) for r, d in self._pending if id(r) not in stream_set]

        if routed.device is Device.SSD:
            for req, data in batch:
                self._append_fast(req, data)
        else:
            for req, data in batch:
                self._write_slow(req.file_id, req.offset, data)
                self.bytes_slow_direct += len(data)

    def _append_fast(self, req: Request, data: bytes) -> None:
        out = self.pipeline.append(req.file_id, req.offset, req.size)
        if out.blocked:
            # both regions full: force + spin until the flusher frees one
            self.flush_stalls += 1
            self.pipeline.force_flush()
            self._lock.release()
            try:
                while True:
                    time.sleep(self._poll)
                    with self._lock:
                        o = self.pipeline.append(req.file_id, req.offset, req.size)
                        if o.ok:
                            out = o
                            break
                        self.pipeline.force_flush()
            finally:
                self._lock.acquire()
        region = self.pipeline.active_region
        rec = region.last_record
        fobj = self._region_files[self.pipeline.active]
        fobj.seek(rec.log_offset)
        fobj.write(data)
        self.bytes_fast += len(data)

    # -- flusher thread ------------------------------------------------------
    def _flush_loop(self) -> None:
        while not self._stop.is_set():
            with self._lock:
                job = self.pipeline.flush_job
                allowed = self.pipeline.flush_allowed() if job else False
                if job is not None and allowed:
                    region = job.region
                    ridx = self.pipeline.regions.index(region)
                    extents = list(region.flush_order())
                    done = job.bytes_done
                else:
                    extents = []
            if not extents:
                time.sleep(self._poll)
                continue
            # copy extents in AVL (sequential slow-tier) order
            skipped = 0
            for file_id, ext in extents:
                if skipped + ext.size <= done:
                    skipped += ext.size
                    continue
                with self._lock:
                    if self.pipeline.flush_job is None or self.pipeline.flush_job.region is not region:
                        break
                    src = self._region_files[ridx]
                    src.seek(ext.log_offset)
                    payload = src.read(ext.size)
                    self._write_slow(file_id, ext.offset, payload)
                    self.pipeline.flush_progress(ext.size)
                    if not self.pipeline.flush_allowed() and self.pipeline.flush_job is not None:
                        break  # traffic turned sequential: pause politely
                time.sleep(0)  # yield

    # -- helpers -------------------------------------------------------------
    def _regions_newest_first(self):
        order = [self.pipeline.active, 1 - self.pipeline.active]
        for i in order:
            if i < len(self.pipeline.regions):
                yield self.pipeline.regions[i], self._region_files[i]

    def _slow_file(self, file_id: int):
        f = self._slow_files.get(file_id)
        if f is None:
            path = os.path.join(self.slow_dir, f"file_{file_id}.bin")
            mode = "r+b" if os.path.exists(path) else "w+b"
            f = open(path, mode)
            self._slow_files[file_id] = f
        return f

    def _write_slow(self, file_id: int, offset: int, data: bytes) -> None:
        f = self._slow_file(file_id)
        f.seek(offset)
        f.write(data)

    # -- stats ---------------------------------------------------------------
    @property
    def fast_byte_ratio(self) -> float:
        total = self.bytes_fast + self.bytes_slow_direct
        return self.bytes_fast / total if total else 0.0

    def stats(self) -> dict:
        return {
            "bytes_fast": self.bytes_fast,
            "bytes_slow_direct": self.bytes_slow_direct,
            "fast_byte_ratio": self.fast_byte_ratio,
            "flushes_completed": self.pipeline.flushes_completed,
            "flush_stalls": self.flush_stalls,
            "metadata_bytes": self.pipeline.metadata_bytes,
            "threshold": self.redirector.policy.threshold,
        }
