"""Adaptive random-percentage threshold (SSDUP+ paper, Section 2.3.2).

SSDUP used static high/low watermarks (45%/30%).  SSDUP+ replaces them with a
history list of recent stream percentages, kept in increasing order
(*PercentList*), and picks the threshold by the quantile rule

    avgper    = mean(PercentList)                       (Eq. 3)
    threshold = PercentList[(1 - avgper) * (N - 1)]     (Eq. 2)

Intuition (paper): when recent streams are mostly sequential (low avgper) the
selected index is *high*, so the threshold is strict and little data goes to
the fast tier; when recent streams are random (high avgper) the index is low,
the threshold drops, and more streams are redirected.

Exact indexing convention: the paper's Eq. 2 leaves the rounding and the
insert-vs-average ordering ambiguous.  We brute-forced every combination of
{seed, floor/round/ceil, N vs N-1, average-before/after-insert} against the
paper's own ten-step case study (Section 2.3.2: thresholds 0.5, 0.5433,
0.5433, 0.5433, 0.5905, 0.5826, 0.5826, 0.5905, 0.5905, 0.6062) and the
convention below reproduces **9/10 values exactly** (the seventh differs by a
single index, consistent with their 4-decimal rounding):

    avgper over the list BEFORE inserting the new percentage,
    then insert, then index = floor((1 - avgper) * len(list)) clamped,
    with a default threshold of 0.5 while the list is empty.

``tests/test_adaptive.py`` locks this against the paper's numbers.
"""

from __future__ import annotations

import bisect
from collections import deque
from typing import Iterable

DEFAULT_THRESHOLD = 0.5  # in effect before any history exists


class AdaptiveThreshold:
    """Traffic-aware adaptive threshold over stream random-percentages.

    Parameters
    ----------
    window:
        Number of most-recent stream percentages retained.  ``None`` keeps
        the full history until :meth:`reset` (the paper empties PercentList
        when the workload's access pattern changes).  The paper's case study
        tracks the latest 10 streams.
    default:
        Threshold returned before any observation.
    """

    def __init__(self, window: int | None = None, default: float = DEFAULT_THRESHOLD):
        if window is not None and window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self.window = window
        self.default = float(default)
        self._recent: deque[float] = deque(maxlen=window)
        self._sorted: list[float] = []
        self._threshold = self.default
        self.observations = 0

    # -- core update ------------------------------------------------------
    def observe(self, percentage: float) -> float:
        """Insert one stream percentage; returns the new threshold."""

        p = float(percentage)
        if not 0.0 <= p <= 1.0 + 1e-9:
            raise ValueError(f"random percentage out of range: {p}")

        # avgper over the PRE-insert list (see module docstring).
        avgper = (sum(self._sorted) / len(self._sorted)) if self._sorted else None

        if self.window is not None and len(self._recent) == self.window:
            evicted = self._recent[0]
            idx = bisect.bisect_left(self._sorted, evicted)
            self._sorted.pop(idx)
        self._recent.append(p)
        bisect.insort(self._sorted, p)
        self.observations += 1

        if avgper is None:
            self._threshold = self.default
        else:
            n = len(self._sorted)
            idx = int((1.0 - avgper) * n)  # floor
            idx = max(0, min(n - 1, idx))
            self._threshold = self._sorted[idx]
        return self._threshold

    def observe_many(self, percentages: Iterable[float]) -> list[float]:
        return [self.observe(p) for p in percentages]

    def seed(self, percentages: Iterable[float]) -> "AdaptiveThreshold":
        """Pre-populate PercentList with history before replay starts.

        Models a detector whose history is warm at t=0 — e.g. a
        fleet-scope PercentList shared across I/O servers
        (``FleetSimulator(threshold_scope="fleet")``) where each node
        starts from the global stream history instead of a cold default.
        Windowed instances keep only the last ``window`` entries, exactly
        as if the history had been observed live.
        """

        for p in percentages:
            self.observe(p)
        return self

    # -- queries ----------------------------------------------------------
    @property
    def threshold(self) -> float:
        return self._threshold

    @property
    def avgper(self) -> float:
        return (sum(self._sorted) / len(self._sorted)) if self._sorted else 0.0

    @property
    def percent_list(self) -> tuple[float, ...]:
        """The sorted PercentList (paper's name), read-only view."""

        return tuple(self._sorted)

    def is_random(self, percentage: float) -> bool:
        """Redirection predicate: stream goes to the fast tier iff True."""

        return percentage > self._threshold

    def reset(self) -> None:
        """Empty PercentList (paper: on workload pattern change)."""

        self._recent.clear()
        self._sorted.clear()
        self._threshold = self.default


class StaticWatermarkThreshold:
    """SSDUP's original static scheme (ICS'17) — the paper's baseline.

    High/low watermarks with hysteresis: above ``high`` the traffic is deemed
    random (fast tier), below ``low`` sequential (slow tier), in between the
    previous decision sticks.  Defaults are the paper's 45%/30%.
    """

    def __init__(self, high: float = 0.45, low: float = 0.30):
        if not 0.0 <= low <= high <= 1.0:
            raise ValueError(f"need 0 <= low <= high <= 1, got {low}, {high}")
        self.high = high
        self.low = low
        self._last_random = False
        self.observations = 0

    def observe(self, percentage: float) -> float:
        self.observations += 1
        if percentage > self.high:
            self._last_random = True
        elif percentage < self.low:
            self._last_random = False
        return self.threshold

    def seed(self, percentages: Iterable[float]) -> "StaticWatermarkThreshold":
        """Warm-start counterpart of :meth:`AdaptiveThreshold.seed` — only
        the final hysteresis state survives (watermarks keep no list)."""

        for p in percentages:
            self.observe(p)
        return self

    @property
    def threshold(self) -> float:
        # exposed for symmetric logging: the effective decision boundary
        return self.low if self._last_random else self.high

    def is_random(self, percentage: float) -> bool:
        if percentage > self.high:
            return True
        if percentage < self.low:
            return False
        return self._last_random

    def reset(self) -> None:
        self._last_random = False
