"""Vectorized extent index — the NumPy counterpart of :class:`AVLTree`.

The paper's flush path (Section 2.5) needs three things from the per-file
metadata index: the *latest* log copy of every written offset, those live
extents in ascending-offset order (the sequential flush order), and point
lookups for read-your-writes.  The AVL tree gives all three at
O(log n)/insert — but the simulator's replay loop pays that cost in
*Python*, one pointer-chasing ``insert`` per request, which caps traces at
~10⁵ requests.

:class:`ExtentIndex` stores the same mapping as flat append-only arrays
and defers all ordering work to one vectorized pass:

* ``insert``/``insert_batch`` append to O(1)-amortized columnar buffers —
  no comparisons, no rebalancing, no per-request Python in the batch path;
* a *compaction* (stable ``argsort`` by offset + last-of-run selection,
  i.e. lexsort-style latest-version dedup) runs lazily on first query and
  is cached until the next insert;
* ``in_order`` / ``in_order_arrays`` / ``lookup`` / ``__len__`` /
  ``approx_bytes`` are bit-for-bit equivalent to the AVL tree's answers
  (property-checked in ``tests/test_extent_index.py``), so
  :class:`repro.core.log_store.LogRegion` can swap backends via its
  ``index_backend`` switch without perturbing a single simulator output.

Cost model: n inserts + one compaction is O(n log n) in C versus the
AVL's O(n log n) in Python — ~two orders of magnitude in practice (see
``benchmarks/bench_replay.py``).  The metadata accounting mirrors the
paper's 24 B/node budget on *live* (deduplicated) extents, matching
``AVLTree.approx_bytes``.
"""

from __future__ import annotations

from typing import Iterator

import numpy as np

from .avl import NODE_BYTES, Extent


class ColumnarAppender:
    """Append-only columnar row buffer shared by the vectorized stores.

    Scalar rows buffer into a plain Python list; batch rows land as
    ready-made int64 array chunks.  The pending rows are sealed into a
    chunk before every batch append and before every read, so chunk
    order IS arrival order regardless of how scalar and batch appends
    interleave.  Used by :class:`ExtentIndex` (3 columns) and
    :class:`repro.core.log_store.LogRegion`'s record log (4 columns).
    """

    __slots__ = ("_ncols", "_pend", "_chunks", "_count")

    def __init__(self, ncols: int) -> None:
        self._ncols = ncols
        self._pend: list[tuple[int, ...]] = []
        self._chunks: list[tuple[np.ndarray, ...]] = []
        self._count = 0

    def __len__(self) -> int:
        return self._count

    def append_row(self, row: tuple[int, ...]) -> None:
        self._pend.append(row)
        self._count += 1

    def append_chunk(self, *cols: np.ndarray) -> None:
        """Append many rows given as parallel columns (arrival order =
        array order)."""

        n = len(cols[0])
        if n == 0:
            return
        self._seal()
        self._chunks.append(
            tuple(np.asarray(c, dtype=np.int64) for c in cols)
        )
        self._count += n

    def _seal(self) -> None:
        if self._pend:
            cols = np.asarray(self._pend, dtype=np.int64).T
            self._chunks.append(tuple(cols[i] for i in range(self._ncols)))
            self._pend.clear()

    def columns(self) -> tuple[np.ndarray, ...]:
        """All rows as parallel int64 columns, in arrival order; chunks
        are consolidated once and the result reused until the next
        append."""

        self._seal()
        if not self._chunks:
            return tuple(
                np.zeros(0, dtype=np.int64) for _ in range(self._ncols)
            )
        if len(self._chunks) > 1:
            self._chunks = [tuple(
                np.concatenate([c[i] for c in self._chunks])
                for i in range(self._ncols)
            )]
        return self._chunks[0]

    def last_row(self) -> tuple[int, ...] | None:
        if self._pend:
            return tuple(int(v) for v in self._pend[-1])
        if self._chunks:
            return tuple(int(col[-1]) for col in self._chunks[-1])
        return None

    def clear(self) -> None:
        self._pend.clear()
        self._chunks.clear()
        self._count = 0


class ExtentIndex:
    """Append-only columnar index from original offset to log extent.

    Drop-in alternative to :class:`repro.core.avl.AVLTree`: same insert
    semantics (re-writes of an offset supersede — latest log copy wins),
    same query surface, vectorized internals.
    """

    __slots__ = ("_rows", "_compact")

    def __init__(self) -> None:
        self._rows = ColumnarAppender(3)  # (offset, size, log_offset)
        # cached compaction: (offsets, sizes, log_offsets) — live extents
        # in ascending-offset order
        self._compact: tuple[np.ndarray, np.ndarray, np.ndarray] | None = None

    # -- mutation --------------------------------------------------------
    def insert(self, offset: int, size: int, log_offset: int) -> None:
        """Record one extent; latest version of an offset supersedes."""

        self._rows.append_row((offset, size, log_offset))
        self._compact = None

    def insert_batch(
        self,
        offsets: np.ndarray,
        sizes: np.ndarray,
        log_offsets: np.ndarray,
    ) -> None:
        """Record many extents at once (arrival order = array order)."""

        self._rows.append_chunk(offsets, sizes, log_offsets)
        self._compact = None

    def clear(self) -> None:
        self._rows.clear()
        self._compact = None

    # -- compaction ------------------------------------------------------
    def _compacted(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        if self._compact is not None:
            return self._compact
        offs, szs, logs = self._rows.columns()
        if not len(offs):
            self._compact = (offs, szs, logs)
            return self._compact
        # stable sort by offset keeps arrival order inside equal-offset
        # runs; the LAST entry of each run is the live (latest) version.
        order = np.argsort(offs, kind="stable")
        so = offs[order]
        last = np.empty(len(so), dtype=bool)
        last[:-1] = so[1:] != so[:-1]
        last[-1] = True
        keep = order[last]
        self._compact = (so[last], szs[keep], logs[keep])
        return self._compact

    # -- queries ---------------------------------------------------------
    def __len__(self) -> int:
        return int(self._compacted()[0].shape[0])

    def lookup(self, offset: int) -> Extent | None:
        offs, szs, logs = self._compacted()
        i = int(np.searchsorted(offs, offset))
        if i < len(offs) and int(offs[i]) == offset:
            return Extent(offset, int(szs[i]), int(logs[i]))
        return None

    def in_order(self) -> Iterator[Extent]:
        """Live extents in ascending original-offset order (flush order)."""

        offs, szs, logs = self._compacted()
        for i in range(len(offs)):
            yield Extent(int(offs[i]), int(szs[i]), int(logs[i]))

    def in_order_arrays(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """``(offsets, sizes, log_offsets)`` of the live extents, sorted —
        the zero-Python view the batched flush accounting consumes."""

        return self._compacted()

    def min_key(self) -> int | None:
        offs = self._compacted()[0]
        return int(offs[0]) if len(offs) else None

    def max_key(self) -> int | None:
        offs = self._compacted()[0]
        return int(offs[-1]) if len(offs) else None

    def approx_bytes(self) -> int:
        """Paper's 24 B/node metadata accounting (live extents only)."""

        return len(self) * NODE_BYTES

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"ExtentIndex(live={len(self)})"


INDEX_BACKENDS = ("avl", "numpy")


def make_index(backend: str):
    """Index factory behind ``LogRegion``'s ``index_backend`` switch."""

    if backend == "numpy":
        return ExtentIndex()
    if backend == "avl":
        from .avl import AVLTree

        return AVLTree()
    raise ValueError(
        f"index_backend must be one of {INDEX_BACKENDS}, got {backend!r}"
    )
