"""Random-factor traffic detection (SSDUP+ paper, Section 2.2).

The paper's central metric: group incoming write requests into *request
streams* of ``stream_len`` (default 128, mirroring the CFQ queue depth), sort
the stream by logical offset, and count how many sorted-adjacent request pairs
are *not* contiguous.  Each non-contiguous pair costs one disk-head seek, so

    RF_i = 0  if sorted_offset[i+1] - sorted_offset[i] == size[i]   (merged)
    RF_i = 1  otherwise                                             (one seek)

    S = sum_i RF_i                       (Eq. 1)
    random_percentage = S / (N - 1)      (Section 2.3.1)

The detector works purely on request *metadata* (offset, size, file, app) —
it never touches payload bytes, which is why it is cheap enough to run on the
server side for every stream (paper Table 1 measures <1% overhead).

Two implementations live here:

* a scalar/NumPy path used by the host-side control plane
  (:class:`StreamGrouper`, :func:`random_factor_sum`), and
* a batched ``jnp`` path (:func:`random_factor_batch`) that scores many
  streams at once; it is also the oracle for the Pallas kernel in
  ``repro.kernels.stream_rf``.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator, Sequence

import numpy as np

try:  # the control plane must import even where jax is absent
    import jax.numpy as jnp
except Exception:  # pragma: no cover - jax is installed in this repo
    jnp = None

DEFAULT_STREAM_LEN = 128  # paper: CFQ queue size, Section 2.3.1


@dataclasses.dataclass(frozen=True, slots=True)
class Request:
    """One write request's metadata, as traced by the I/O-node server.

    Mirrors the fields SSDUP+ records in the trove layer (Section 3):
    logical offset, request size, file handle and the issuing application.
    """

    offset: int
    size: int
    file_id: int = 0
    app_id: int = 0
    time: float = 0.0

    @property
    def end(self) -> int:
        return self.offset + self.size


def random_factor_sum(
    offsets: Sequence[int] | np.ndarray,
    sizes: Sequence[int] | np.ndarray | int,
) -> int:
    """Total random factor ``S`` of one stream (paper Eq. 1).

    ``sizes`` may be a scalar (uniform request size, the common IOR case) or a
    per-request array.  Offsets are sorted first — the paper sorts each
    128-request block exactly like the CFQ elevator would, and only then
    counts seeks; adjacent-after-sort contiguity is what matters, not arrival
    order (Fig. 4).
    """

    offs = np.asarray(offsets, dtype=np.int64)
    if offs.size <= 1:
        return 0
    szs = np.broadcast_to(np.asarray(sizes, dtype=np.int64), offs.shape)
    order = np.argsort(offs, kind="stable")
    so = offs[order]
    ss = szs[order]
    gaps = so[1:] - so[:-1]
    return int(np.sum(gaps != ss[:-1]))


def random_percentage(
    offsets: Sequence[int] | np.ndarray,
    sizes: Sequence[int] | np.ndarray | int,
) -> float:
    """``S / (N - 1)`` — the stream's level of randomness in [0, 1]."""

    offs = np.asarray(offsets, dtype=np.int64)
    n = offs.size
    if n <= 1:
        return 0.0
    return random_factor_sum(offs, sizes) / (n - 1)


def random_factor_batch(offsets, sizes):
    """Batched random factor: ``(M, N) -> (M,)`` on device.

    jnp oracle shared with the ``stream_rf`` Pallas kernel.  Sorting uses
    ``jnp.sort``; the seek count compares sorted-adjacent gaps against the
    size carried by the *lower-offset* request of each pair (requests are
    sorted together with their sizes).
    """

    offs = jnp.asarray(offsets, dtype=jnp.int32)
    szs = jnp.broadcast_to(jnp.asarray(sizes, dtype=jnp.int32), offs.shape)
    order = jnp.argsort(offs, axis=-1, stable=True)
    so = jnp.take_along_axis(offs, order, axis=-1)
    ss = jnp.take_along_axis(szs, order, axis=-1)
    gaps = so[..., 1:] - so[..., :-1]
    return jnp.sum((gaps != ss[..., :-1]).astype(jnp.int32), axis=-1)


def random_percentage_batch(offsets, sizes):
    """Batched ``S/(N-1)`` with float32 output."""

    offs = jnp.asarray(offsets)
    n = offs.shape[-1]
    s = random_factor_batch(offs, sizes)
    return s.astype(jnp.float32) / max(n - 1, 1)


def seek_distance_batch(offsets, sizes):
    """Batched sorted seek distance: ``(M, N) -> (M,)`` on device.

    Same definition as :func:`sorted_seek_distance` — total |gap - size|
    over sorted-adjacent pairs; see :func:`stream_stats_batch` for the
    dtype caveats.
    """

    return stream_stats_batch(offsets, sizes)[2]


def stream_stats_batch(offsets, sizes):
    """All three per-stream statistics in one device call.

    ``(M, N)`` offsets/sizes -> ``(rf_sum (M,), percentage (M,),
    seek_distance (M,))``.  One sort feeds both the Eq. 1 seek count and
    the seek-distance aggregate; this is the jnp oracle for the
    ``stream_rf`` Pallas kernel and the device fast path behind
    :func:`repro.core.trace.compute_stream_scores`.

    Dtypes: offsets/sizes ride int32 lanes (jax's default integer width
    here), so per-request values must fit below 2 GiB; the seek-distance
    *sum* can exceed int32 even then (127 residuals of up to 2 GiB), so
    it is accumulated in float32 — overflow-safe, with ~1e-7 relative
    rounding above 16 MiB totals (irrelevant to the timing model, which
    multiplies by seconds-per-byte).  The host path
    (:func:`stream_stats_batch_np`) is the full-range int64 exact oracle.
    """

    offs = jnp.asarray(offsets, dtype=jnp.int32)
    szs = jnp.broadcast_to(jnp.asarray(sizes, dtype=jnp.int32), offs.shape)
    n = offs.shape[-1]
    order = jnp.argsort(offs, axis=-1, stable=True)
    so = jnp.take_along_axis(offs, order, axis=-1)
    ss = jnp.take_along_axis(szs, order, axis=-1)
    resid = so[..., 1:] - so[..., :-1] - ss[..., :-1]
    rf = jnp.sum((resid != 0).astype(jnp.int32), axis=-1)
    pct = rf.astype(jnp.float32) / max(n - 1, 1)
    dist = jnp.sum(jnp.abs(resid).astype(jnp.float32), axis=-1)
    return rf, pct, dist


def stream_stats_batch64(offsets, sizes):
    """Exact int64/float64 device scoring — bit-equal to the numpy oracle.

    Same math as :func:`stream_stats_batch`, run under a scoped
    ``jax.experimental.enable_x64`` so offsets/sizes ride true int64 lanes
    and the percentage divides in float64.  This removes BOTH device-dtype
    caveats: offsets above 2 GiB no longer truncate, and the seek-distance
    sum accumulates as int64 with no float32 rounding.  ``(M, N)`` ->
    ``(rf int64, percentage float64, seek_distance int64)``.

    The scope is per-call: the global jax x64 flag is untouched, so f32
    kernels elsewhere in the process are unaffected.
    """

    from jax.experimental import enable_x64

    with enable_x64():
        offs = jnp.asarray(np.asarray(offsets, dtype=np.int64))
        szs = jnp.broadcast_to(
            jnp.asarray(np.asarray(sizes, dtype=np.int64)), offs.shape)
        n = offs.shape[-1]
        order = jnp.argsort(offs, axis=-1, stable=True)
        so = jnp.take_along_axis(offs, order, axis=-1)
        ss = jnp.take_along_axis(szs, order, axis=-1)
        resid = so[..., 1:] - so[..., :-1] - ss[..., :-1]
        rf = jnp.sum((resid != 0).astype(jnp.int64), axis=-1)
        pct = rf.astype(jnp.float64) / max(n - 1, 1)
        dist = jnp.sum(jnp.abs(resid), axis=-1)
        return rf, pct, dist


def stream_stats_batch_np(offsets, sizes):
    """Vectorized host-side scoring of many streams at once (int64, exact).

    ``(M, N)`` -> ``(rf_sum int64, percentage float64, seek_distance
    int64)``, each ``(M,)``.  Bit-for-bit equal to looping the scalar
    :func:`random_factor_sum` / :func:`random_percentage` /
    :func:`sorted_seek_distance` over the rows — the fleet simulator's
    default scoring path and the correctness oracle for the device
    backends.
    """

    offs = np.asarray(offsets, dtype=np.int64)
    szs = np.broadcast_to(np.asarray(sizes, dtype=np.int64), offs.shape)
    m, n = offs.shape
    if n <= 1:
        z = np.zeros(m, dtype=np.int64)
        return z, np.zeros(m, dtype=np.float64), z.copy()
    order = np.argsort(offs, axis=-1, kind="stable")
    so = np.take_along_axis(offs, order, axis=-1)
    ss = np.take_along_axis(szs, order, axis=-1)
    resid = so[:, 1:] - so[:, :-1] - ss[:, :-1]
    rf = np.count_nonzero(resid, axis=-1).astype(np.int64)
    pct = rf / (n - 1)
    dist = np.abs(resid).sum(axis=-1)
    return rf, pct, dist


class StreamGrouper:
    """Groups an arriving request sequence into fixed-length streams.

    The paper's server groups requests in arrival order into blocks of
    ``stream_len`` (Section 2.1: "SSDUP+ groups the requests into blocks...
    also called a request stream").  A trailing partial stream can be flushed
    explicitly at end-of-trace.
    """

    def __init__(self, stream_len: int = DEFAULT_STREAM_LEN):
        if stream_len < 2:
            raise ValueError(f"stream_len must be >= 2, got {stream_len}")
        self.stream_len = stream_len
        self._pending: list[Request] = []
        self.streams_emitted = 0

    def push(self, req: Request) -> list[Request] | None:
        """Add one request; returns a full stream when one completes."""

        self._pending.append(req)
        if len(self._pending) >= self.stream_len:
            stream, self._pending = self._pending, []
            self.streams_emitted += 1
            return stream
        return None

    def push_many(self, reqs: Iterable[Request]) -> Iterator[list[Request]]:
        for r in reqs:
            out = self.push(r)
            if out is not None:
                yield out

    def flush(self) -> list[Request] | None:
        """Emit the trailing partial stream (end of trace / app barrier)."""

        if not self._pending:
            return None
        stream, self._pending = self._pending, []
        self.streams_emitted += 1
        return stream

    @property
    def pending(self) -> int:
        return len(self._pending)


def stream_percentage(stream: Sequence[Request]) -> float:
    """Random percentage of a list of :class:`Request`."""

    if len(stream) <= 1:
        return 0.0
    offs = np.fromiter((r.offset for r in stream), dtype=np.int64, count=len(stream))
    szs = np.fromiter((r.size for r in stream), dtype=np.int64, count=len(stream))
    return random_percentage(offs, szs)


def seek_distance_np(
    offsets: Sequence[int] | np.ndarray, sizes: Sequence[int] | np.ndarray
) -> int:
    """Sorted seek distance of one stream given as plain arrays (int64,
    exact) — the array-native form of :func:`sorted_seek_distance`, used
    by the batched replay engine for overflow subsets that have no
    precomputed score."""

    offs = np.asarray(offsets, dtype=np.int64)
    if offs.size <= 1:
        return 0
    szs = np.asarray(sizes, dtype=np.int64)
    order = np.argsort(offs, kind="stable")
    so, ss = offs[order], szs[order]
    gaps = so[1:] - so[:-1] - ss[:-1]
    return int(np.abs(gaps[gaps != 0]).sum())


def sorted_seek_distance(stream: Sequence[Request]) -> int:
    """Total logical seek distance after sorting (used by the HDD model).

    The paper argues seek time is roughly linear in logical-offset distance
    (Section 2.2, citing FS2); the device model consumes this aggregate.
    """

    if len(stream) <= 1:
        return 0
    offs = np.fromiter((r.offset for r in stream), dtype=np.int64, count=len(stream))
    szs = np.fromiter((r.size for r in stream), dtype=np.int64, count=len(stream))
    return seek_distance_np(offs, szs)
