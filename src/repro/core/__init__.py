"""SSDUP+ core: traffic-aware burst buffering (the paper's contribution).

Layering:

* detection     — :mod:`repro.core.random_factor` (random factor, Eq. 1)
* policy        — :mod:`repro.core.adaptive` (Eq. 2/3 adaptive threshold)
* routing       — :mod:`repro.core.redirector` (Algorithm 1)
* buffering     — :mod:`repro.core.log_store` with two index backends:
                  :mod:`repro.core.avl` (oracle) and
                  :mod:`repro.core.extent_index` (vectorized) (§2.5)
* pipelining    — :mod:`repro.core.pipeline` (two-region + traffic-aware,
                  Eq. 6 flush costing, §2.4)
* timing model  — :mod:`repro.core.device_model`, :mod:`repro.core.simulator`
                  (batched + per-request replay engines)
* workloads     — :mod:`repro.core.workloads` (IOR/HPIO/MPI-Tile-IO)
* production IO — :mod:`repro.core.burst_buffer` (real-byte facade used by
                  the checkpoint path)
* trace batch   — :mod:`repro.core.trace` (struct-of-arrays traces +
                  vectorized per-stream scoring)
* device engine — :mod:`repro.core.engine_device` (the batched engine's
                  state transition as a jitted scan/vmap array program)
* fleet         — :mod:`repro.core.fleet` (multi-node sharded replay,
                  paper's aggregate evaluation scaled to N nodes)
"""

from .adaptive import AdaptiveThreshold, StaticWatermarkThreshold
from .avl import AVLTree, Extent
from .burst_buffer import BurstBufferWriter
from .device_model import (
    STORAGE_BACKENDS,
    HDDModel,
    InterferenceModel,
    SSDModel,
    StorageModel,
    clone_storage,
    make_storage_model,
)
from .ftl import FTLModel
from .extent_index import INDEX_BACKENDS, ExtentIndex, make_index
from .log_store import LogRegion, RegionFullError
from .pipeline import FlushState, SingleRegionBuffer, TwoRegionPipeline
from .random_factor import (
    DEFAULT_STREAM_LEN,
    Request,
    StreamGrouper,
    random_factor_batch,
    random_factor_sum,
    random_percentage,
    random_percentage_batch,
    stream_percentage,
)
from .redirector import DataRedirector, Device, RoutedStream
from .simulator import Gap, IONodeSimulator, SimResult, run_schemes
from .trace import StreamScores, TraceBatch, compute_stream_scores
from .fleet import FleetProgram, FleetResult, FleetSimulator, run_fleet_schemes
from .workloads import (
    Workload,
    checkpoint_wave,
    hpio,
    ior,
    mixed,
    mpi_tile_io,
    relabel,
)

__all__ = [
    "AdaptiveThreshold",
    "StaticWatermarkThreshold",
    "AVLTree",
    "Extent",
    "ExtentIndex",
    "INDEX_BACKENDS",
    "make_index",
    "BurstBufferWriter",
    "HDDModel",
    "SSDModel",
    "StorageModel",
    "FTLModel",
    "STORAGE_BACKENDS",
    "make_storage_model",
    "clone_storage",
    "InterferenceModel",
    "LogRegion",
    "RegionFullError",
    "FlushState",
    "TwoRegionPipeline",
    "SingleRegionBuffer",
    "DEFAULT_STREAM_LEN",
    "Request",
    "StreamGrouper",
    "random_factor_sum",
    "random_percentage",
    "random_factor_batch",
    "random_percentage_batch",
    "stream_percentage",
    "DataRedirector",
    "Device",
    "RoutedStream",
    "Gap",
    "IONodeSimulator",
    "SimResult",
    "run_schemes",
    "StreamScores",
    "TraceBatch",
    "compute_stream_scores",
    "FleetProgram",
    "FleetResult",
    "FleetSimulator",
    "run_fleet_schemes",
    "Workload",
    "checkpoint_wave",
    "ior",
    "hpio",
    "mpi_tile_io",
    "mixed",
    "relabel",
]
