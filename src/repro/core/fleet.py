"""Multi-node fleet simulator (the paper's testbed, scaled out).

The paper evaluates SSDUP+ on an OrangeFS deployment with multiple I/O
nodes and reports *aggregate* throughput (Fig. 6/8/11 are 2-node
aggregates).  The seed repo could only replay a trace against one node;
this module shards a server-side arrival trace across N I/O nodes and
replays each shard through :class:`repro.core.simulator.IONodeSimulator`,
with all per-stream scoring done up front in one vectorized pass
(:func:`repro.core.trace.compute_stream_scores`) instead of per-stream
NumPy calls inside the replay loop.

Sharding policies come from :mod:`repro.distributed.sharding`
(``round-robin-app``, ``hash-file``, ``range-offset``) — each is a pure
``request -> node`` assignment, so the shards partition the trace exactly
(no byte is dropped or duplicated) and compute gaps are replicated to
every node (a compute phase idles the whole fleet).

Aggregation matches the paper's accounting: the fleet's I/O time is the
**straggler's** (apps block on their slowest I/O server), aggregate
throughput is total bytes over that time, and ``load_imbalance`` is
max-over-mean node bytes (1.0 = perfectly balanced).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.distributed.sharding import TRACE_POLICIES, assign_nodes

from .device_model import clone_storage, make_storage_model
from .random_factor import DEFAULT_STREAM_LEN
from ..analysis import sanitize as _sanitize
from .simulator import IONodeSimulator, SimResult
from .trace import (
    SCORE_BACKENDS,
    TraceBatch,
    TraceItem,
    compute_stream_scores,
)


@dataclasses.dataclass(frozen=True)
class FleetResult:
    """Aggregate of one fleet replay: per-node results + fleet metrics."""

    scheme: str
    policy: str
    num_nodes: int
    node_results: tuple[SimResult, ...]

    # -- fleet-level accounting ----------------------------------------
    @property
    def total_bytes(self) -> int:
        return sum(r.total_bytes for r in self.node_results)

    @property
    def bytes_to_ssd(self) -> int:
        return sum(r.bytes_to_ssd for r in self.node_results)

    @property
    def bytes_to_hdd_direct(self) -> int:
        return sum(r.bytes_to_hdd_direct for r in self.node_results)

    @property
    def ssd_byte_ratio(self) -> float:
        return self.bytes_to_ssd / self.total_bytes if self.total_bytes else 0.0

    @property
    def io_seconds(self) -> float:
        """Fleet I/O time = the straggler node's I/O time."""

        return max((r.io_seconds for r in self.node_results), default=0.0)

    @property
    def total_seconds(self) -> float:
        return max((r.total_seconds for r in self.node_results), default=0.0)

    @property
    def straggler(self) -> int:
        """Index of the node whose I/O time bounds the fleet."""

        secs = [r.io_seconds for r in self.node_results]
        return int(np.argmax(secs)) if secs else 0

    @property
    def throughput_mbs(self) -> float:
        """Aggregate fleet throughput (bytes over straggler time)."""

        t = self.io_seconds
        return self.total_bytes / t / 1e6 if t else 0.0

    @property
    def node_throughputs_mbs(self) -> tuple[float, ...]:
        return tuple(r.throughput_mbs for r in self.node_results)

    @property
    def node_bytes(self) -> tuple[int, ...]:
        return tuple(r.total_bytes for r in self.node_results)

    @property
    def load_imbalance(self) -> float:
        """max / mean of per-node byte loads; 1.0 = perfectly balanced."""

        if not self.node_results or not self.total_bytes:
            return 1.0
        loads = np.asarray(self.node_bytes, dtype=np.float64)
        return float(loads.max() / loads.mean())


class FleetSimulator:
    """Shard one arrival trace over N I/O nodes and replay each shard.

    Parameters mirror :class:`IONodeSimulator` (``node_kwargs`` are passed
    through to every node — ``ssd_capacity`` is *per node*), plus:

    num_nodes:
        Fleet size.
    policy:
        Trace-sharding policy name from
        :data:`repro.distributed.sharding.TRACE_POLICIES`.
    score_backend:
        Backend for the up-front batched stream scoring: ``"numpy"``
        (exact, default), ``"jnp"``, or ``"pallas"``.
    threshold_scope:
        ``"node"`` (default): every node's detector starts cold and only
        ever observes its own shard's streams — the deployment where each
        I/O server runs an independent SSDUP+ daemon.  ``"fleet"``: each
        node's PercentList is warm-started with the *global* trace's
        stream-percentage history (in arrival order) before replay,
        modeling a fleet-scope detector whose history is shared across
        servers.  During replay each node still evolves independently;
        live cross-node coupling would need a merged arrival timeline.
        Used by ``experiments/anomaly_hunt.py`` to separate per-shard
        threshold-state effects from trace-composition effects.
    """

    def __init__(
        self,
        num_nodes: int = 2,
        scheme: str = "ssdup+",
        policy: str = "round-robin-app",
        stream_len: int = DEFAULT_STREAM_LEN,
        score_backend: str = "numpy",
        threshold_scope: str = "node",
        **node_kwargs,
    ):
        if num_nodes < 1:
            raise ValueError(f"num_nodes must be >= 1, got {num_nodes}")
        if threshold_scope not in ("node", "fleet"):
            raise ValueError(
                f"threshold_scope must be 'node' or 'fleet', "
                f"got {threshold_scope!r}"
            )
        if threshold_scope == "fleet" and "threshold_warmup" in node_kwargs:
            raise ValueError(
                "threshold_scope='fleet' derives each node's "
                "threshold_warmup from the global trace; passing an "
                "explicit threshold_warmup is ambiguous"
            )
        if policy not in TRACE_POLICIES:
            raise ValueError(
                f"unknown policy {policy!r}; choose from {sorted(TRACE_POLICIES)}"
            )
        if score_backend not in SCORE_BACKENDS:
            raise ValueError(
                f"score_backend must be one of {SCORE_BACKENDS}, "
                f"got {score_backend!r}"
            )
        self.num_nodes = num_nodes
        self.scheme = scheme
        self.policy = policy
        self.stream_len = stream_len
        self.score_backend = score_backend
        self.threshold_scope = threshold_scope
        self.node_kwargs = node_kwargs

    # ------------------------------------------------------------------
    def assignment(self, batch: TraceBatch) -> np.ndarray:
        """Per-request node assignment under the policy.

        Exposed separately from :meth:`shard` so the online service layer
        (:mod:`repro.service`) can release arriving requests to exactly
        the lanes the offline simulator would use — the precondition for
        a no-fault service run being bit-identical to :meth:`run`.
        """

        return assign_nodes(
            self.policy, batch.offsets, batch.file_ids, batch.app_ids,
            self.num_nodes,
        )

    def shard(self, batch: TraceBatch) -> list[TraceBatch]:
        """Partition a batch into per-node sub-batches under the policy."""

        return batch.shard(self.assignment(batch), self.num_nodes)

    def run(self, trace: TraceBatch | Sequence[TraceItem]) -> FleetResult:
        """Shard ``trace`` and replay every node with the per-node engine.

        Accuracy contract: inherits the node engine's — bit-identical to
        the per-request oracle for the numpy engines, ``DEVICE_TOLERANCES``
        tiers for ``engine="device"``; aggregation is deterministic
        (nodes reduced in index order).
        """

        batch = (
            trace if isinstance(trace, TraceBatch) else TraceBatch.from_items(trace)
        )
        shards = self.shard(batch)
        if _sanitize.resolve(self.node_kwargs.get("sanitize")):
            # sharding must conserve the trace: every request lands on
            # exactly one node
            n_req = sum(s.num_requests for s in shards)
            _sanitize.check(
                n_req == batch.num_requests,
                "sharding dropped/duplicated requests: %d across shards "
                "vs %d offered", n_req, batch.num_requests,
            )
            n_bytes = sum(s.total_bytes for s in shards)
            _sanitize.check(
                n_bytes == batch.total_bytes,
                "sharding dropped/duplicated bytes: %d across shards "
                "vs %d offered", n_bytes, batch.total_bytes,
            )
        node_kwargs = dict(self.node_kwargs)
        if self.threshold_scope == "fleet" and self.scheme in ("ssdup",
                                                               "ssdup+"):
            global_scores = compute_stream_scores(
                batch, self.stream_len, backend=self.score_backend
            )
            node_kwargs["threshold_warmup"] = tuple(
                float(p) for p in global_scores.percentage
            )
        results = []
        for shard in shards:
            scores = compute_stream_scores(
                shard, self.stream_len, backend=self.score_backend
            )
            kw = node_kwargs
            if "ssd" in kw:
                # stateful storage (FTL) must never share mapping state
                # across nodes — each I/O server has its own device
                kw = dict(kw)
                kw["ssd"] = clone_storage(kw["ssd"])
            node = IONodeSimulator(
                scheme=self.scheme, stream_len=self.stream_len,
                **kw,
            )
            # shards stay columnar end-to-end: the batched replay engine
            # consumes the TraceBatch directly (no item materialization)
            results.append(node.run(shard, scores=scores))
        return FleetResult(
            scheme=self.scheme,
            policy=self.policy,
            num_nodes=self.num_nodes,
            node_results=tuple(results),
        )


class FleetProgram:
    """One jitted device sweep over the whole shard matrix.

    Where :class:`FleetSimulator` loops Python over nodes (and callers
    loop over schemes), ``FleetProgram`` lowers every shard to an event
    tape ONCE (tapes are scheme-independent), stacks one lane per
    ``scheme × node`` combination, and replays all of them in a single
    ``jit(scan(vmap(step)))`` device call through
    :mod:`repro.core.engine_device`.  A 64-node × 4-scheme sweep is one
    XLA executable launch instead of 256 Python replays.

    Results carry the device engine's documented tolerances
    (:data:`repro.core.engine_device.DEVICE_TOLERANCES`) vs the numpy
    engines; see ``benchmarks/bench_device_replay.py`` for the speedup
    this buys.

    Parameters mirror :class:`FleetSimulator` /
    :class:`IONodeSimulator`; ``ssd_capacity`` is per node.
    """

    def __init__(
        self,
        num_nodes: int = 2,
        schemes: Sequence[str] = (
            "orangefs", "orangefs-bb", "ssdup", "ssdup+",
        ),
        policy: str = "round-robin-app",
        stream_len: int = DEFAULT_STREAM_LEN,
        score_backend: str = "numpy",
        ssd_capacity: int = 8 << 30,
        hdd=None,
        ssd=None,
        link=None,
        interference=None,
        flush_gate: float | str = 0.5,
        adaptive_window: int = 64,
        threshold_warmup: Sequence[float] | None = None,
    ):
        from . import engine_device  # deferred: needs jax at run time

        if num_nodes < 1:
            raise ValueError(f"num_nodes must be >= 1, got {num_nodes}")
        if policy not in TRACE_POLICIES:
            raise ValueError(
                f"unknown policy {policy!r}; choose from "
                f"{sorted(TRACE_POLICIES)}"
            )
        if score_backend not in SCORE_BACKENDS:
            raise ValueError(
                f"score_backend must be one of {SCORE_BACKENDS}, "
                f"got {score_backend!r}"
            )
        unknown = [s for s in schemes if s not in engine_device.SCHEME_IDS]
        if unknown:
            raise ValueError(f"unknown schemes {unknown}")
        self.num_nodes = num_nodes
        self.schemes = tuple(schemes)
        self.policy = policy
        self.stream_len = stream_len
        self.score_backend = score_backend
        self.ssd_capacity = ssd_capacity
        self.hdd = hdd
        # resolve ssd= specs ("constant"/"ftl"/instance) once; every lane
        # shares the template's geometry but carries its own FTL columns
        # in the lane state, so one resolved model serves the whole sweep
        self.ssd = (
            make_storage_model(ssd, logical_bytes=ssd_capacity)
            if isinstance(ssd, str) else ssd
        )
        self.link = link
        self.interference = interference
        self.flush_gate = flush_gate
        self.adaptive_window = adaptive_window
        self.threshold_warmup = threshold_warmup
        self._ed = engine_device
        # tapes are scheme-independent and pure functions of the trace:
        # repeat sweeps of the same TraceBatch (parameter studies, the
        # steady-state benchmark) reuse them instead of re-sharding and
        # re-scoring. Keyed by object identity with a liveness anchor so
        # a recycled id can never alias a different trace.
        self._tape_cache: tuple[int, TraceBatch, list, list, list] | None = None

    # ------------------------------------------------------------------
    def shard(self, batch: TraceBatch) -> list[TraceBatch]:
        assignment = assign_nodes(
            self.policy, batch.offsets, batch.file_ids, batch.app_ids,
            self.num_nodes,
        )
        return batch.shard(assignment, self.num_nodes)

    def run(
        self, trace: TraceBatch | Sequence[TraceItem]
    ) -> dict[str, FleetResult]:
        """Replay every ``scheme × node`` lane in one device call.

        Accuracy contract: each lane matches the device engine's
        ``DEVICE_TOLERANCES`` tiers against the batched numpy oracle.
        """

        ed = self._ed
        batch = (
            trace if isinstance(trace, TraceBatch)
            else TraceBatch.from_items(trace)
        )
        cached = self._tape_cache
        if cached is not None and cached[0] == id(batch) and cached[1] is batch:
            _, _, shards, tapes, per_app = cached
        else:
            shards = self.shard(batch)
            tapes = [
                ed.build_events(
                    shard,
                    compute_stream_scores(
                        shard, self.stream_len, backend=self.score_backend
                    ),
                    stream_len=self.stream_len,
                    hdd=self.hdd, ssd=self.ssd, link=self.link,
                )
                for shard in shards
            ]
            per_app = [ed.per_app_bytes(shard) for shard in shards]
            self._tape_cache = (id(batch), batch, shards, tapes, per_app)
        # lane order is scheme-major: lane s * N + n replays shard n
        # under scheme s (every scheme reuses the same N tapes)
        events = ed.stack_events(
            [tapes[n] for _ in self.schemes for n in range(self.num_nodes)]
        )
        lanes = ed._stack_lanes([
            ed.lane_consts(
                s, self.ssd_capacity, self.flush_gate, ssd=self.ssd
            )
            for s in self.schemes
            for _ in range(self.num_nodes)
        ])
        state0 = ed._stack_lanes([
            ed.initial_lane_state(
                s, self.adaptive_window, self.threshold_warmup,
                ssd=self.ssd,
            )
            for s in self.schemes
            for _ in range(self.num_nodes)
        ])
        out = ed.replay_lanes(
            events, lanes, state0,
            hdd=self.hdd, interference=self.interference,
        )
        results: dict[str, FleetResult] = {}
        for si, scheme in enumerate(self.schemes):
            nodes = []
            for n in range(self.num_nodes):
                i = si * self.num_nodes + n
                b_ssd = int(out["bytes_to_ssd"][i])
                b_hdd = int(out["bytes_to_hdd_direct"][i])
                nodes.append(SimResult(
                    scheme=scheme,
                    io_seconds=float(out["io_seconds"][i]),
                    total_seconds=float(out["total_seconds"][i]),
                    total_bytes=b_ssd + b_hdd,
                    bytes_to_ssd=b_ssd,
                    bytes_to_hdd_direct=b_hdd,
                    flushes=int(out["flushes"][i]),
                    flush_paused_seconds=float(
                        out["flush_paused_seconds"][i]
                    ),
                    blocked_seconds=float(out["blocked_seconds"][i]),
                    peak_ssd_occupancy=int(out["peak_ssd_occupancy"][i]),
                    metadata_bytes=0,
                    per_app_bytes=per_app[n],
                ))
            results[scheme] = FleetResult(
                scheme=scheme,
                policy=self.policy,
                num_nodes=self.num_nodes,
                node_results=tuple(nodes),
            )
        return results


def run_fleet_schemes(
    trace: TraceBatch | Sequence[TraceItem],
    num_nodes: int = 2,
    schemes: Sequence[str] = ("orangefs", "orangefs-bb", "ssdup", "ssdup+"),
    policy: str = "round-robin-app",
    **kwargs,
) -> dict[str, FleetResult]:
    """Fleet counterpart of :func:`repro.core.simulator.run_schemes`.

    Accuracy contract: same as :meth:`FleetSimulator.run` — bit-identical
    to the per-request oracle on numpy engines, ``DEVICE_TOLERANCES``
    tiers on the device engine.
    """

    batch = trace if isinstance(trace, TraceBatch) else TraceBatch.from_items(trace)
    return {
        s: FleetSimulator(
            num_nodes=num_nodes, scheme=s, policy=policy, **kwargs
        ).run(batch)
        for s in schemes
    }
