"""HPC I/O access-pattern generators (paper Sections 2.2, 4.2-4.4).

Synthesizes the request traces the paper's benchmarks produce at the I/O
node: IOR's segmented-contiguous / segmented-random / strided patterns, HPIO
region workloads, and MPI-Tile-IO 2-D tile access, plus mixed multi-app
loads.  A trace is a time-ordered list of :class:`Request` as the server
would observe it.

Arrival model: each process issues its own ordered request sequence; the
server-side arrival order merges these per-process sequences with a
*progress skew* — processes drift apart by a random walk whose magnitude
grows with contention (more processes ⇒ more drift).  This is the mechanism
the paper observes (Fig. 2/6): strided traffic looks nearly sequential after
CFQ sorting at 8 processes (7% random percentage) but 71% random at 128
processes, because a 128-request window no longer covers aligned iteration
ranges from all processes.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Sequence

import numpy as np

from .random_factor import Request

KiB = 1024
MiB = 1024 * KiB
GiB = 1024 * MiB

DEFAULT_REQUEST = 256 * KiB


# ---------------------------------------------------------------------------
# per-process offset sequences
# ---------------------------------------------------------------------------

def _segmented_contiguous_offsets(nproc: int, total: int, req: int) -> list[np.ndarray]:
    """Each process writes its 1/n segment of the shared file sequentially."""

    per = total // nproc
    nreq = per // req
    return [np.arange(nreq, dtype=np.int64) * req + p * per for p in range(nproc)]


def _segmented_random_offsets(
    nproc: int, total: int, req: int, rng: np.random.Generator
) -> list[np.ndarray]:
    """Segments as above but each process permutes its request order."""

    seqs = _segmented_contiguous_offsets(nproc, total, req)
    return [rng.permutation(s) for s in seqs]


def _strided_offsets(nproc: int, total: int, req: int) -> list[np.ndarray]:
    """Iteration i, process j touches offset (i*n + j) * req (paper §2.2)."""

    iters = total // (req * nproc)
    return [
        (np.arange(iters, dtype=np.int64) * nproc + j) * req for j in range(nproc)
    ]


# ---------------------------------------------------------------------------
# server-side arrival merge
# ---------------------------------------------------------------------------

def merge_arrivals(
    per_proc: Sequence[np.ndarray],
    req: int,
    rng: np.random.Generator,
    skew: float = 0.0,
    app_id: int = 0,
    file_id: int = 0,
    start_time: float = 0.0,
    dt: float = 1e-4,
) -> list[Request]:
    """Merge per-process sequences into one arrival-ordered trace.

    ``skew`` is the standard deviation (in requests) of each process's
    progress drift, modeled as a reflected Gaussian random walk on the
    virtual clock of each request.  skew=0 is a perfect round-robin.
    """

    items: list[tuple[float, int, int]] = []  # (virtual time, proc, offset)
    for p, offs in enumerate(per_proc):
        n = len(offs)
        if n == 0:
            continue
        base = np.arange(n, dtype=np.float64)
        if skew > 0:
            # STATIONARY progress skew: each process runs a constant offset
            # ahead/behind (steady-state contention), plus light per-request
            # jitter.  A cumulative random walk would make the randomness
            # ramp within the run, which the paper's traces don't show.
            base = base + rng.normal(0.0, skew) + rng.normal(0.0, skew * 0.2, n)
        phase = rng.uniform(0, 1) if skew > 0 else p / max(len(per_proc), 1)
        for i in range(n):
            items.append((base[i] + phase, p, int(offs[i])))
    items.sort(key=lambda t: (t[0], t[1]))
    return [
        Request(offset=off, size=req, file_id=file_id, app_id=app_id,
                time=start_time + k * dt)
        for k, (_, _p, off) in enumerate(items)
    ]


def contention_skew(nproc: int, base: float = 0.35) -> float:
    """Progress-drift magnitude as a function of process count.

    Calibrated so strided IOR reproduces the paper's Fig. 6 random
    percentages (7%, 15%, 28%, 46%, 71% at n = 8..128); the drift grows
    linearly with contention.
    """

    return base * nproc


# ---------------------------------------------------------------------------
# public workload constructors
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    trace: tuple[Request, ...]
    total_bytes: int
    nproc: int

    def __len__(self) -> int:
        return len(self.trace)


def ior(
    pattern: str,
    nproc: int,
    total_bytes: int = 16 * GiB,
    request_size: int = DEFAULT_REQUEST,
    seed: int = 0,
    app_id: int = 0,
    file_id: int = 0,
    skew: float | None = None,
) -> Workload:
    """IOR trace with one of the paper's three access patterns."""

    rng = np.random.default_rng(seed)
    if pattern == "segmented-contiguous":
        # The run-interleaving of n sequential writers is structural: a
        # sorted 128-window holds ~n runs, RP ≈ (n-1)/127, which reproduces
        # the paper's Fig. 5a measurement (RF = 15 at 16 processes) exactly.
        # Drift barely matters; keep a gentle 0.25x.
        eff_skew = (contention_skew(nproc) * 0.25) if skew is None else skew
        seqs = _segmented_contiguous_offsets(nproc, total_bytes, request_size)
    elif pattern == "segmented-random":
        eff_skew = contention_skew(nproc) if skew is None else skew
        seqs = _segmented_random_offsets(nproc, total_bytes, request_size, rng)
    elif pattern == "strided":
        # Calibrated against paper Fig. 6 (7/15/28/46/71% RP at n=8..128):
        # a stationary per-process progress offset of ~1 request reproduces
        # the curve (measured 6/12/25/55/74), nearly independent of n.
        eff_skew = 1.0 if skew is None else skew
        seqs = _strided_offsets(nproc, total_bytes, request_size)
    else:
        raise ValueError(f"unknown IOR pattern: {pattern}")
    trace = merge_arrivals(seqs, request_size, rng, skew=eff_skew,
                           app_id=app_id, file_id=file_id)
    return Workload(f"ior-{pattern}-{nproc}p", tuple(trace),
                    len(trace) * request_size, nproc)


def hpio(
    contiguous: bool,
    nproc: int = 32,
    region_size: int = 64 * KiB,
    region_count: int | None = None,
    region_spacing: int = 0,
    total_bytes: int = 8 * GiB,
    seed: int = 0,
    app_id: int = 0,
    file_id: int = 0,
) -> Workload:
    """HPIO-style trace (paper Section 4.3).

    ``contiguous`` maps the paper's c-c (non-contiguous test array 1000) vs
    c-nc (0010) instances: contiguous packs regions back-to-back per process;
    non-contiguous spaces them by ``nproc`` regions (strided layout).
    """

    rng = np.random.default_rng(seed)
    if region_count is None:
        region_count = max(total_bytes // (region_size * nproc), 1)
    seqs = []
    for p in range(nproc):
        idx = np.arange(region_count, dtype=np.int64)
        if contiguous:
            base = p * region_count * (region_size + region_spacing)
            offs = base + idx * (region_size + region_spacing)
        else:
            offs = (idx * nproc + p) * (region_size + region_spacing)
        seqs.append(offs)
    skew = contention_skew(nproc) * (0.25 if contiguous else 1.0)
    trace = merge_arrivals(seqs, region_size, rng, skew=skew, app_id=app_id,
                           file_id=file_id)
    return Workload(
        f"hpio-{'cc' if contiguous else 'cnc'}-{region_size//KiB}k",
        tuple(trace), len(trace) * region_size, nproc,
    )


def mpi_tile_io(
    nproc: int,
    one_dimensional: bool,
    element_size: int = 4 * KiB,
    total_bytes: int = 16 * GiB,
    seed: int = 0,
    app_id: int = 0,
    file_id: int = 0,
) -> Workload:
    """MPI-Tile-IO trace (paper Section 4.4).

    1-D instance: process grid 1 x n — each tile is a contiguous slab.
    2-D instance: grid sqrt(n) x (n/sqrt(n)) — each row of a tile is one
    request, strided by the full row length of the global array.
    """

    rng = np.random.default_rng(seed)
    if one_dimensional:
        px, py = 1, nproc
    else:
        px = int(math.sqrt(nproc))
        while nproc % px:
            px -= 1
        py = nproc // px

    elems_total = total_bytes // element_size
    tile_elems = max(elems_total // nproc, 1)
    tile_x = max(int(math.sqrt(tile_elems)), 1)  # elements per tile row
    tile_y = max(tile_elems // tile_x, 1)
    row_len = px * tile_x * element_size  # global array row in bytes

    seqs = []
    for p in range(nproc):
        gx, gy = p % px, p // px
        rows = np.arange(tile_y, dtype=np.int64)
        offs = (gy * tile_y + rows) * row_len + gx * tile_x * element_size
        seqs.append(offs)
    req = tile_x * element_size
    trace = merge_arrivals(seqs, req, rng, skew=contention_skew(nproc),
                           app_id=app_id, file_id=file_id)
    return Workload(
        f"tileio-{'1d' if one_dimensional else '2d'}-{nproc}p",
        tuple(trace), len(trace) * req, nproc,
    )


def mixed(
    *workloads: Workload, seed: int = 0, burst_requests: int | None = None
) -> Workload:
    """Interleave several app traces into one server-side arrival order.

    Different apps write different files (file_id must already differ);
    offsets from different apps are uncorrelated, exactly the condition the
    paper notes makes per-stream sorting still meaningful (Section 2.2).

    ``burst_requests=None`` merges strictly by timestamp (fine-grained
    interleave — every stream blends all apps, pct ≈ superimposed, the
    paper's Fig. 3d/5d situation).  With ``burst_requests=k`` the apps
    alternate in bursts of ~k requests (jittered ±50%), which is how two
    IOR instances actually hit an I/O node over the network and is the
    regime of the paper's limited-SSD experiments (Fig. 9/13): streams keep
    their per-app character, so redirection and traffic-aware flushing see
    alternating sequential/random phases.
    """

    if burst_requests is None:
        merged: list[Request] = []
        for w in workloads:
            merged.extend(w.trace)
        merged.sort(key=lambda r: (r.time, r.app_id, r.offset))
    else:
        rng = np.random.default_rng(seed)
        cursors = [0] * len(workloads)
        merged = []
        while any(c < len(w.trace) for c, w in zip(cursors, workloads)):
            for i, w in enumerate(workloads):
                if cursors[i] >= len(w.trace):
                    continue
                k = max(1, int(burst_requests * rng.uniform(0.5, 1.5)))
                merged.extend(w.trace[cursors[i]: cursors[i] + k])
                cursors[i] += k
    name = "+".join(w.name for w in workloads)
    return Workload(
        f"mixed({name})",
        tuple(merged),
        sum(w.total_bytes for w in workloads),
        sum(w.nproc for w in workloads),
    )


def checkpoint_wave(
    nproc: int,
    waves: int = 4,
    bytes_per_wave: int = 2 * GiB,
    compute_seconds: float = 30.0,
    request_size: int = DEFAULT_REQUEST,
    rotate_files: int = 2,
    seed: int = 0,
    app_id: int = 0,
    file_id: int = 0,
) -> Workload:
    """Checkpoint-burst workload (Wang et al.'s burst-buffer traffic,
    PAPERS.md): after every ``compute_seconds`` of computation, all
    ``nproc`` processes dump their checkpoint segment at once — a
    segmented-contiguous burst — then go quiet again.  The trace
    interleaves :class:`repro.core.trace.Gap` compute phases between
    bursts, which is exactly the regime where a burst buffer shines:
    the SSD absorbs the spike and flushes during the gap.

    Checkpoint files rotate over ``rotate_files`` handles (the usual
    double-buffered checkpoint), so wave ``w`` *overwrites* the extents
    wave ``w - rotate_files`` wrote: the log-structured SSD store dedups
    the superseded version while an in-place scheme pays the full write.
    """

    if waves < 1:
        raise ValueError(f"waves must be >= 1, got {waves}")
    if rotate_files < 1:
        raise ValueError(f"rotate_files must be >= 1, got {rotate_files}")
    from .trace import Gap  # local: keeps workloads importable standalone

    rng = np.random.default_rng(seed)
    items: list = []
    t = 0.0
    total = 0
    for w in range(waves):
        if w:
            items.append(Gap(compute_seconds))
        seqs = _segmented_contiguous_offsets(nproc, bytes_per_wave,
                                             request_size)
        burst = merge_arrivals(
            seqs, request_size, rng,
            skew=contention_skew(nproc) * 0.25,
            app_id=app_id, file_id=file_id + (w % rotate_files),
            start_time=t,
        )
        items.extend(burst)
        total += len(burst) * request_size
        t = (burst[-1].time if burst else t) + compute_seconds
    return Workload(f"ckpt-{nproc}p-{waves}w", tuple(items), total, nproc)


def relabel(w: Workload, app_id: int, file_id: int, start_time: float = 0.0) -> Workload:
    """Retag a workload for use inside a mixed load."""

    trace = tuple(
        dataclasses.replace(r, app_id=app_id, file_id=file_id,
                            time=r.time + start_time)
        for r in w.trace
    )
    return Workload(w.name, trace, w.total_bytes, w.nproc)
