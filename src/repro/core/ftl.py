"""Columnar page-mapped FTL storage model (paper §2.5, ROADMAP FTL item).

The constant-bandwidth :class:`~repro.core.device_model.SSDModel` *assumes*
the paper's §2.5 claim — that log-structured buffering makes redirected
random writes cheap on flash.  This module models the mechanism so the
claim can be *measured*: a page-mapped flash translation layer with

* **columnar mapping state** — logical→physical (``l2p``) and
  physical→logical (``p2l``) int32 arrays plus a per-block valid-page
  count, mirroring the cache/channel/NAND split of FTL-SIM; no
  per-page Python objects anywhere.
* **N-channel striping** — a page program occupies one channel for
  ``t_prog`` seconds; with ``n_channels`` interleaved dies the device
  sustains one page per ``t_prog / n_channels`` (``t_page``).  The
  default ``t_prog`` is calibrated so the nominal striped bandwidth
  equals the constant model's 380 MB/s.
* **watermark-triggered greedy GC** — writes consume a free-block
  queue; when it dips below ``gc_low_blocks`` the FTL relocates the
  still-valid pages of minimum-valid sealed blocks (greedy victim
  choice) and erases them until ``gc_high_blocks`` are free again —
  the free-block-watermark dynamics of the unsynchronized-GC paper in
  PAPERS.md.  Relocations are charged to the request that tripped the
  watermark.
* **measured write amplification** — ``wa = (host_pages +
  relocated_pages) / host_pages``.  Sequential log appends plus
  whole-region ``trim`` on flush completion keep WA ≈ 1 (SSDUP+'s log
  store); in-place random writes at high occupancy drive WA up — the
  comparison ``benchmarks/bench_ftl.py`` reports.

Batch-size independence (the engine-parity contract): GC fires at exact
request boundaries.  :meth:`charge_write` slices a request batch into
GC epochs — the maximal prefix that cannot trip the low watermark is
served vectorized, the tripping request is served and pays the GC time,
then the scan resumes — so charging requests one at a time (the
per-request engine) and in arbitrary batches (the batched engine)
produces bit-identical times and identical device state.
"""

from __future__ import annotations

from collections import deque
from typing import Any

import numpy as np

from ..analysis import sanitize as _sanitize


class FTLModel:
    """Page-mapped FTL with N-channel striping and watermark greedy GC.

    Implements the :class:`~repro.core.device_model.StorageModel`
    protocol (``stateful=True``): :meth:`charge_write` consumes LBAs and
    mutates mapping state; :meth:`trim` invalidates a flushed region's
    pages (what keeps the log store's WA at ~1).
    """

    stateful: bool = True
    name: str = "ftl"

    def __init__(
        self,
        logical_bytes: int,
        page_size: int = 4096,
        pages_per_block: int = 256,
        n_channels: int = 8,
        overprovision: float = 0.25,
        t_prog: float | None = None,
        t_erase: float = 2.0e-3,
        read_bw: float = 450e6,
        gc_low_blocks: int = 4,
        gc_high_blocks: int = 8,
    ):
        if logical_bytes <= 0:
            raise ValueError("logical_bytes must be positive")
        if page_size <= 0 or pages_per_block <= 0 or n_channels <= 0:
            raise ValueError("page_size/pages_per_block/n_channels must be positive")
        if overprovision < 0.0:
            raise ValueError("overprovision must be >= 0")
        if not 2 <= gc_low_blocks < gc_high_blocks:
            raise ValueError(
                "need 2 <= gc_low_blocks < gc_high_blocks "
                f"(got {gc_low_blocks}/{gc_high_blocks})"
            )
        if t_prog is None:
            # nominal striped write bandwidth == the constant model's 380 MB/s
            t_prog = n_channels * page_size / 380e6
        if t_prog <= 0 or t_erase < 0 or read_bw <= 0:
            raise ValueError("non-positive device timing parameter")
        self.logical_bytes = int(logical_bytes)
        self.page_size = int(page_size)
        self.pages_per_block = int(pages_per_block)
        self.n_channels = int(n_channels)
        self.overprovision = float(overprovision)
        self.t_prog = float(t_prog)
        self.t_erase = float(t_erase)
        self.read_bw = float(read_bw)
        self.gc_low_blocks = int(gc_low_blocks)
        self.gc_high_blocks = int(gc_high_blocks)

        ps, ppb = self.page_size, self.pages_per_block
        self.num_logical_pages = -(-self.logical_bytes // ps)
        logical_blocks = -(-self.num_logical_pages // ppb)
        spare = max(
            self.gc_high_blocks + 2,
            int(np.ceil(logical_blocks * self.overprovision)),
        )
        self.num_blocks = logical_blocks + spare
        self.total_pages = self.num_blocks * ppb

        # columnar mapping state (int32: page counts stay < 2^31)
        self._l2p = np.full(self.num_logical_pages, -1, dtype=np.int32)
        self._p2l = np.full(self.total_pages, -1, dtype=np.int32)
        self._valid = np.zeros(self.num_blocks, dtype=np.int32)
        self._sealed = np.zeros(self.num_blocks, dtype=bool)
        self._free: deque[int] = deque(range(1, self.num_blocks))
        self._open = 0  # block receiving the write frontier
        self._fp = 0  # next unwritten page slot in the open block

        # conservation ledgers (sanitize_check invariants)
        self._valid_total = 0
        self._invalid_pages = 0
        self.host_bytes = 0
        self.host_pages = 0
        self.reloc_pages = 0
        self.trimmed_pages = 0
        self.erases = 0
        self.gc_runs = 0
        self.last_t = 0.0

    # -- derived timing/occupancy ----------------------------------------
    @property
    def t_page(self) -> float:
        """Seconds per page program with all channels interleaved."""

        return self.t_prog / self.n_channels

    @property
    def write_bw(self) -> float:
        """Nominal (GC-free) striped write bandwidth, bytes/s."""

        return self.n_channels * self.page_size / self.t_prog

    @property
    def free_pages(self) -> int:
        return (self.pages_per_block - self._fp) + self.pages_per_block * len(
            self._free
        )

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def live_pages(self) -> int:
        return self._valid_total

    @property
    def wa(self) -> float:
        """Measured write amplification: NAND pages per host page."""

        if self.host_pages == 0:
            return 1.0
        return (self.host_pages + self.reloc_pages) / self.host_pages

    # -- StorageModel protocol -------------------------------------------
    def write_time(self, nbytes: int) -> float:
        """Nominal (stateless) write estimate at the striped bandwidth."""

        return nbytes / self.write_bw

    def read_time(self, nbytes: int) -> float:
        return nbytes / self.read_bw

    def charge_write(
        self,
        offsets: np.ndarray | None,
        sizes: np.ndarray,
        t: float = 0.0,
    ) -> np.ndarray:
        """Service times of a request batch, mutating device state.

        Accuracy contract: batch-size independent — charging the same
        request sequence one call per request or in one call yields
        bit-identical times and identical mapping/ledger state (GC
        epochs are cut at exact request boundaries).
        """

        if offsets is None:
            raise ValueError(
                "FTLModel.charge_write needs per-request offsets (LBAs); "
                "only the stateless constant backend accepts offsets=None"
            )
        off = np.asarray(offsets, dtype=np.int64)
        szs = np.asarray(sizes, dtype=np.int64)
        n = len(szs)
        times = np.zeros(n, dtype=np.float64)
        if n == 0:
            return times
        if len(off) != n:
            raise ValueError(f"{len(off)} offsets for {n} sizes")
        if bool(np.any(szs < 0)) or bool(np.any(off < 0)) or bool(
            np.any(off + szs > self.logical_bytes)
        ):
            raise ValueError(
                "write outside the FTL's logical address space "
                f"[0, {self.logical_bytes})"
            )
        ps, ppb = self.page_size, self.pages_per_block
        p0 = off // ps
        pcnt = (off + szs + ps - 1) // ps - p0
        pcnt = np.where(szs > 0, pcnt, 0)
        self.host_bytes += int(szs.sum())
        self.last_t = float(t)

        i = 0
        while i < n:
            if len(self._free) >= self.gc_low_blocks:
                # pages servable before any request can trip the low
                # watermark: the open block's remainder plus every free
                # block above the watermark
                headroom = (ppb - self._fp) + (
                    len(self._free) - self.gc_low_blocks
                ) * ppb
                cum = np.cumsum(pcnt[i:])
                j = int(np.searchsorted(cum, headroom, side="right"))
                if j >= n - i:  # no trigger in the rest of the batch
                    self._serve(p0[i:], pcnt[i:], times[i:])
                    return times
                stop = i + j + 1  # include the tripping request
            else:
                stop = i + 1  # already below the watermark: GC per request
            self._serve(p0[i:stop], pcnt[i:stop], times[i:stop])
            times[stop - 1] += self._collect()
            self.gc_runs += 1
            i = stop
        return times

    def trim(self, offset: int, nbytes: int) -> None:
        """Invalidate the latest versions of fully-covered pages.

        Called by the pipeline when a flushed region's content is no
        longer needed on flash — this is what keeps the log store's
        measured WA at ~1 (GC finds whole blocks invalid).
        """

        if nbytes <= 0:
            return
        ps = self.page_size
        first = -(-offset // ps)
        last = min(offset + nbytes, self.logical_bytes) // ps
        if last <= first:
            return
        lp = np.arange(first, last, dtype=np.int64)
        old = self._l2p[lp]
        m = old >= 0
        cnt = int(np.count_nonzero(m))
        if cnt:
            stale = old[m].astype(np.int64)
            self._p2l[stale] = -1
            self._valid -= np.bincount(
                stale // self.pages_per_block, minlength=self.num_blocks
            ).astype(np.int32)
            self._l2p[lp[m]] = -1
            self._valid_total -= cnt
            self._invalid_pages += cnt
            self.trimmed_pages += cnt

    def clone(self) -> "FTLModel":
        """Fresh same-config FTL (per-node copies in fleet runs)."""

        return FTLModel(
            logical_bytes=self.logical_bytes,
            page_size=self.page_size,
            pages_per_block=self.pages_per_block,
            n_channels=self.n_channels,
            overprovision=self.overprovision,
            t_prog=self.t_prog,
            t_erase=self.t_erase,
            read_bw=self.read_bw,
            gc_low_blocks=self.gc_low_blocks,
            gc_high_blocks=self.gc_high_blocks,
        )

    def degraded(self, factor: float) -> "FTLModel":
        """Scale device bandwidths by ``factor`` (< 1 degrades) IN PLACE,
        preserving mapping state and WA ledgers; returns self."""

        if not factor > 0.0:
            raise ValueError(f"degradation factor must be > 0, got {factor!r}")
        self.t_prog = self.t_prog / factor
        self.t_erase = self.t_erase / factor
        self.read_bw = self.read_bw * factor
        return self

    def config_fingerprint(self) -> dict[str, Any]:
        """Config identity embedded in golden fixtures: replaying a
        fixture under a different backend/config fails loudly."""

        return {
            "name": self.name,
            "logical_bytes": int(self.logical_bytes),
            "page_size": int(self.page_size),
            "pages_per_block": int(self.pages_per_block),
            "n_channels": int(self.n_channels),
            "overprovision": float(self.overprovision),
            "t_prog": float(self.t_prog),
            "t_erase": float(self.t_erase),
            "read_bw": float(self.read_bw),
            "gc_low_blocks": int(self.gc_low_blocks),
            "gc_high_blocks": int(self.gc_high_blocks),
        }

    def stats(self) -> dict[str, float]:
        """Occupancy/WA snapshot for benchmarks and diagnostics."""

        return {
            "wa": float(self.wa),
            "host_bytes": float(self.host_bytes),
            "host_pages": float(self.host_pages),
            "reloc_pages": float(self.reloc_pages),
            "trimmed_pages": float(self.trimmed_pages),
            "erases": float(self.erases),
            "gc_runs": float(self.gc_runs),
            "free_blocks": float(len(self._free)),
            "live_fraction": float(self._valid_total / self.total_pages),
        }

    # -- conservation ledgers (sanitize mode) ----------------------------
    def sanitize_check(self) -> None:
        """FTL conservation ledgers; raises
        :class:`~repro.analysis.sanitize.SanitizerError` on violation."""

        valid_sum = int(self._valid.sum())
        _sanitize.check(
            valid_sum == self._valid_total,
            "per-block valid counts sum to %d but the ledger says %d",
            valid_sum, self._valid_total,
        )
        _sanitize.check(
            self._valid_total + self._invalid_pages + self.free_pages
            == self.total_pages,
            "page conservation broken: valid=%d + invalid=%d + free=%d "
            "!= total=%d",
            self._valid_total, self._invalid_pages, self.free_pages,
            self.total_pages,
        )
        mapped = int(np.count_nonzero(self._l2p >= 0))
        _sanitize.check(
            mapped == self._valid_total,
            "l2p maps %d pages but %d physical pages are valid",
            mapped, self._valid_total,
        )
        _sanitize.check(
            (self.host_pages + self.reloc_pages) * self.page_size
            >= self.host_bytes,
            "physical NAND writes (%d pages) cannot cover host bytes (%d)",
            self.host_pages + self.reloc_pages, self.host_bytes,
        )

    # -- internals --------------------------------------------------------
    def _alloc(self, k: int) -> np.ndarray:
        """Allocate ``k`` physical pages at the write frontier."""

        out = np.empty(k, dtype=np.int64)
        ppb = self.pages_per_block
        i = 0
        while i < k:
            if self._fp == ppb:
                self._sealed[self._open] = True
                if not self._free:
                    raise RuntimeError(
                        "FTL out of physical space (GC cannot reclaim "
                        "enough invalid pages)"
                    )
                self._open = self._free.popleft()
                self._fp = 0
            take = min(ppb - self._fp, k - i)
            base = self._open * ppb + self._fp
            out[i:i + take] = np.arange(base, base + take, dtype=np.int64)
            self._fp += take
            i += take
        return out

    def _serve(self, p0: np.ndarray, pcnt: np.ndarray, out: np.ndarray) -> None:
        """Serve one GC-free request segment: program its pages and write
        per-request channel-striped program times into ``out``."""

        out[:] = pcnt.astype(np.float64) * self.t_page
        total = int(pcnt.sum())
        if total == 0:
            return
        base = np.repeat(np.cumsum(pcnt) - pcnt, pcnt)
        lpns = np.repeat(p0, pcnt) + np.arange(total, dtype=np.int64) - base
        self._program(lpns)

    def _program(self, lpns: np.ndarray) -> None:
        """Program one page per element of ``lpns`` (in order); the LAST
        write of a duplicated lpn wins, earlier copies are immediately
        superseded (they still consume a program and a page)."""

        total = len(lpns)
        ppns = self._alloc(total)
        if total == 1 or bool(np.all(lpns[1:] > lpns[:-1])):
            # log-append fast path: strictly increasing => no duplicates
            uniq, final, stale_new = lpns, ppns, None
        else:
            order = np.argsort(lpns, kind="stable")
            sl = lpns[order]
            last = np.ones(total, dtype=bool)
            last[:-1] = sl[1:] != sl[:-1]
            uniq = sl[last]
            sp = ppns[order]
            final = sp[last]
            stale_new = sp[~last]
        old = self._l2p[uniq]
        old_live = old[old >= 0].astype(np.int64)
        self._p2l[ppns] = lpns.astype(np.int32)
        self._valid += np.bincount(
            ppns // self.pages_per_block, minlength=self.num_blocks
        ).astype(np.int32)
        self._valid_total += total
        stale = (
            old_live if stale_new is None
            else np.concatenate([old_live, stale_new])
        )
        cnt = len(stale)
        if cnt:
            self._p2l[stale] = -1
            self._valid -= np.bincount(
                stale // self.pages_per_block, minlength=self.num_blocks
            ).astype(np.int32)
            self._valid_total -= cnt
            self._invalid_pages += cnt
        self._l2p[uniq] = final.astype(np.int32)
        self.host_pages += total

    def _collect(self) -> float:
        """Greedy GC: relocate + erase minimum-valid sealed blocks until
        ``gc_high_blocks`` are free; returns the channel-striped time."""

        secs = 0.0
        ppb = self.pages_per_block
        while len(self._free) < self.gc_high_blocks:
            cands = np.flatnonzero(self._sealed)
            if not len(cands):
                break  # nothing sealed yet: GC cannot help
            vi = int(cands[np.argmin(self._valid[cands])])
            v = int(self._valid[vi])
            if v >= ppb:
                break  # every sealed block fully valid: no space to gain
            if v:
                span = self._p2l[vi * ppb:(vi + 1) * ppb]
                live = np.flatnonzero(span >= 0)
                lp = span[live].astype(np.int64)
                new = self._alloc(v)
                span[live] = -1
                self._valid[vi] = 0
                self._p2l[new] = lp.astype(np.int32)
                self._l2p[lp] = new.astype(np.int32)
                self._valid += np.bincount(
                    new // ppb, minlength=self.num_blocks
                ).astype(np.int32)
                self._invalid_pages += v  # the relocated-from slots
                self.reloc_pages += v
                secs += v * self.t_page
            # erase: a sealed victim's ppb written pages are all invalid now
            self._sealed[vi] = False
            self._free.append(vi)
            self._invalid_pages -= ppb
            self.erases += 1
            secs += self.t_erase / self.n_channels
        return secs

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"FTLModel(logical={self.logical_bytes >> 20}MiB, "
            f"blocks={self.num_blocks}, free={len(self._free)}, "
            f"wa={self.wa:.3f})"
        )
