"""Struct-of-arrays trace representation + batched per-stream scoring.

The seed simulator consumed a Python ``list[Request | Gap]`` and re-scored
every 128-request stream with per-stream NumPy calls (argsort + reductions
inside a Python loop).  This module is the columnar counterpart used by the
fleet layer (:mod:`repro.core.fleet`):

* :class:`TraceBatch` — one trace as parallel ``int64``/``float64`` arrays
  (offset, size, file_id, app_id, time) plus *gap markers*: compute phases
  (:class:`Gap`) are stored out-of-band as ``(position, seconds)`` pairs
  where ``position`` is the request index the gap precedes.  Converts
  losslessly to/from the simulator's item lists.
* :class:`StreamScores` — the three per-stream statistics the simulator
  needs (Eq. 1 random-factor sum, random percentage, sorted seek distance),
  precomputed for *all* streams of a trace in one vectorized call so
  :meth:`repro.core.simulator.IONodeSimulator.run` never re-sorts a stream
  in its hot loop.
* :func:`compute_stream_scores` — scoring entry point with three backends:
  ``numpy`` (vectorized ``int64`` host math, bit-exact against the scalar
  definitions — the default and the oracle), ``jnp`` (one device call via
  :func:`repro.core.random_factor.stream_stats_batch64` under a scoped
  x64 enable — int64 lanes, float64 division, bit-exact at any offset
  magnitude), and ``pallas`` (the fused ``repro.kernels.stream_rf``
  TPU kernel; int32 lanes, so traces with offsets/sizes above 2 GiB fall
  back to the exact host path, and the float32 seek-distance sum is
  rounded back to integer bytes).  Both device backends fall back to
  ``numpy`` automatically when jax is absent.

Stream grouping follows :class:`repro.core.random_factor.StreamGrouper`
semantics exactly: requests are blocked in arrival order into windows of
``stream_len``; gaps do NOT flush a partial window.  The trailing partial
stream is padded into a score-neutral fixed-shape row
(:meth:`TraceBatch.padded_stream_matrix`) so device backends score it in
the same dispatch as the full windows.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

from .random_factor import DEFAULT_STREAM_LEN, Request, stream_stats_batch_np


@dataclasses.dataclass(frozen=True, slots=True)
class Gap:
    """A compute phase between I/O phases (no foreground I/O)."""

    seconds: float


TraceItem = Request | Gap


@dataclasses.dataclass(frozen=True, eq=False)  # ndarray fields: generated
class TraceBatch:                               # __eq__ would raise
    """A request trace in struct-of-arrays form (+ out-of-band gap markers).

    ``gap_positions[i]`` is the index of the request that gap ``i``
    *precedes* (``num_requests`` means "after the last request"); positions
    are non-decreasing.  Several gaps may share a position.
    """

    offsets: np.ndarray  # (R,) int64
    sizes: np.ndarray  # (R,) int64
    file_ids: np.ndarray  # (R,) int64
    app_ids: np.ndarray  # (R,) int64
    times: np.ndarray  # (R,) float64
    gap_positions: np.ndarray  # (G,) int64, non-decreasing, in [0, R]
    gap_seconds: np.ndarray  # (G,) float64

    def __post_init__(self):
        r = self.offsets.shape[0]
        for name in ("sizes", "file_ids", "app_ids", "times"):
            arr = getattr(self, name)
            if arr.shape[0] != r:
                raise ValueError(f"{name} length {arr.shape[0]} != offsets length {r}")
        g = self.gap_positions.shape[0]
        if self.gap_seconds.shape[0] != g:
            raise ValueError("gap_positions / gap_seconds length mismatch")
        if g and (np.any(self.gap_positions < 0) or np.any(self.gap_positions > r)):
            raise ValueError("gap position out of range")

    def validate(self) -> None:
        """Deep per-element invariants (sanitize mode; ``__post_init__``
        only checks shapes).  Raises :class:`ValueError` on the first
        violated one: non-negative sizes/offsets, finite non-negative gap
        durations, non-decreasing gap positions and request times."""

        if self.num_requests:
            if np.any(self.sizes < 0):
                raise ValueError("negative request size in trace")
            if np.any(self.offsets < 0):
                raise ValueError("negative request offset in trace")
            if not np.all(np.isfinite(self.times)):
                raise ValueError("non-finite request time in trace")
        if self.num_gaps:
            if np.any(np.diff(self.gap_positions) < 0):
                raise ValueError("gap_positions must be non-decreasing")
            if not np.all(np.isfinite(self.gap_seconds)):
                raise ValueError("non-finite gap duration in trace")
            if np.any(self.gap_seconds < 0):
                raise ValueError("negative gap duration in trace")

    # -- constructors ---------------------------------------------------
    @classmethod
    def from_items(cls, items: Iterable[TraceItem]) -> "TraceBatch":
        """Build from the simulator's mixed ``Request | Gap`` sequence."""

        offs: list[int] = []
        szs: list[int] = []
        fids: list[int] = []
        aids: list[int] = []
        tms: list[float] = []
        gpos: list[int] = []
        gsec: list[float] = []
        for item in items:
            if isinstance(item, Gap):
                gpos.append(len(offs))
                gsec.append(item.seconds)
                continue
            offs.append(item.offset)
            szs.append(item.size)
            fids.append(item.file_id)
            aids.append(item.app_id)
            tms.append(item.time)
        return cls(
            offsets=np.asarray(offs, dtype=np.int64),
            sizes=np.asarray(szs, dtype=np.int64),
            file_ids=np.asarray(fids, dtype=np.int64),
            app_ids=np.asarray(aids, dtype=np.int64),
            times=np.asarray(tms, dtype=np.float64),
            gap_positions=np.asarray(gpos, dtype=np.int64),
            gap_seconds=np.asarray(gsec, dtype=np.float64),
        )

    @classmethod
    def from_requests(cls, requests: Sequence[Request]) -> "TraceBatch":
        """Build from a gap-free request sequence (e.g. ``Workload.trace``)."""

        return cls.from_items(requests)

    # -- converters -----------------------------------------------------
    def to_items(self) -> list[TraceItem]:
        """Round-trip back to the simulator's item list (gaps in place)."""

        out: list[TraceItem] = []
        gi = 0
        ng = len(self.gap_positions)
        for i in range(self.num_requests):
            while gi < ng and self.gap_positions[gi] == i:
                out.append(Gap(float(self.gap_seconds[gi])))
                gi += 1
            out.append(
                Request(
                    offset=int(self.offsets[i]),
                    size=int(self.sizes[i]),
                    file_id=int(self.file_ids[i]),
                    app_id=int(self.app_ids[i]),
                    time=float(self.times[i]),
                )
            )
        while gi < ng:
            out.append(Gap(float(self.gap_seconds[gi])))
            gi += 1
        return out

    def to_requests(self) -> list[Request]:
        """Requests only (gap markers dropped)."""

        return [r for r in self.to_items() if isinstance(r, Request)]

    # -- basic queries --------------------------------------------------
    @property
    def num_requests(self) -> int:
        return int(self.offsets.shape[0])

    @property
    def num_gaps(self) -> int:
        return int(self.gap_positions.shape[0])

    @property
    def total_bytes(self) -> int:
        return int(self.sizes.sum())

    @property
    def gap_seconds_total(self) -> float:
        return float(self.gap_seconds.sum())

    def num_streams(self, stream_len: int = DEFAULT_STREAM_LEN) -> int:
        return -(-self.num_requests // stream_len) if self.num_requests else 0

    # -- slicing / sharding --------------------------------------------
    def select(self, indices: np.ndarray) -> "TraceBatch":
        """Sub-trace of the requests at ``indices`` (must be sorted).

        Gap markers are *replicated* into every selection — a compute phase
        idles the whole fleet, not one shard — with positions remapped to
        the local request indexing.
        """

        idx = np.asarray(indices, dtype=np.int64)
        if idx.size > 1 and np.any(np.diff(idx) < 0):
            raise ValueError("selection indices must be sorted (arrival order)")
        return TraceBatch(
            offsets=self.offsets[idx],
            sizes=self.sizes[idx],
            file_ids=self.file_ids[idx],
            app_ids=self.app_ids[idx],
            times=self.times[idx],
            # local position = how many selected requests precede the gap
            gap_positions=np.searchsorted(idx, self.gap_positions, side="left"),
            gap_seconds=self.gap_seconds.copy(),
        )

    def shard(self, assignment: np.ndarray, num_nodes: int) -> list["TraceBatch"]:
        """Split by a per-request node assignment into ``num_nodes`` batches."""

        assignment = np.asarray(assignment)
        if assignment.shape[0] != self.num_requests:
            raise ValueError("assignment length != num_requests")
        if assignment.size and (assignment.min() < 0 or assignment.max() >= num_nodes):
            raise ValueError("node assignment out of range")
        return [
            self.select(np.nonzero(assignment == node)[0])
            for node in range(num_nodes)
        ]

    # -- stream view ----------------------------------------------------
    def stream_bounds(self, stream_len: int = DEFAULT_STREAM_LEN) -> np.ndarray:
        """Request-index boundaries of the streams: ``bounds[s] .. bounds[s+1]``
        is stream ``s`` (full windows, then the trailing partial), matching
        :class:`repro.core.random_factor.StreamGrouper` emission order."""

        r = self.num_requests
        if r == 0:
            return np.zeros(1, dtype=np.int64)
        bounds = np.arange(0, r, stream_len, dtype=np.int64)
        return np.append(bounds, r)

    def stream_sums(
        self, stream_len: int = DEFAULT_STREAM_LEN
    ) -> tuple[np.ndarray, np.ndarray]:
        """Per-stream ``(nbytes, offset_sum)`` — the checksums the replay
        engine compares against :class:`StreamScores` to reject scores
        computed for a different trace."""

        bounds = self.stream_bounds(stream_len)
        starts = bounds[:-1]
        if not len(starts):
            z = np.zeros(0, dtype=np.int64)
            return z, z.copy()
        return (
            np.add.reduceat(self.sizes, starts),
            np.add.reduceat(self.offsets, starts),
        )

    def stream_matrix(
        self, stream_len: int = DEFAULT_STREAM_LEN
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """``(offsets (M, L), sizes (M, L), tail_offsets, tail_sizes)``.

        M full streams in arrival order plus the (possibly empty) trailing
        partial stream, matching :class:`StreamGrouper` emission order.
        """

        r = self.num_requests
        m = r // stream_len
        full = m * stream_len
        return (
            self.offsets[:full].reshape(m, stream_len),
            self.sizes[:full].reshape(m, stream_len),
            self.offsets[full:],
            self.sizes[full:],
        )

    def padded_stream_matrix(
        self, stream_len: int = DEFAULT_STREAM_LEN
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """``(offsets (S, L), sizes (S, L), true_lens (S,))`` — every stream
        as a fixed-shape row, the trailing partial padded to ``stream_len``.

        The padding is a *score-neutral contiguous run*: zero-size requests
        placed at ``sorted_last.offset + sorted_last.size``.  After the
        offset sort the pad block lands strictly past every real request;
        the (real_last, pad_0) gap equals the real last request's size and
        the pad-pad gaps are zero-against-zero-size, so Eq. 1 counts no
        extra seek and the seek-distance residuals are all zero.  Device
        kernels can therefore score the whole matrix — tail included — in
        one fixed-shape dispatch, with only the percentage denominator
        (``true_lens - 1``) applied host-side.
        """

        offs2d, szs2d, tail_offs, tail_szs = self.stream_matrix(stream_len)
        lens = np.full(offs2d.shape[0], stream_len, dtype=np.int64)
        t = tail_offs.size
        if t:
            # sorted-last real request = LAST occurrence of the max offset
            # (stable sort keeps arrival order among equal offsets)
            j = t - 1 - int(np.argmax(tail_offs[::-1]))
            pad_off = int(tail_offs[j]) + int(tail_szs[j])
            row_o = np.concatenate(
                [tail_offs, np.full(stream_len - t, pad_off, dtype=np.int64)])
            row_s = np.concatenate(
                [tail_szs, np.zeros(stream_len - t, dtype=np.int64)])
            offs2d = np.vstack([offs2d, row_o[None, :]])
            szs2d = np.vstack([szs2d, row_s[None, :]])
            lens = np.append(lens, t)
        return offs2d, szs2d, lens


# ---------------------------------------------------------------------------
# batched per-stream scoring
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)  # ndarray fields: generated
class StreamScores:                             # __eq__ would raise
    """Per-stream statistics in stream-emission order.

    One row per stream (full windows first, trailing partial last):
    Eq. 1 random-factor sum, random percentage ``S/(N-1)``, total sorted
    seek distance, the stream's byte count, and an offset checksum
    (plain sum) the simulator uses to reject scores that were computed
    for a different trace.
    """

    rf_sum: np.ndarray  # (S,) int64
    percentage: np.ndarray  # (S,) float64
    seek_distance: np.ndarray  # (S,) int64
    nbytes: np.ndarray  # (S,) int64
    offset_sum: np.ndarray  # (S,) int64
    stream_len: int
    backend: str

    def __len__(self) -> int:
        return int(self.rf_sum.shape[0])

    def validate(self) -> None:
        """Deep per-element invariants (sanitize mode): every score row
        in range — random percentage in [0, 1], non-negative seek sums,
        byte counts and distances.  Raises :class:`ValueError`."""

        n = len(self)
        for name in ("percentage", "seek_distance", "nbytes", "offset_sum"):
            if getattr(self, name).shape[0] != n:
                raise ValueError(f"{name} length != rf_sum length {n}")
        if n == 0:
            return
        if np.any(self.rf_sum < 0) or np.any(self.seek_distance < 0):
            raise ValueError("negative seek score")
        if np.any(self.nbytes < 0):
            raise ValueError("negative stream byte count")
        if np.any((self.percentage < 0.0) | (self.percentage > 1.0)):
            raise ValueError("random percentage outside [0, 1]")


SCORE_BACKENDS = ("numpy", "jnp", "pallas")


_INT32_MAX = np.int64(2**31 - 1)


def _score_streams_device(
    offs2d: np.ndarray, szs2d: np.ndarray, lens: np.ndarray, backend: str
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Score the padded (S, L) stream matrix on device.

    ``lens`` holds each row's TRUE request count (< L only for the padded
    trailing partial); the percentage denominator uses it host-side in
    float64, so ``pct`` is bit-equal to the numpy oracle's division for
    every backend.

    ``jnp`` runs :func:`repro.core.random_factor.stream_stats_batch64`
    under a scoped x64 enable — int64 lanes, float64 division — and is
    bit-exact at any offset magnitude.  ``pallas`` keeps the kernel's
    int32/float32 lanes: offsets or sizes above 2 GiB would TRUNCATE into
    wrong seek counts (not just imprecise ones), so those traces fall back
    to the exact host path, and the float32 distance sum is rounded back
    to integer bytes.
    """

    from . import random_factor as rf_mod

    pallas_overflow = backend == "pallas" and (
        np.abs(offs2d).max(initial=0) > _INT32_MAX
        or szs2d.max(initial=0) > _INT32_MAX
    )
    if rf_mod.jnp is None or pallas_overflow:
        rf, _, dist = stream_stats_batch_np(offs2d, szs2d)
    elif backend == "pallas":
        from repro.kernels.stream_rf.ops import stream_stats_op

        rf, _, dist = stream_stats_op(offs2d, szs2d)
    else:
        rf, _, dist = rf_mod.stream_stats_batch64(offs2d, szs2d)
    rf = np.asarray(rf, dtype=np.int64)
    pct = rf / np.maximum(lens - 1, 1)
    dist = np.rint(np.asarray(dist, dtype=np.float64)).astype(np.int64)
    return rf, pct, dist


def compute_stream_scores(
    trace: "TraceBatch | Sequence[TraceItem]",
    stream_len: int = DEFAULT_STREAM_LEN,
    backend: str = "numpy",
) -> StreamScores:
    """Score every stream of a trace in one vectorized pass.

    ``backend="numpy"`` (default) is bit-exact against the scalar
    ``stream_percentage`` / ``sorted_seek_distance`` path and needs no
    accelerator.  ``"jnp"`` runs every stream — trailing partial included,
    via the score-neutral padding of :meth:`TraceBatch.padded_stream_matrix`
    — as ONE device call under a scoped x64 enable, bit-exact against the
    oracle.  ``"pallas"`` routes the same padded matrix through the fused
    ``stream_rf`` bitonic-sort kernel (int32 lanes: requires power-of-two
    ``stream_len`` and offsets below 2 GiB, else it falls back to the exact
    host path).
    """

    if backend not in SCORE_BACKENDS:
        raise ValueError(f"backend must be one of {SCORE_BACKENDS}, got {backend!r}")
    batch = trace if isinstance(trace, TraceBatch) else TraceBatch.from_items(trace)
    nbytes, osum = batch.stream_sums(stream_len)

    if backend == "numpy":
        offs2d, szs2d, tail_offs, tail_szs = batch.stream_matrix(stream_len)
        if offs2d.shape[0]:
            rf, pct, dist = stream_stats_batch_np(offs2d, szs2d)
        else:
            rf = np.zeros(0, dtype=np.int64)
            pct = np.zeros(0, dtype=np.float64)
            dist = np.zeros(0, dtype=np.int64)
        if tail_offs.size:
            trf, tpct, tdist = stream_stats_batch_np(
                tail_offs[None, :], tail_szs[None, :]
            )
            rf = np.concatenate([rf, trf])
            pct = np.concatenate([pct, tpct])
            dist = np.concatenate([dist, tdist])
    else:
        offs_p, szs_p, lens = batch.padded_stream_matrix(stream_len)
        if offs_p.shape[0]:
            rf, pct, dist = _score_streams_device(offs_p, szs_p, lens, backend)
        else:
            rf = np.zeros(0, dtype=np.int64)
            pct = np.zeros(0, dtype=np.float64)
            dist = np.zeros(0, dtype=np.int64)

    return StreamScores(
        rf_sum=rf,
        percentage=pct,
        seek_distance=dist,
        nbytes=np.asarray(nbytes, dtype=np.int64),
        offset_sum=np.asarray(osum, dtype=np.int64),
        stream_len=stream_len,
        backend=backend,
    )
