"""Two-region SSD pipeline with traffic-aware flushing (paper Section 2.4).

The fast tier is split into two equal regions.  One region buffers incoming
redirected writes while the other flushes to the slow tier; when the
buffering region fills, the roles swap (Eq. 5: all but the first/last m/2
stages are fully pipelined).  If both regions are full the writer *blocks*
until a flush completes (paper: "the system waits until a region becomes
empty").

Traffic-aware flushing (Section 2.4.2): the flusher checks the detector's
current random percentage.  High percentage ⇒ most traffic is being absorbed
by the fast tier, the slow tier is idle ⇒ flush.  Low percentage ⇒ the slow
tier is busy with direct sequential writes ⇒ pause the flush to avoid head
thrashing (Eq. 7's T_f' > T_f), unless the pipeline is out of space (both
regions full), in which case flushing is forced.

This module is a pure state machine — the simulator / checkpoint runtime own
the clock and call :meth:`flush_progress` with byte quantities.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import TYPE_CHECKING, Callable

from .log_store import LogRegion

if TYPE_CHECKING:
    from .device_model import HDDModel, StorageModel


class FlushState(enum.Enum):
    IDLE = "idle"
    FLUSHING = "flushing"
    PAUSED = "paused"


@dataclasses.dataclass
class FlushJob:
    region: LogRegion
    bytes_total: int
    seeks: int  # residual seeks of the index-ordered flush
    bytes_done: int = 0
    paused_seconds: float = 0.0
    forced: bool = False

    @property
    def bytes_left(self) -> int:
        return self.bytes_total - self.bytes_done

    @property
    def done(self) -> bool:
        return self.bytes_done >= self.bytes_total

    # -- Eq. 6 flush cost (paper Section 2.5) --------------------------
    def service_seconds(self, hdd: "HDDModel") -> float:
        """Exclusive-HDD time to drain the whole job:
        ``seeks × seek_time + bytes / seq_bw`` (paper Eq. 6).

        The residual seeks are the gaps left between live extents after
        the index-ordered sort — the part of the flush the log-structured
        buffer cannot make sequential.
        """

        return self.seeks * hdd.seek_time + self.bytes_total / hdd.seq_bw

    def effective_rate(
        self, hdd: "HDDModel", storage: "StorageModel | None" = None
    ) -> float:
        """Drain rate (B/s) with the residual seeks amortized per byte.

        Every byte-budget drain path charges the flush at this rate, so
        the seek cost is paid no matter which code path drains the job
        (foreground-overlapped, compute gap, blocked writer, final
        drain).  With a stateful ``storage`` model the flusher's SSD
        *read* side can also bind (e.g. a degraded device): the rate is
        then capped by ``storage.read_time``; the default models read
        faster than the HDD writes, so the constant path is unchanged.
        """

        if self.bytes_total <= 0:
            return hdd.seq_bw
        secs = self.service_seconds(hdd)
        if storage is not None:
            secs = max(secs, storage.read_time(self.bytes_total))
        return self.bytes_total / secs


@dataclasses.dataclass(frozen=True)
class AppendOutcome:
    ok: bool
    swapped: bool = False  # filled region handed to the flusher
    blocked: bool = False  # both regions full; caller must drain a flush


class TwoRegionPipeline:
    """The paper's two-region buffering/flushing pipeline."""

    def __init__(
        self,
        region_capacity: int,
        traffic_aware: bool = True,
        flush_gate: float | str = 0.5,
        percentage_source: Callable[[], float] | None = None,
        index_backend: str = "numpy",
        storage: "StorageModel | None" = None,
        fg_ssd_source: Callable[[], bool] | None = None,
    ):
        if isinstance(flush_gate, str) and flush_gate != "device":
            raise ValueError(
                f"flush_gate must be a float or 'device', got {flush_gate!r}"
            )
        self.regions = (
            LogRegion(region_capacity, "R0", index_backend=index_backend),
            LogRegion(region_capacity, "R1", index_backend=index_backend),
        )
        # region 1 lives in the upper half of the SSD's logical space
        self.regions[1].base_lba = region_capacity
        self.active = 0
        self.flush_job: FlushJob | None = None
        self._flush_backlog: list[FlushJob] = []
        self.traffic_aware = traffic_aware
        self.flush_gate = flush_gate
        # Detector hook: returns the current stream random percentage.
        self.percentage_source = percentage_source or (lambda: 1.0)
        # Stateful storage backend (FTL): receives trim() when a flushed
        # region's log dies.  None for the stateless constant model.
        self.storage = storage
        # Flush-gate v2 hook (flush_gate="device"): returns True while the
        # foreground stream is writing the SSD (HDD quiet => flush).
        self.fg_ssd_source = fg_ssd_source or (lambda: True)
        # stats
        self.flushes_completed = 0
        self.total_flushed_bytes = 0
        self.total_paused_seconds = 0.0
        self.blocked_events = 0

    # -- write path -------------------------------------------------------
    @property
    def active_region(self) -> LogRegion:
        return self.regions[self.active]

    @property
    def standby_region(self) -> LogRegion:
        return self.regions[1 - self.active]

    def append(self, file_id: int, offset: int, size: int) -> AppendOutcome:
        """Append one redirected request; may swap regions or report a block."""

        region = self.active_region
        if region.fits(size):
            region.append(file_id, offset, size)
            return AppendOutcome(ok=True)

        # Active region is full: try to swap to the standby region.
        standby = self.standby_region
        standby_busy = standby.used_bytes > 0 or self._scheduled(standby)
        if standby_busy:
            self.blocked_events += 1
            return AppendOutcome(ok=False, blocked=True)

        self._schedule_flush(region)
        self.active = 1 - self.active
        if not self.active_region.fits(size):
            raise ValueError(
                f"request of {size} B exceeds region capacity {self.active_region.capacity}"
            )
        self.active_region.append(file_id, offset, size)
        return AppendOutcome(ok=True, swapped=True)

    def _scheduled(self, region: LogRegion) -> bool:
        return (
            self.flush_job is not None and self.flush_job.region is region
        ) or any(j.region is region for j in self._flush_backlog)

    def _schedule_flush(self, region: LogRegion) -> None:
        # bytes/seeks are fixed at schedule time: a scheduled region never
        # receives further appends (it is no longer the active region)
        nbytes = region.flush_bytes()
        if nbytes <= 0:
            # Nothing live to flush (e.g. an oversized request rejected by
            # an EMPTY single-region buffer).  A zero-byte job would wedge
            # the drain loop: flush_progress() ignores nbytes <= 0, so the
            # job could never complete.  Clear the region and skip the job.
            self._trim_region(region)
            region.reset()
            return
        job = FlushJob(
            region=region,
            bytes_total=nbytes,
            seeks=region.seek_count_sorted(),
        )
        if self.flush_job is None:
            self.flush_job = job
        else:
            self._flush_backlog.append(job)

    # -- flush path -------------------------------------------------------
    def flush_state(self) -> FlushState:
        job = self.flush_job
        if job is None:
            return FlushState.IDLE
        if self.flush_allowed():
            return FlushState.FLUSHING
        return FlushState.PAUSED

    def flush_allowed(self) -> bool:
        """Traffic-aware gate (Section 2.4.2)."""

        job = self.flush_job
        if job is None:
            return False
        if job.forced or not self.traffic_aware:
            return True
        if isinstance(self.flush_gate, str):  # flush_gate="device" (v2)
            # Pause whenever the foreground stream is writing the HDD:
            # the device itself, not the detector's percentage, decides.
            return self.fg_ssd_source()
        # High random percentage => slow tier is quiet => flush now.
        return self.percentage_source() >= self.flush_gate

    def force_flush(self) -> None:
        """Used when the writer is blocked: space reclaim beats interference."""

        if self.flush_job is not None:
            self.flush_job.forced = True

    def flush_progress(self, nbytes: int) -> int:
        """Advance the current flush by up to ``nbytes``; returns bytes used."""

        job = self.flush_job
        if job is None or nbytes <= 0:
            return 0
        used = min(nbytes, job.bytes_left)
        job.bytes_done += used
        self.total_flushed_bytes += used
        if job.done:
            self._complete_flush()
        return used

    def note_pause(self, seconds: float) -> None:
        if self.flush_job is not None:
            self.flush_job.paused_seconds += seconds
        self.total_paused_seconds += seconds

    def _trim_region(self, region: LogRegion) -> None:
        """Tell a stateful storage model the region's log content died."""

        if self.storage is not None and region.used_bytes > 0:
            self.storage.trim(region.base_lba, region.used_bytes)

    def _complete_flush(self) -> None:
        if self.flush_job is None:
            raise RuntimeError("completing a flush with no active job")
        self._trim_region(self.flush_job.region)
        self.flush_job.region.reset()
        self.flush_job = None
        self.flushes_completed += 1
        if self._flush_backlog:
            self.flush_job = self._flush_backlog.pop(0)

    def drain(self) -> list[FlushJob]:
        """Schedule and force flushes for ALL remaining data (end of I/O
        phase), returning every outstanding job — the active one AND the
        backlog — so a caller draining the returned jobs can never stall
        on a never-forced second region."""

        for region in self.regions:
            if region.used_bytes > 0 and not self._scheduled(region):
                self._schedule_flush(region)
        jobs: list[FlushJob] = []
        if self.flush_job is not None:
            self.flush_job.forced = True
            jobs.append(self.flush_job)
        for job in self._flush_backlog:
            job.forced = True
            jobs.append(job)
        return jobs

    # -- accounting ---------------------------------------------------------
    @property
    def buffered_bytes(self) -> int:
        return sum(r.used_bytes for r in self.regions)

    @property
    def metadata_bytes(self) -> int:
        return sum(r.metadata_bytes() for r in self.regions)


class SingleRegionBuffer(TwoRegionPipeline):
    """Plain burst buffer: the whole SSD as ONE region (OrangeFS-BB baseline).

    Paper Section 4.2.3: "in OrangeFS-BB, the 8GB is used as an entire
    space".  When the region fills it flushes; until the flush completes the
    buffer rejects appends (the simulator then routes those writes straight
    to the HDD, the paper's overflow behaviour).
    """

    def __init__(self, capacity: int, **kwargs):
        kwargs.setdefault("traffic_aware", False)
        super().__init__(capacity, **kwargs)
        # keep only region 0; region 1 is permanently retired
        self.regions = (self.regions[0],)

    @property
    def active_region(self) -> LogRegion:
        return self.regions[0]

    @property
    def standby_region(self) -> LogRegion:  # pragma: no cover - not used
        return self.regions[0]

    def append(self, file_id: int, offset: int, size: int) -> AppendOutcome:
        region = self.regions[0]
        if self.flush_job is not None:
            # region is being drained; cannot buffer until it completes
            self.blocked_events += 1
            return AppendOutcome(ok=False, blocked=True)
        if region.fits(size):
            region.append(file_id, offset, size)
            if region.free_bytes() < max(size, region.capacity // 256):
                # buffer is (effectively) full: plain BB starts its flush
                # phase right away (paper Section 4.2.4: "after the first IOR
                # instance fills the SSD buffer, OrangeFS-BB starts the
                # flushing phase") — eagerly, so a following compute gap can
                # drain it.
                self._schedule_flush(region)
                if self.flush_job is not None:
                    self.flush_job.forced = True
            return AppendOutcome(ok=True)
        self._schedule_flush(region)
        if self.flush_job is not None:
            self.flush_job.forced = True  # plain BB flushes immediately
        self.blocked_events += 1
        return AppendOutcome(ok=False, blocked=True)
