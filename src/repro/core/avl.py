"""AVL-tree metadata index for the log-structured buffer (paper Section 2.5).

Each fast-tier file keeps one AVL tree.  A node stores the *original* extent
(offset, size in the backing file) and the *new* extent (offset in the
append-only log).  Nodes are keyed by original offset, so an in-order
traversal enumerates the buffered data in backing-file order — exactly the
order in which the flusher wants to write it to the slow tier (sequential
flush without a separate sort phase).

The paper budgets 24 bytes/node (3 × 8 B values) ≈ 3 MB for 40 GB of 256 KB
requests; :meth:`AVLTree.approx_bytes` mirrors that accounting and the
overhead benchmark (paper Table 1) reads it.

Self-balancing is the textbook height-balanced AVL with single/double
rotations; ``tests/test_avl.py`` property-checks the balance and ordering
invariants under random workloads (hypothesis).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


NODE_BYTES = 24  # paper Section 2.5: 3 values x 8 bytes


@dataclasses.dataclass(slots=True)
class _Node:
    key: int  # original offset
    size: int
    log_offset: int  # position in the fast-tier log
    left: "_Node | None" = None
    right: "_Node | None" = None
    height: int = 1


def _h(n: _Node | None) -> int:
    return n.height if n is not None else 0


def _update(n: _Node) -> None:
    n.height = 1 + max(_h(n.left), _h(n.right))


def _balance(n: _Node) -> int:
    return _h(n.left) - _h(n.right)


def _rot_right(y: _Node) -> _Node:
    x = y.left
    if x is None:
        raise RuntimeError("right rotation on a node with no left child")
    y.left, x.right = x.right, y
    _update(y)
    _update(x)
    return x


def _rot_left(x: _Node) -> _Node:
    y = x.right
    if y is None:
        raise RuntimeError("left rotation on a node with no right child")
    x.right, y.left = y.left, x
    _update(x)
    _update(y)
    return y


def _rebalance(n: _Node) -> _Node:
    _update(n)
    b = _balance(n)
    if b > 1:
        if n.left is None:
            raise RuntimeError("left-heavy node with no left child")
        if _balance(n.left) < 0:  # LR
            n.left = _rot_left(n.left)
        return _rot_right(n)
    if b < -1:
        if n.right is None:
            raise RuntimeError("right-heavy node with no right child")
        if _balance(n.right) > 0:  # RL
            n.right = _rot_right(n.right)
        return _rot_left(n)
    return n


@dataclasses.dataclass(frozen=True, slots=True)
class Extent:
    """One buffered extent: original offset -> log offset."""

    offset: int
    size: int
    log_offset: int

    @property
    def end(self) -> int:
        return self.offset + self.size


class AVLTree:
    """Height-balanced index from original offset to log extent."""

    def __init__(self) -> None:
        self._root: _Node | None = None
        self._count = 0

    def __len__(self) -> int:
        return self._count

    # -- mutation --------------------------------------------------------
    def insert_batch(self, offsets, sizes, log_offsets) -> None:
        """Insert many extents in array order (pointer-chasing loop).

        Interface shared with :class:`repro.core.extent_index.ExtentIndex`
        so :class:`repro.core.log_store.LogRegion` can drive either backend
        from its batched append path; here it is just the scalar insert in
        a loop — the AVL stays the bit-exact *oracle*, not the fast path.
        """

        for off, size, log_off in zip(offsets, sizes, log_offsets):
            self.insert(int(off), int(size), int(log_off))

    def insert(self, offset: int, size: int, log_offset: int) -> None:
        """Insert an extent.  Re-writes of the same original offset replace
        the mapping (latest log copy wins — log-structured semantics)."""

        def rec(n: _Node | None) -> _Node:
            if n is None:
                self._count += 1
                return _Node(offset, size, log_offset)
            if offset < n.key:
                n.left = rec(n.left)
            elif offset > n.key:
                n.right = rec(n.right)
            else:  # same original offset: newest version supersedes
                n.size = size
                n.log_offset = log_offset
                return n
            return _rebalance(n)

        self._root = rec(self._root)

    def clear(self) -> None:
        self._root = None
        self._count = 0

    # -- queries ---------------------------------------------------------
    def lookup(self, offset: int) -> Extent | None:
        n = self._root
        while n is not None:
            if offset < n.key:
                n = n.left
            elif offset > n.key:
                n = n.right
            else:
                return Extent(n.key, n.size, n.log_offset)
        return None

    def in_order(self) -> Iterator[Extent]:
        """Extents in original-offset order — the sequential flush order."""

        stack: list[_Node] = []
        n = self._root
        while stack or n is not None:
            while n is not None:
                stack.append(n)
                n = n.left
            n = stack.pop()
            yield Extent(n.key, n.size, n.log_offset)
            n = n.right

    def in_order_arrays(self):
        """``(offsets, sizes, log_offsets)`` int64 arrays of the live
        extents in ascending-offset order — same contract as
        :meth:`repro.core.extent_index.ExtentIndex.in_order_arrays` (here
        materialized from the in-order traversal)."""

        offs = np.empty(self._count, dtype=np.int64)
        szs = np.empty(self._count, dtype=np.int64)
        logs = np.empty(self._count, dtype=np.int64)
        for i, ext in enumerate(self.in_order()):
            offs[i] = ext.offset
            szs[i] = ext.size
            logs[i] = ext.log_offset
        return offs, szs, logs

    def min_key(self) -> int | None:
        n = self._root
        if n is None:
            return None
        while n.left is not None:
            n = n.left
        return n.key

    def max_key(self) -> int | None:
        n = self._root
        if n is None:
            return None
        while n.right is not None:
            n = n.right
        return n.key

    @property
    def height(self) -> int:
        return _h(self._root)

    def approx_bytes(self) -> int:
        """Metadata footprint under the paper's 24 B/node accounting."""

        return self._count * NODE_BYTES

    # -- invariants (exercised by property tests) -------------------------
    def check_invariants(self) -> None:
        """Raises AssertionError if AVL balance/order/height break anywhere."""

        def rec(n: _Node | None, lo: int | None, hi: int | None) -> int:
            if n is None:
                return 0
            if not (lo is None or n.key > lo):
                raise AssertionError("BST order violated (left)")
            if not (hi is None or n.key < hi):
                raise AssertionError("BST order violated (right)")
            hl = rec(n.left, lo, n.key)
            hr = rec(n.right, n.key, hi)
            if abs(hl - hr) > 1:
                raise AssertionError(f"AVL balance violated at key {n.key}")
            if n.height != 1 + max(hl, hr):
                raise AssertionError("stale height")
            return n.height

        total = rec(self._root, None, None)
        if total != self.height:
            raise AssertionError("root height disagrees with recursion")
        if sum(1 for _ in self.in_order()) != self._count:
            raise AssertionError("node count disagrees with in-order walk")
