"""Storage-device timing models, calibrated to the paper's testbed.

This container has neither a spinning disk nor an SSD under test, so the
paper-validation experiments run against analytic device models (DESIGN.md
§8).  The HDD model follows the paper's own abstraction (Section 2.2): one
seek per random-factor unit, with seek time roughly linear in logical-offset
distance (the paper cites FS2 for that linearity), plus sequential-bandwidth
transfer.

Calibration.  The testbed (Section 4.1) is OrangeFS over 2 I/O nodes with a
Toshiba MBF2300RC SAS disk and an Intel DC S3520 SATA SSD per node, on
**Gigabit Ethernet** — so each I/O node's ingest is capped at ~110 MB/s,
which is what makes the paper's SSD-backed curves plateau at ~212-218 MB/s
aggregate (Fig. 11).  We fit TWO constants against two measurements from
Fig. 2/6 (16 GiB, 256 KiB requests, aggregate over 2 nodes):

* segmented-random  ≈  95 MB/s (RP ≈ 0.97, ~124 seeks + a full-file sweep
  per 128-request window)
* strided @32 procs ≈ 176 MB/s (RP ≈ 0.28, ~37 seeks + sweep)

Solving ``t_stream = bytes/seq_bw + seeks*seek_time + distance*coeff`` for
the two unknowns gives ``seek_time ≈ 3.56 ms`` and
``coeff ≈ 5.1e-12 s/B``.  The remaining Fig. 6 points then VALIDATE the
model: strided@16 → 213 (paper 211.8), strided@64 → ~146 (paper 159),
strided@128 → ~116 (paper 133), seg-contig@16 → 220 (paper 218).  Known
deviation: seg-contig@128 undershoots (94 vs paper's 150 MB/s) because the
paper's CFQ elevator retains cross-window track locality that a per-window
seek count cannot see; scheme *comparisons* are unaffected (EXPERIMENTS.md
§Paper-validation).
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Any, ClassVar, Protocol, runtime_checkable

import numpy as np

if TYPE_CHECKING:
    from .ftl import FTLModel


@runtime_checkable
class StorageModel(Protocol):
    """Pluggable SSD timing backend threaded through all four engines.

    Two shipped backends: the stateless constant-bandwidth
    :class:`SSDModel` (``ssd="constant"``, the default — bit-exact with
    the pre-refactor inline ``nbytes / write_bw`` math everywhere) and
    the stateful page-mapped :class:`~repro.core.ftl.FTLModel`
    (``ssd="ftl"`` — GC, channel striping, measured write
    amplification).  Engines branch on ``stateful``: stateless models
    may be charged without offsets (vectorized, order-free); stateful
    models are charged with per-request LBAs in arrival order and get
    :meth:`trim` calls when a flushed region's content dies.
    """

    stateful: bool
    name: str
    read_bw: float

    def charge_write(
        self,
        offsets: np.ndarray | None,
        sizes: np.ndarray,
        t: float = 0.0,
    ) -> np.ndarray:
        """Per-request SSD service times (seconds, float64) for a batch."""
        ...

    def write_time(self, nbytes: int) -> float: ...

    def read_time(self, nbytes: int) -> float: ...

    def trim(self, offset: int, nbytes: int) -> None: ...

    def clone(self) -> "StorageModel": ...

    def degraded(self, factor: float) -> "StorageModel": ...

    def config_fingerprint(self) -> dict[str, Any]: ...


@dataclasses.dataclass(frozen=True)
class HDDModel:
    """Seek + distance + sequential-bandwidth disk model."""

    seq_bw: float = 220e6  # bytes/s, large sequential writes
    seek_time: float = 3.56e-3  # s per head movement (random-factor unit)
    seek_dist_coeff: float = 5.1e-12  # s per byte of logical seek distance
    name: str = "hdd"

    def write_time(self, nbytes: int, seeks: int, seek_distance: int = 0) -> float:
        """Service time of a sorted request batch with ``seeks`` movements."""

        if nbytes < 0 or seeks < 0:
            raise ValueError("negative work")
        return (
            seeks * self.seek_time
            + seek_distance * self.seek_dist_coeff
            + nbytes / self.seq_bw
        )

    def sequential_time(self, nbytes: int) -> float:
        return nbytes / self.seq_bw


@dataclasses.dataclass(frozen=True)
class SSDModel:
    """Flash model: bandwidth-only, near-zero seek (paper Section 2.5).

    The ``ssd="constant"`` storage backend.  Stateless: ``charge_write``
    is exactly ``sizes / write_bw`` elementwise (same IEEE operations as
    the pre-refactor inline math, so every golden fixture stays
    bit-exact) and ``trim`` is a no-op.
    """

    write_bw: float = 380e6  # bytes/s sequential (log-structured appends)
    read_bw: float = 450e6  # bytes/s (random reads ~ sequential on flash)
    name: str = "ssd"
    stateful: ClassVar[bool] = False

    def write_time(self, nbytes: int) -> float:
        return nbytes / self.write_bw

    def read_time(self, nbytes: int) -> float:
        return nbytes / self.read_bw

    def charge_write(
        self,
        offsets: np.ndarray | None,
        sizes: np.ndarray,
        t: float = 0.0,
    ) -> np.ndarray:
        """Per-request SSD write times; stateless, so offsets/t are
        ignored and the result is exactly ``sizes / write_bw``."""

        del offsets, t
        return np.asarray(sizes) / self.write_bw

    def trim(self, offset: int, nbytes: int) -> None:
        """No device state to invalidate in the constant model."""

    def clone(self) -> "SSDModel":
        return self  # immutable: safe to share across nodes

    def degraded(self, factor: float) -> "SSDModel":
        """New model with bandwidths scaled by ``factor`` (< 1 degrades)."""

        if not factor > 0.0:
            raise ValueError(f"degradation factor must be > 0, got {factor!r}")
        return dataclasses.replace(
            self, write_bw=self.write_bw * factor, read_bw=self.read_bw * factor
        )

    def config_fingerprint(self) -> dict[str, Any]:
        return {
            "name": "constant",
            "write_bw": float(self.write_bw),
            "read_bw": float(self.read_bw),
        }


STORAGE_BACKENDS = ("constant", "ftl")


def make_storage_model(
    spec: "StorageModel | str | None",
    logical_bytes: int = 0,
    **kwargs: Any,
) -> "StorageModel":
    """Resolve an ``ssd=`` spec into a :class:`StorageModel` instance.

    ``None`` / ``"constant"`` build the stateless :class:`SSDModel`;
    ``"ftl"`` builds an :class:`~repro.core.ftl.FTLModel` sized to
    ``logical_bytes`` (the buffer capacity it backs); an object that
    already implements the protocol passes through unchanged.
    """

    if spec is None or (isinstance(spec, str) and spec == "constant"):
        return SSDModel(**kwargs)
    if isinstance(spec, str):
        if spec == "ftl":
            from .ftl import FTLModel

            if logical_bytes <= 0:
                raise ValueError(
                    "ssd='ftl' needs a positive buffer capacity to size "
                    "the logical address space"
                )
            return FTLModel(logical_bytes=logical_bytes, **kwargs)
        raise ValueError(
            f"unknown storage model {spec!r}; choose from "
            f"{STORAGE_BACKENDS} or pass a StorageModel instance"
        )
    if isinstance(spec, StorageModel):
        return spec
    raise TypeError(
        f"ssd= expects {STORAGE_BACKENDS}, None, or a StorageModel "
        f"instance; got {type(spec).__name__}"
    )


def clone_storage(
    spec: "StorageModel | str | None",
) -> "StorageModel | str | None":
    """Per-node copy of an ``ssd=`` spec.

    Stateful instances are cloned so fleet nodes and scheme sweeps never
    share FTL mapping state; strings/None resolve to fresh models per
    node anyway and stateless instances are immutable, so both pass
    through unchanged.
    """

    if isinstance(spec, str) or spec is None:
        return spec
    if getattr(spec, "stateful", False):
        return spec.clone()
    return spec


@dataclasses.dataclass(frozen=True)
class IngestLink:
    """Per-I/O-node network ingest (GbE on the paper's testbed)."""

    bw: float = 110e6  # bytes/s

    def time(self, nbytes: int) -> float:
        return nbytes / self.bw


@dataclasses.dataclass(frozen=True)
class InterferenceModel:
    """Cost of concurrent HDD writers (paper Sections 2.4.2-2.4.3, Eq. 7).

    When the flusher and direct application writes hit the HDD together the
    disk head ping-pongs between the two streams.  We model the shared disk
    as a fair (50/50) server with a service-time inflation ``phi`` on every
    byte while shared: a foreground batch whose disk time is ``dt`` alone
    needs ``2 * phi * dt`` of disk occupancy when shared, and the concurrent
    flusher drains at ``seq_bw / (2 * phi)``.

    ``phi = 2.0`` calibrates SSDUP+ on the paper's workload_1 (Fig. 9/13)
    to within 2% of the paper's aggregate (176.9 vs 180.7 MB/s) and keeps
    the SSDUP+ > SSDUP ordering; see EXPERIMENTS.md §Paper-validation for
    the one ordering (BB vs SSDUP) the fair-share model flips.
    """

    phi: float = 2.0

    def foreground_slowdown(self) -> float:
        return 2.0 * self.phi

    def flush_rate_fraction(self) -> float:
        return 1.0 / (2.0 * self.phi)


# The tiers of the *framework* deployment (checkpoint path).  Relative speeds
# mirror the paper's SSD:HDD asymmetry one level up the hierarchy: local
# NVMe/DRAM burst tier vs. a remote parallel FS whose effective per-client
# bandwidth collapses under unmerged small writes.
@dataclasses.dataclass(frozen=True)
class TierSpec:
    name: str
    bw: float  # bytes/s
    seek_time: float = 0.0  # per non-contiguous write (request-merge miss)


LOCAL_BURST_TIER = TierSpec("local-nvme", bw=2.0e9)
REMOTE_PFS_TIER = TierSpec("remote-pfs", bw=0.5e9, seek_time=0.8e-3)
