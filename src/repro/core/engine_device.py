"""Device-resident replay engine: the batched engine's per-node state
transition as a pure, fixed-shape array program.

:class:`repro.core.simulator.IONodeSimulator` advances one node's replay
through a Python loop over streams; this module re-expresses that
transition as a functional step over a *state struct* (a pytree of
per-lane scalars) driven by ``jax.lax.scan`` over trace *events* and
``jax.vmap`` over *lanes* (node × scheme combinations), so an entire
fleet sweep runs as ONE jitted device call
(:class:`repro.core.fleet.FleetProgram`).

Structure (the state-struct / transition / orchestration split):

* **events** — :func:`build_events` lowers one shard's
  (:class:`~repro.core.trace.TraceBatch`,
  :class:`~repro.core.trace.StreamScores`) pair into a fixed-shape
  struct-of-arrays event tape: one entry per stream or compute gap, in
  the exact interleaving the batched engine uses (a full stream fires
  before a gap marker at its end boundary; the trailing partial stream
  fires after all remaining gaps).  Tapes are padded with ``valid=False``
  entries to a shared power-of-two length so every lane scans the same
  shape (:func:`stack_events`).
* **state** — :func:`initial_lane_state` builds the per-lane state struct
  (clocks, byte counters, region occupancy, the single in-flight flush
  job, the adaptive-threshold window as a circular buffer, routing
  hysteresis bits).  ``threshold_warmup`` is applied on the host through
  the exact scalar policies, then transplanted into the window buffer.
* **transition** — :func:`_event_step` is the pure per-lane step: stream
  routing against the precomputed scores (Eq. 1–3 threshold update +
  Algorithm 1 hysteresis), SSD region fills/swaps/blocks via a bounded
  ``lax.while_loop``, HDD/overflow foreground advances with Eq. 7
  interference, flush-quanta accounting per Eq. 6, and compute-gap
  draining.  All four schemes run the same step, selected by per-lane
  flags, so lanes of different schemes batch into one ``vmap``.
* **orchestration** — :func:`replay_lanes` jits ``scan(vmap(step))`` plus
  the vectorized end-of-trace drain and returns per-lane result arrays;
  :func:`simulate_device` wraps a single lane into a
  :class:`~repro.core.simulator.SimResult` (the ``engine="device"`` path
  of :class:`IONodeSimulator`).

Dtype policy: the engine runs under a scoped ``jax.experimental
.enable_x64`` — clocks/rates in float64, byte counters in int64 — so the
numbers track the numpy oracle at f64 resolution instead of drifting
through float32.

Accuracy contract (vs the bit-exact numpy engines): the device engine is
*stream-granular* where the oracle is request-granular.  The documented
approximations, all bounded and recorded as tolerances in the golden
fixtures (``device_tolerance`` metadata, checked by
``tests/test_engine_device.py``):

1. **Region fills stop on mean-request boundaries.**  The oracle
   appends whole requests (a region takes every request that fits
   entirely; plain BB stops at the eager-trigger request); the device
   reproduces that with the stream's MEAN request size — exact for
   uniform-size streams (the golden traces), byte-fraction approximate
   otherwise.
2. **Flush quanta accumulate in float64.**  The oracle truncates
   ``int(rate * wall)`` per request; the device accumulates
   continuously (≤ 1 byte/request difference).
3. **Eq. 6 residual seeks come from precomputed anchors, not a live
   sort.**  A region buffering an arrival-window of a stream sorts that
   window ALONE (``LogRegion.seek_count_sorted``), which no pro-rated
   share of the whole stream's count reproduces.  The host precomputes
   per stream (a) exact PREFIX seek counts at ``SUFFIX_ANCHORS + 1``
   request quantiles — every plain-BB fill and every first two-region
   fill is prefix-aligned, so those lerp within ~2% — and (b) dyadic
   window anchors (whole/halves/quarters/eighths, extent count +
   distinct-file baseline each) for interior fills, picked by nearest
   scale with linear partial-coverage; overwritten-extent dedup is not
   modeled (flush bytes = appended bytes).  A region holding SEVERAL
   streams sorts their union, so extents contiguous across neighbouring
   streams merge: the tape's per-stream cross-merge counts
   (``xm_1..xm_{XMERGE_D}``, see :func:`_cross_stream_merges`) are
   subtracted for partners still in the active region — without this a
   tiled workload's flush rate is underestimated ~2× and plain-BB
   routing diverges.  Merges at stream distance > ``XMERGE_D`` stay
   uncorrected (seeks are over-, never under-counted).
4. **Plain-BB overflow suffixes are interpolated, not re-scored.**  The
   oracle re-scores an overflowed stream suffix from scratch (a strided
   suffix sorts far worse than its byte share of the whole stream), so
   the device precomputes every stream's suffix HDD time at
   ``SUFFIX_ANCHORS + 1`` request-quantile split points on the host and
   lerps between them by byte fraction — exact for whole streams (the
   0-split anchor IS the stream's scored time) and at anchor-aligned
   splits, a few percent between anchors.
5. Routing, threshold evolution, and therefore **byte routing for the
   orangefs/ssdup/ssdup+ schemes is timing-independent and exact**;
   plain-BB byte splits are timing-coupled (overflow depends on when a
   flush completes) and carry tolerances.

``metadata_bytes`` is reported as 0, matching the oracle's post-drain
value.  The unbounded adaptive window (``adaptive_window=None``) is not
representable in fixed shape — the device engine requires a finite
window.
"""

from __future__ import annotations

import functools
from typing import Mapping, Sequence

import numpy as np

try:  # the control plane must import even where jax is absent
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.experimental import checkify, enable_x64
except Exception:  # pragma: no cover - jax is installed in this repo
    jax = None
    jnp = None

from ..analysis import sanitize as _sanitize

from .adaptive import (
    DEFAULT_THRESHOLD,
    AdaptiveThreshold,
    StaticWatermarkThreshold,
)
from .device_model import HDDModel, IngestLink, InterferenceModel, SSDModel
from .random_factor import DEFAULT_STREAM_LEN

SCHEME_IDS = {"orangefs": 0, "orangefs-bb": 1, "ssdup": 2, "ssdup+": 3}

#: Documented comparison tolerances of the device engine vs the numpy
#: oracle, per SimResult field: ``field -> (rtol, atol)``.  Derived from
#: the approximation list in the module docstring; golden fixtures embed
#: this table (``device_tolerance``) at --write time after verifying the
#: device replay satisfies it, and ``tests/test_engine_device.py``
#: asserts against the embedded copy.
DEVICE_TOLERANCES: dict[str, tuple[float, float]] = {
    "total_bytes": (0.0, 0.0),        # conservation: every byte lands
    "per_app_bytes": (0.0, 0.0),      # host-computed, scheme-independent
    "bytes_to_ssd": (0.0, 4 << 20),   # BB overflow split is timing-coupled
    "bytes_to_hdd_direct": (0.0, 4 << 20),
    "metadata_bytes": (0.0, 0.0),     # both report 0 post-drain
    "flushes": (0.0, 2.0),            # BB flush count is timing-coupled
    "peak_ssd_occupancy": (0.0, 4 << 20),
    "blocked_seconds": (0.05, 1e-6),  # Eq. 6 anchor lerp at block time
    "flush_paused_seconds": (0.05, 1e-6),
    "io_seconds": (0.05, 1e-9),       # suffix/seek anchor lerp dominates
    "total_seconds": (0.02, 1e-9),
}

#: Suffix-anchor count: stream suffix HDD times are precomputed at
#: ``round(j * n / SUFFIX_ANCHORS)`` for ``j = 0..SUFFIX_ANCHORS``
#: (anchor 0 = the whole stream, the last anchor = empty suffix).
SUFFIX_ANCHORS = 16

#: Dyadic window scales for Eq. 6 region-seek anchors: every stream is
#: scored whole, in halves, quarters and eighths (1 + 2 + 4 + 8 = 15
#: windows).  A region holding an arrival-window of a stream sorts that
#: window ALONE, so its seek count is NOT a pro-rated share of the whole
#: stream's (a strided stream's window loses the cross-window extent
#: merges); the device picks the scale nearest the fill width and
#: interpolates partial window coverage linearly.
WINDOW_SCALES = 4
N_WINDOWS = (1 << WINDOW_SCALES) - 1

#: Cross-stream merge depth: a flushed region sorts ALL its buffered
#: streams together, so extents that are contiguous ACROSS neighbouring
#: streams merge and cost no seek (``LogRegion.seek_count_sorted``) —
#: on tiled workloads (IOR strided) this collapses per-stream seek sums
#: by an order of magnitude.  The tape carries, per stream, the count of
#: sort-adjacent contiguous pairs it forms with each of its
#: ``XMERGE_D`` predecessors; the fill loop subtracts the pairs whose
#: partner stream is (fractionally) in the active region.  Pairs at
#: distance > XMERGE_D are left uncorrected (the estimate stays
#: conservative: seeks are over-, never under-counted).
XMERGE_D = 4

_EVENT_FIELDS = {
    "valid": np.bool_,
    "is_gap": np.bool_,
    "gap_sec": np.float64,
    "pct": np.float64,
    "nbytes": np.int64,
    "net_t": np.float64,
    "ssd_w": np.float64,
    "mean_sz": np.float64,
    **{f"hddt_{j}": np.float64 for j in range(SUFFIX_ANCHORS + 1)},
    **{f"pf_{j}": np.float64 for j in range(SUFFIX_ANCHORS + 1)},
    **{f"wf_{i}": np.float64 for i in range(N_WINDOWS)},
    **{f"wn_{i}": np.float64 for i in range(N_WINDOWS)},
    **{f"xm_{d}": np.float64 for d in range(1, XMERGE_D + 1)},
}


def _require_jax():
    if jax is None:  # pragma: no cover - jax is installed in this repo
        raise RuntimeError(
            "engine='device' requires jax; use engine='batched' instead"
        )


# ---------------------------------------------------------------------------
# host side: event tapes, lane constants, initial state
# ---------------------------------------------------------------------------


def _stream_extent_starts(
    batch, bounds: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Per-stream Eq. 6 seek statistics: ``(extent_starts, nfiles)``.

    ``extent_starts`` is the stream's extent count after the per-file
    offset sort (per file: 1 + non-contiguous breaks), i.e. exactly
    ``LogRegion.seek_count_sorted`` for a region holding the whole
    stream with unique extents.  ``nfiles`` (distinct files touched) is
    the part that does NOT scale when a region holds a stream fraction:
    each region pays the per-file baseline in full, only the breaks
    pro-rate.  One vectorized lexsort covers all streams.
    """

    ns = len(bounds) - 1
    sid = np.repeat(np.arange(ns, dtype=np.int64), np.diff(bounds))
    order = np.lexsort((batch.offsets, batch.file_ids, sid))
    so = batch.offsets[order]
    ss = batch.sizes[order]
    sf = batch.file_ids[order]
    ssid = sid[order]
    new_file = np.ones(len(so), dtype=bool)
    new_file[1:] = (ssid[1:] != ssid[:-1]) | (sf[1:] != sf[:-1])
    start = new_file.copy()
    start[1:] |= so[1:] != so[:-1] + ss[:-1]
    return (
        np.bincount(ssid[start], minlength=ns).astype(np.float64),
        np.bincount(ssid[new_file], minlength=ns).astype(np.float64),
    )


def _cross_stream_merges(batch, bounds: np.ndarray) -> np.ndarray:
    """Per-stream cross-merge counts ``(ns, XMERGE_D)``.

    ``out[j, d-1]`` = contiguous pairs stream ``j`` forms with stream
    ``j - d`` in the GLOBAL per-file offset sort (each pair assigned to
    the later stream).  For non-overlapping extents a contiguous pair is
    always sort-adjacent — an element between ``p`` and
    ``p.offset + p.size`` would overlap ``p`` — so one global lexsort
    suffices.  These are exactly the seeks
    ``LogRegion.seek_count_sorted`` does NOT pay when both streams sit
    in the same region, i.e. the gap between summing per-stream seek
    estimates and sorting the region's union.
    """

    ns = len(bounds) - 1
    out = np.zeros((ns, XMERGE_D), dtype=np.float64)
    if batch.num_requests < 2:
        return out
    sid = np.repeat(np.arange(ns, dtype=np.int64), np.diff(bounds))
    order = np.lexsort((batch.offsets, batch.file_ids))
    so = batch.offsets[order]
    ss = batch.sizes[order]
    sf = batch.file_ids[order]
    ssid = sid[order]
    contig = (sf[1:] == sf[:-1]) & (so[1:] == so[:-1] + ss[:-1])
    d = np.abs(ssid[1:] - ssid[:-1])
    later = np.maximum(ssid[1:], ssid[:-1])
    for k in range(1, XMERGE_D + 1):
        sel = contig & (d == k)
        out[:, k - 1] = np.bincount(later[sel], minlength=ns)
    return out


def _masked_predecessors(mask: np.ndarray) -> np.ndarray:
    """Index of each element's nearest PRECEDING masked element (-1: none).

    The anchor families below all reduce to "score a subset of a sorted
    sequence": the subset keeps the global sort order, so the element
    before ``v`` in the subset-restricted order is simply the nearest
    earlier index with ``mask`` set — one ``maximum.accumulate``, no
    re-sort.  This is what lets every anchor level reuse ONE global
    lexsort instead of paying its own (the tape build was ~38 lexsorts
    per shard before; it is 2 now).
    """

    idx = np.arange(mask.shape[0], dtype=np.int64)
    pidx = np.maximum.accumulate(np.where(mask, idx, -1))
    prev = np.empty_like(pidx)
    prev[0] = -1
    prev[1:] = pidx[:-1]
    return prev


def _window_seek_anchors(
    batch, bounds: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Eq. 6 seek anchors for dyadic arrival-windows of every stream.

    Returns ``(wf, wn)`` of shape ``(ns, N_WINDOWS)``: window ``(s, j)``
    (scale ``s`` splits the stream into ``2**s`` equal-request windows)
    is scored ALONE — extent count ``wf`` (per file: 1 + non-contiguous
    breaks) and distinct-file baseline ``wn``.  Column layout is
    scale-major: ``[whole, half0, half1, quarter0..3, eighth0..7]``.

    One global ``(stream, file, offset)`` lexsort serves all 15 windows:
    a window's elements keep their global sort order, so each window is
    scored with a masked predecessor pass (:func:`_masked_predecessors`)
    instead of its own sort.
    """

    ns = len(bounds) - 1
    lens = np.diff(bounds)
    wf = np.zeros((ns, N_WINDOWS), dtype=np.float64)
    wn = np.zeros((ns, N_WINDOWS), dtype=np.float64)
    if batch.num_requests == 0:
        return wf, wn
    sid = np.repeat(np.arange(ns, dtype=np.int64), lens)
    pos_in = np.arange(batch.num_requests, dtype=np.int64) - np.repeat(
        bounds[:-1], lens
    )
    order = np.lexsort((batch.offsets, batch.file_ids, sid))
    so = batch.offsets[order]
    ss = batch.sizes[order]
    sf = batch.file_ids[order]
    sdi = sid[order]
    spos = pos_in[order]
    slen = lens[sdi]
    col = 0
    for s in range(WINDOW_SCALES):
        w = 1 << s
        # window of position p: boundaries sit at round(k * len / w), so
        # p's window is the count of k >= 1 with floor(k*len/w + 0.5) <= p,
        # i.e. 2*len*k < (2p+1)*w — integer-exact, no float quantiles
        win = np.minimum(
            ((2 * spos + 1) * w - 1) // np.maximum(2 * slen, 1), w - 1
        )
        for k in range(w):
            m = win == k
            prev = _masked_predecessors(m)
            pc = np.maximum(prev, 0)
            same = m & (prev >= 0) & (sdi[pc] == sdi) & (sf[pc] == sf)
            contig = same & (so == so[pc] + ss[pc])
            wf[:, col + k] = np.bincount(sdi[m & ~contig], minlength=ns)
            wn[:, col + k] = np.bincount(sdi[m & ~same], minlength=ns)
        col += w
    return wf, wn


def _prefix_seek_anchors(batch, bounds: np.ndarray) -> np.ndarray:
    """``(ns, SUFFIX_ANCHORS + 1)`` Eq. 6 seek counts of every stream's
    arrival-order PREFIX at the request-quantile split points.

    Anchor ``j`` scores requests ``[0, round(j * n / A))`` of the stream
    sorted alone (per file: 1 + non-contiguous breaks), i.e. exactly the
    oracle's ``seek_count_sorted`` for a region buffering that prefix.
    Anchor 0 (empty prefix) is 0, anchor A is the whole stream.  Every
    plain-BB fill and every FIRST two-region fill of a stream is
    prefix-aligned, so these anchors are exact there up to the quantile
    lerp.  One global lexsort + one masked predecessor pass per anchor.
    """

    ns = len(bounds) - 1
    out = np.zeros((ns, SUFFIX_ANCHORS + 1), dtype=np.float64)
    if batch.num_requests == 0:
        return out
    lens = np.diff(bounds)
    sid = np.repeat(np.arange(ns, dtype=np.int64), lens)
    pos_in = np.arange(batch.num_requests, dtype=np.int64) - np.repeat(
        bounds[:-1], lens
    )
    order = np.lexsort((batch.offsets, batch.file_ids, sid))
    so = batch.offsets[order]
    ss = batch.sizes[order]
    sf = batch.file_ids[order]
    sdi = sid[order]
    spos = pos_in[order]
    for j in range(1, SUFFIX_ANCHORS + 1):
        k = np.floor(j * lens / SUFFIX_ANCHORS + 0.5).astype(np.int64)
        m = spos < k[sdi]
        prev = _masked_predecessors(m)
        pc = np.maximum(prev, 0)
        same = m & (prev >= 0) & (sdi[pc] == sdi) & (sf[pc] == sf)
        contig = same & (so == so[pc] + ss[pc])
        out[:, j] = np.bincount(sdi[m & ~contig], minlength=ns)
    return out


def _suffix_hdd_anchors(batch, bounds: np.ndarray, hdd) -> np.ndarray:
    """``(ns, SUFFIX_ANCHORS + 1)`` HDD device times of every stream's
    arrival-order suffix at the request-quantile split points.

    Anchor ``j`` of stream ``s`` scores the suffix starting at request
    ``round(j * n_s / SUFFIX_ANCHORS)`` exactly like the oracle's
    overflow path (sort the suffix alone, Eq. 1 seeks + sweep distance +
    sequential time); the last anchor (empty suffix) is 0.  One global
    ``(stream, offset)`` lexsort + a masked predecessor pass per anchor.
    """

    ns = len(bounds) - 1
    out = np.zeros((ns, SUFFIX_ANCHORS + 1), dtype=np.float64)
    if batch.num_requests == 0:
        return out
    lens = np.diff(bounds)
    sid = np.repeat(np.arange(ns, dtype=np.int64), lens)
    pos_in = np.arange(batch.num_requests, dtype=np.int64) - np.repeat(
        bounds[:-1], lens
    )
    order = np.lexsort((batch.offsets, sid))
    so = batch.offsets[order]
    ss = batch.sizes[order]
    sdi = sid[order]
    spos = pos_in[order]
    szf = ss.astype(np.float64)
    for j in range(SUFFIX_ANCHORS):
        k = np.floor(j * lens / SUFFIX_ANCHORS + 0.5).astype(np.int64)
        m = spos >= k[sdi]
        prev = _masked_predecessors(m)
        pc = np.maximum(prev, 0)
        pair = m & (prev >= 0) & (sdi[pc] == sdi)
        resid = np.where(pair, so - so[pc] - ss[pc], 0)
        rf = np.bincount(sdi[pair & (resid != 0)], minlength=ns)
        dist = np.bincount(
            sdi, weights=np.abs(resid).astype(np.float64), minlength=ns
        )
        nb = np.bincount(sdi[m], weights=szf[m], minlength=ns)
        # same term order as HDDModel.write_time
        out[:, j] = (
            rf * hdd.seek_time + dist * hdd.seek_dist_coeff + nb / hdd.seq_bw
        )
    return out


def build_events(
    batch,
    scores,
    stream_len: int = DEFAULT_STREAM_LEN,
    hdd: HDDModel | None = None,
    ssd: "SSDModel | object | None" = None,
    link: IngestLink | None = None,
) -> dict[str, np.ndarray]:
    """Lower one shard into its event tape (struct-of-arrays, length E).

    One event per stream or gap, in the batched engine's firing order.
    All timing inputs the device step needs are precomputed here in
    float64 with the oracle's exact expressions: whole-stream HDD time
    (Eq. 1 seeks + sweep + sequential), network time, the sequential sum
    of per-request SSD walls, and the per-stream score row.
    """

    hdd = hdd or HDDModel()
    ssd = ssd or SSDModel()
    link = link or IngestLink()

    bounds = batch.stream_bounds(stream_len)
    ns = len(bounds) - 1 if batch.num_requests else 0
    n_req = np.diff(bounds) if ns else np.zeros(0, dtype=np.int64)

    nb = np.asarray(scores.nbytes, dtype=np.int64)
    rf = np.asarray(scores.rf_sum, dtype=np.float64)
    dist = np.asarray(scores.seek_distance, dtype=np.float64)
    pct = np.asarray(scores.percentage, dtype=np.float64)
    if len(nb) != ns:
        raise ValueError(
            f"scores cover {len(nb)} streams but the trace produced {ns}"
        )
    # same association order as HDDModel.write_time / IngestLink.time
    hdd_t = rf * hdd.seek_time + dist * hdd.seek_dist_coeff + nb / hdd.seq_bw
    net_t = nb / link.bw
    if ns:
        anchors = _suffix_hdd_anchors(batch, bounds, hdd)
        # anchor 0 (whole stream) comes straight from the scores so the
        # pure-HDD path reproduces the oracle's walls bit-for-bit
        anchors[:, 0] = hdd_t
    else:
        anchors = np.zeros((0, SUFFIX_ANCHORS + 1), dtype=np.float64)
    if ns:
        w = np.maximum(batch.sizes / link.bw, batch.sizes / ssd.write_bw)
        ssd_w = np.add.reduceat(w, bounds[:-1])
        wf, wn = _window_seek_anchors(batch, bounds)
        pf = _prefix_seek_anchors(batch, bounds)
        xm = _cross_stream_merges(batch, bounds)
    else:
        ssd_w = np.zeros(0, dtype=np.float64)
        wf = np.zeros((0, N_WINDOWS), dtype=np.float64)
        wn = np.zeros((0, N_WINDOWS), dtype=np.float64)
        pf = np.zeros((0, SUFFIX_ANCHORS + 1), dtype=np.float64)
        xm = np.zeros((0, XMERGE_D), dtype=np.float64)
    mean_sz = nb / np.maximum(n_req, 1)

    gap_pos = batch.gap_positions
    gap_sec = batch.gap_seconds
    ng = len(gap_pos)

    # the batched engine's interleave: a full stream fires before any gap
    # at its end boundary; the trailing partial stream fires after ALL
    # remaining gaps (see IONodeSimulator._run_batched)
    if ns:
        fire_before = np.where(
            n_req == stream_len, bounds[1:], batch.num_requests + 1
        )
        gaps_before = np.searchsorted(gap_pos, fire_before, side="left")
    else:
        gaps_before = np.zeros(0, dtype=np.int64)

    e = ns + ng
    ev = {k: np.zeros(e, dtype=dt) for k, dt in _EVENT_FIELDS.items()}
    ev["valid"][:] = True
    s_idx = np.arange(ns) + gaps_before
    g_idx = np.arange(ng) + np.searchsorted(
        gaps_before, np.arange(ng), side="right"
    )
    ev["pct"][s_idx] = pct
    ev["nbytes"][s_idx] = nb
    for j in range(SUFFIX_ANCHORS + 1):
        ev[f"hddt_{j}"][s_idx] = anchors[:, j]
        ev[f"pf_{j}"][s_idx] = pf[:, j]
    for i in range(N_WINDOWS):
        ev[f"wf_{i}"][s_idx] = wf[:, i]
        ev[f"wn_{i}"][s_idx] = wn[:, i]
    for d in range(1, XMERGE_D + 1):
        ev[f"xm_{d}"][s_idx] = xm[:, d - 1]
    ev["net_t"][s_idx] = net_t
    ev["ssd_w"][s_idx] = ssd_w
    ev["mean_sz"][s_idx] = mean_sz
    ev["is_gap"][g_idx] = True
    ev["gap_sec"][g_idx] = gap_sec
    return ev


def _pad_len(n: int) -> int:
    """Shared tape length: next power of two (bounds jit recompiles)."""

    p = 8
    while p < n:
        p *= 2
    return p


def stack_events(
    tapes: Sequence[Mapping[str, np.ndarray]], pad_to: int | None = None
) -> dict[str, np.ndarray]:
    """Stack per-lane event tapes into ``(S, L)`` arrays.

    Tapes are right-padded with ``valid=False`` events to ``pad_to``
    (default: the next power of two above the longest tape, so programs
    of similar size share one compiled executable).
    """

    if not tapes:
        raise ValueError("need at least one lane")
    longest = max(len(t["valid"]) for t in tapes)
    s = pad_to if pad_to is not None else _pad_len(longest)
    if s < longest:
        raise ValueError(f"pad_to={s} < longest tape {longest}")
    out = {
        k: np.zeros((s, len(tapes)), dtype=dt)
        for k, dt in _EVENT_FIELDS.items()
    }
    for j, t in enumerate(tapes):
        n = len(t["valid"])
        for k in _EVENT_FIELDS:
            out[k][:n, j] = t[k]
    return out


def lane_consts(
    scheme: str,
    ssd_capacity: int,
    flush_gate: float | str = 0.5,
    ssd: object | None = None,
) -> dict[str, object]:
    """Per-lane scalar constants (scheme id, region capacity, gate,
    storage-model geometry).

    ``flush_gate="device"`` (flush-gate v2) is encoded as the sentinel
    ``gate = -1.0``: the gate then follows the foreground device instead
    of the detector percentage.  A stateful ``ssd`` (FTL) contributes
    its page/GC geometry as ``ftl_*`` constants; stateless lanes get
    inert defaults (``ftl_on=False``) so the jitted step stays one
    program for mixed fleets.
    """

    if scheme not in SCHEME_IDS:
        raise ValueError(f"unknown scheme {scheme!r}")
    if isinstance(flush_gate, str):
        if flush_gate != "device":
            raise ValueError(
                f"flush_gate must be a float or 'device', got {flush_gate!r}"
            )
        gate = -1.0
    else:
        gate = float(flush_gate)
    if scheme == "orangefs":
        cap = 0
    elif scheme == "orangefs-bb":
        cap = int(ssd_capacity)
    else:  # two-region pipeline: half the SSD per region
        cap = int(ssd_capacity) // 2
    ftl_on = bool(ssd is not None and getattr(ssd, "stateful", False))
    if ftl_on:
        page = float(ssd.page_size)
        tpp = float(ssd.t_page)
        terase = float(ssd.t_erase / ssd.n_channels)
        ppb = float(ssd.pages_per_block)
        phys = float(ssd.total_pages)
        low = float(ssd.gc_low_blocks * ssd.pages_per_block)
        high = float(ssd.gc_high_blocks * ssd.pages_per_block)
    else:  # inert defaults keep the where()-discarded branch NaN-free
        page, tpp, terase, ppb, phys, low, high = (
            1.0, 0.0, 0.0, 1.0, 1.0, 0.0, 0.0,
        )
    return {
        "scheme": np.int32(SCHEME_IDS[scheme]),
        "cap": np.int64(cap),
        "gate": np.float64(gate),
        "ftl_on": np.bool_(ftl_on),
        "ftl_page": np.float64(page),
        "ftl_tpp": np.float64(tpp),
        "ftl_terase": np.float64(terase),
        "ftl_ppb": np.float64(ppb),
        "ftl_phys": np.float64(phys),
        "ftl_low": np.float64(low),
        "ftl_high": np.float64(high),
    }


def initial_lane_state(
    scheme: str,
    window: int,
    threshold_warmup: Sequence[float] | None = None,
    ssd: object | None = None,
) -> dict[str, np.ndarray]:
    """One lane's initial state struct (numpy; stacked by the caller).

    ``threshold_warmup`` is replayed through the exact host policy
    (:class:`AdaptiveThreshold` / :class:`StaticWatermarkThreshold`) and
    the resulting window/hysteresis state transplanted — bit-identical
    to seeding the oracle's policy.
    """

    if window is None or window < 1:
        raise ValueError(
            "engine='device' needs a finite adaptive window "
            f"(got {window!r}); the unbounded PercentList is host-only"
        )
    win = np.full(window, np.inf, dtype=np.float64)
    win_n = 0
    win_p = 0
    static_rand = False
    if threshold_warmup is not None:
        if scheme == "ssdup+":
            pol = AdaptiveThreshold(window=window)
            pol.seed(threshold_warmup)
            recent = list(pol._recent)  # arrival order, oldest first
            win[: len(recent)] = recent
            win_n = len(recent)
            win_p = len(recent) % window
        elif scheme == "ssdup":
            static_rand = StaticWatermarkThreshold().seed(
                threshold_warmup
            )._last_random
    # FTL occupancy columns mirror the (possibly pre-used) host model
    if ssd is not None and getattr(ssd, "stateful", False):
        ftl_free = float(ssd.free_pages)
        ftl_live = float(ssd.live_pages)
    else:
        ftl_free = 0.0
        ftl_live = 0.0
    return {
        "clock": np.float64(0.0),
        "gap": np.float64(0.0),
        "pause": np.float64(0.0),
        "blocked": np.float64(0.0),
        "b_ssd": np.int64(0),
        "b_hdd": np.int64(0),
        "a_used": np.int64(0),
        "s_used": np.int64(0),
        "peak": np.int64(0),
        "a_fs": np.float64(0.0),
        # fraction of each of the last XMERGE_D streams buffered in the
        # ACTIVE region (newest first) — partners for the cross-stream
        # merge correction of the flush seek estimate
        **{f"xf_{d}": np.float64(0.0) for d in range(1, XMERGE_D + 1)},
        "j_left": np.float64(0.0),
        "j_rate": np.float64(1.0),  # >0 so where() divisions stay finite
        "j_alive": np.bool_(False),
        "flushes": np.int32(0),
        "win": win,
        "win_n": np.int32(win_n),
        "win_p": np.int32(win_p),
        "static_rand": np.bool_(static_rand),
        "cur_ssd": np.bool_(False),  # paper: apps start writing the HDD
        # FTL lane-state columns (zeros on constant-backend lanes)
        "ftl_free": np.float64(ftl_free),
        "ftl_live": np.float64(ftl_live),
        "ftl_reloc": np.float64(0.0),
    }


def _stack_lanes(dicts: Sequence[Mapping[str, np.ndarray]]) -> dict:
    return {k: np.stack([d[k] for d in dicts]) for k in dicts[0]}


# ---------------------------------------------------------------------------
# device side: the pure per-lane transition
# ---------------------------------------------------------------------------


def _i32(b):
    return b.astype(jnp.int32)


def _observe_and_route(g, lane, st, pct):
    """Threshold observe + Algorithm 1 hysteresis for one stream.

    Returns ``(dev_ssd, allowed, upd)`` — the device serving THIS stream,
    whether the traffic-aware gate lets the flusher run during it, and
    the policy-state updates (applied only on stream events of
    threshold schemes).
    """

    scheme = lane["scheme"]
    is_ofs = scheme == 0
    is_bb = scheme == 1
    is_plus = scheme == 3

    # -- adaptive threshold (Eq. 2/3): avgper over the PRE-insert sorted
    #    window, insert (circular buffer overwrites the oldest entry),
    #    index floor((1-avgper)*n) into the POST-insert sorted window
    win, win_n, win_p = st["win"], st["win_n"], st["win_p"]
    w = win.shape[0]
    pre_sorted = jnp.sort(win)  # +inf pads sort last
    csum = jnp.cumsum(pre_sorted)
    have = win_n > 0
    avg = jnp.where(
        have, csum[jnp.maximum(win_n - 1, 0)] / jnp.maximum(win_n, 1), 0.0
    )
    win2 = win.at[win_p].set(pct)
    n2 = jnp.minimum(win_n + 1, w)
    p2 = (win_p + 1) % w
    post_sorted = jnp.sort(win2)
    idx = jnp.clip(jnp.floor((1.0 - avg) * n2).astype(jnp.int32), 0, n2 - 1)
    adap_thr = jnp.where(have, post_sorted[idx], g["default_thr"])

    # -- static watermarks (SSDUP): hysteresis between high/low
    sr = st["static_rand"]
    sr2 = jnp.where(
        pct > g["static_high"],
        True,
        jnp.where(pct < g["static_low"], False, sr),
    )
    static_thr = jnp.where(sr2, g["static_low"], g["static_high"])

    thr = jnp.where(is_plus, adap_thr, static_thr)

    # -- Algorithm 1: this stream rides the PREVIOUS decision; the new
    #    percentage-vs-threshold comparison steers the NEXT stream
    #    (equality keeps the current device)
    cur = st["cur_ssd"]
    dev_ssd = jnp.where(is_bb, True, jnp.where(is_ofs, False, cur))
    cur2 = jnp.where(pct > thr, True, jnp.where(pct < thr, False, cur))

    # traffic-aware gate (Section 2.4.2): only ssdup+ pauses; BB jobs are
    # forced and ssdup flushes immediately.  gate < 0 is the sentinel for
    # flush_gate="device" (v2): flush exactly while the foreground stream
    # writes the SSD (HDD quiet), pause when it writes the HDD
    allowed = jnp.where(
        is_plus,
        jnp.where(lane["gate"] < 0.0, dev_ssd, pct >= lane["gate"]),
        True,
    )

    upd = {
        "win": win2,
        "win_n": n2,
        "win_p": p2,
        "static_rand": sr2,
        "cur_ssd": cur2,
    }
    return dev_ssd, allowed, upd


def _ssd_fill_loop(g, lane, st, ev, allowed, dev_ssd):
    """SSD-routed stream: fill regions, swap/block/trigger, overflow.

    Returns the post-loop state pieces plus the overflowed byte count
    (plain BB only; 0 elsewhere).
    """

    scheme = lane["scheme"]
    is_bb = scheme == 1
    is_tworeg = (scheme == 2) | (scheme == 3)
    cap = lane["cap"]
    nb = ev["nbytes"]
    nb_f = jnp.maximum(nb, 1).astype(jnp.float64)
    margin = jnp.maximum(ev["mean_sz"], (cap // 256).astype(jnp.float64))

    def cond(c):
        return (c["rem"] > 0) & ~c["ovf"]

    def body(c):
        bb_ovf = is_bb & c["j_alive"]  # BB drains: whole rest overflows
        room = cap - c["a_used"]
        # plain BB stops at the eager-trigger request — the first append
        # that leaves free space below the margin — NOT at a full region.
        # The oracle appends whole requests, so the fill stops on a
        # request boundary: k = floor((room - margin)/size) + 1 more
        # requests land before the trigger fires (k*size <= room because
        # margin >= size).
        room_f = room.astype(jnp.float64)
        m = jnp.maximum(ev["mean_sz"], 1.0)
        k = jnp.floor((room_f - margin) / m) + 1.0
        bb_cap = jnp.ceil(jnp.maximum(k, 0.0) * m).astype(jnp.int64)
        # two-region fills also stop on a request boundary: the oracle
        # appends every request that fits ENTIRELY, then swaps/blocks
        tr_cap = (jnp.floor(room_f / m) * m).astype(jnp.int64)
        fill_cap = jnp.where(is_bb, jnp.minimum(room, bb_cap), tr_cap)
        fill = jnp.where(bb_ovf, 0, jnp.minimum(c["rem"], fill_cap))
        frac = fill / nb_f
        # -- storage-model device time for this fill.  Constant backend:
        # the pro-rated per-request SSD wall sum (bit-path identical to
        # the pre-FTL engine).  FTL backend: page programs on N channels
        # plus an analytic greedy-GC charge when the fill dips the free
        # pool below the low watermark — the aggregate counterpart of
        # FTLModel._collect with u = mean valid fraction of written
        # blocks (greedy victims are at-most-average, so clip at 0.97).
        pages = fill.astype(jnp.float64) / lane["ftl_page"]
        free1 = c["ftl_free"] - pages
        live1 = c["ftl_live"] + pages
        gc_on = lane["ftl_on"] & (fill > 0) & (free1 < lane["ftl_low"])
        u = jnp.clip(
            live1 / jnp.maximum(lane["ftl_phys"] - free1, 1.0), 0.0, 0.97
        )
        need = jnp.maximum(lane["ftl_high"] - free1, 0.0)
        nblk = need / jnp.maximum(lane["ftl_ppb"] * (1.0 - u), 1.0)
        reloc = nblk * lane["ftl_ppb"] * u
        gc_t = reloc * lane["ftl_tpp"] + nblk * lane["ftl_terase"]
        seg_dev = pages * lane["ftl_tpp"] + jnp.where(gc_on, gc_t, 0.0)
        segw = jnp.where(
            lane["ftl_on"],
            jnp.maximum(ev["net_t"] * frac, seg_dev),
            ev["ssd_w"] * frac,
        )

        # flush bookkeeping while the foreground writes the SSD: the job
        # drains at its full Eq. 6 effective rate (no HDD contention)
        progressing = c["j_alive"] & allowed
        prog = c["j_rate"] * segw
        completed = progressing & (prog >= c["j_left"])
        # a completing flush retires its region's log: the FTL trims
        # those pages (they stop being live on flash)
        trim_b = jnp.where(completed, c["s_used"], 0)
        j_left = jnp.where(
            completed,
            0.0,
            jnp.where(progressing, c["j_left"] - prog, c["j_left"]),
        )
        pause = c["pause"] + jnp.where(c["j_alive"] & ~allowed, segw, 0.0)
        flushes = c["flushes"] + _i32(completed)
        s_used = jnp.where(completed, 0, c["s_used"])
        j_alive = c["j_alive"] & ~completed

        clock = c["clock"] + segw
        a_used = c["a_used"] + fill
        # Eq. 6 seek accrual: the region sorts its arrival-window of the
        # stream ALONE, so score the fill against the dyadic window
        # anchors of the nearest scale — per window, the distinct-file
        # baseline lands whole with any coverage and only the extent
        # breaks scale with the covered fraction
        a0 = (nb_f - c["rem"].astype(jnp.float64)) / nb_f
        wfrac = fill.astype(jnp.float64) / nb_f
        a1 = a0 + wfrac
        scale = jnp.clip(
            jnp.round(-jnp.log2(jnp.maximum(wfrac, 1e-9))),
            0,
            WINDOW_SCALES - 1,
        ).astype(jnp.int32)
        seg_fs = jnp.zeros_like(nb_f)
        col = 0
        for s_ in range(WINDOW_SCALES):
            nw = 1 << s_
            acc = jnp.zeros_like(nb_f)
            for wj in range(nw):
                lo = wj / nw
                cov = jnp.clip(
                    (jnp.minimum(a1, lo + 1.0 / nw) - jnp.maximum(a0, lo))
                    * nw,
                    0.0,
                    1.0,
                )
                wfv = ev[f"wf_{col}"]
                wnv = ev[f"wn_{col}"]
                acc = acc + jnp.where(
                    cov > 0, wnv + (wfv - wnv) * cov, 0.0
                )
                col += 1
            seg_fs = jnp.where(scale == s_, acc, seg_fs)
        # prefix-aligned fills (every BB fill, the first two-region fill
        # of a stream) have EXACT anchors at the request quantiles: lerp
        # the prefix seek counts instead of the dyadic window estimate
        ppos = jnp.clip(a1 * SUFFIX_ANCHORS, 0.0, float(SUFFIX_ANCHORS))
        pj = jnp.clip(
            jnp.floor(ppos), 0.0, float(SUFFIX_ANCHORS - 1)
        ).astype(jnp.int32)
        plam = ppos - pj.astype(jnp.float64)
        pref_fs = jnp.zeros_like(nb_f)
        for j in range(SUFFIX_ANCHORS):
            sel = pj == j
            lerp = (1.0 - plam) * ev[f"pf_{j}"] + plam * ev[f"pf_{j + 1}"]
            pref_fs = jnp.where(sel, lerp, pref_fs)
        seg_fs = jnp.where(a0 <= 0.0, pref_fs, seg_fs)
        seg_fs = jnp.where(fill > 0, seg_fs, 0.0)
        # cross-stream merge correction: pairs this stream forms with a
        # predecessor still (fractionally) in the active region cost no
        # seek once the region sorts its union; pro-rate by this fill's
        # share of the stream
        seg_xm = wfrac * sum(
            ev[f"xm_{d}"] * c[f"xf_{d}"] for d in range(1, XMERGE_D + 1)
        )
        a_fs = jnp.maximum(c["a_fs"] + seg_fs - seg_xm, 0.0)
        b_ssd = c["b_ssd"] + fill
        rem = c["rem"] - fill

        # -- plain BB eager trigger: the append that leaves free space
        #    below max(request, cap/256) schedules a forced flush
        bb_trig = is_bb & ~bb_ovf & ((room - fill) < margin)
        # -- two-region swap: the next request does not fit
        swap = is_tworeg & (rem > 0)
        # a live flush on the standby region blocks the writer: drain it
        # at the job's exclusive effective rate, then swap
        do_block = swap & j_alive
        dtb = jnp.where(do_block, j_left / c["j_rate"], 0.0)
        clock = clock + dtb
        blocked = c["blocked"] + dtb
        flushes = flushes + _i32(do_block)
        j_alive = j_alive & ~do_block
        j_left = jnp.where(do_block, 0.0, j_left)
        trim_b = trim_b + jnp.where(do_block, s_used, 0)
        s_used = jnp.where(do_block, 0, s_used)

        # schedule the filled region's flush (Eq. 6: seeks = pro-rated
        # extent-start count of the region's content)
        sched = swap | bb_trig
        jb = a_used
        jb_f = jb.astype(jnp.float64)
        service = a_fs * g["seek_time"] + jb_f / g["seq_bw"]
        n_rate = jnp.where(jb > 0, jb_f / service, g["seq_bw"])
        j_rate = jnp.where(sched, n_rate, c["j_rate"])
        j_left = jnp.where(sched, jb_f, j_left)
        j_alive = j_alive | sched
        s_used = jnp.where(sched, jb, s_used)
        a_used = jnp.where(sched, 0, a_used)
        a_fs = jnp.where(sched, 0.0, a_fs)
        # scheduling hands the region's content to the flusher: earlier
        # streams leave the active region, and only fills AFTER the swap
        # count toward this stream's presence in it
        xf = {
            f"xf_{d}": jnp.where(sched, 0.0, c[f"xf_{d}"])
            for d in range(1, XMERGE_D + 1)
        }
        cur_xf = jnp.where(sched, 0.0, c["cur_xf"] + wfrac)

        ovf = c["ovf"] | bb_ovf | (bb_trig & (rem > 0))
        # FTL occupancy columns: programs consume free pages, GC restores
        # the high watermark, retired (trimmed) region logs leave live
        trim_p = trim_b.astype(jnp.float64) / lane["ftl_page"]
        ftl_free = jnp.where(
            lane["ftl_on"],
            jnp.where(gc_on, lane["ftl_high"], free1),
            c["ftl_free"],
        )
        ftl_live = jnp.where(lane["ftl_on"], live1 - trim_p, c["ftl_live"])
        ftl_reloc = c["ftl_reloc"] + jnp.where(gc_on, reloc, 0.0)
        return {
            "rem": rem, "ovf": ovf, "clock": clock, "pause": pause,
            "blocked": blocked, "b_ssd": b_ssd, "flushes": flushes,
            "a_used": a_used, "s_used": s_used, "a_fs": a_fs,
            "j_left": j_left, "j_rate": j_rate, "j_alive": j_alive,
            "cur_xf": cur_xf, "ftl_free": ftl_free, "ftl_live": ftl_live,
            "ftl_reloc": ftl_reloc, **xf,
        }

    # HDD-routed streams and capacity-less lanes (orangefs) must never
    # enter the loop: a vmapped while_loop spins until EVERY lane's
    # condition clears, and a cap=0 lane would make no progress
    init = {
        "rem": jnp.where(dev_ssd & (cap > 0), nb, 0),
        "ovf": jnp.asarray(False),
        "clock": st["clock"], "pause": st["pause"],
        "blocked": st["blocked"], "b_ssd": st["b_ssd"],
        "flushes": st["flushes"], "a_used": st["a_used"],
        "s_used": st["s_used"], "a_fs": st["a_fs"],
        "j_left": st["j_left"], "j_rate": st["j_rate"],
        "j_alive": st["j_alive"],
        "cur_xf": jnp.zeros_like(st["a_fs"]),
        "ftl_free": st["ftl_free"], "ftl_live": st["ftl_live"],
        "ftl_reloc": st["ftl_reloc"],
        **{f"xf_{d}": st[f"xf_{d}"] for d in range(1, XMERGE_D + 1)},
    }
    return lax.while_loop(cond, body, init)


def _hdd_advance(g, lane, c, hdd_b, nb, ev, allowed):
    """Foreground HDD write of ``hdd_b`` bytes (whole stream or BB
    overflow suffix), Eq. 7 interference with a concurrent flush.

    The HDD wall for a *suffix* of a stream is not proportional to its
    bytes — the oracle re-scores the overflow tail from scratch, and a
    strided tail loses the sorted contiguity of the whole stream.  The
    event tape carries ``SUFFIX_ANCHORS + 1`` precomputed suffix walls
    (anchor j = suffix keeping the last ``1 - j/A`` fraction of
    requests); we hat-weight interpolate between the two neighbouring
    anchors.  frac = 1 lands exactly on anchor 0, which is built from
    the stream scores, so pure-HDD whole streams stay bit-exact."""

    nb_f = jnp.maximum(nb, 1).astype(jnp.float64)
    frac = hdd_b.astype(jnp.float64) / nb_f
    pos = (1.0 - frac) * SUFFIX_ANCHORS
    dt = jnp.zeros_like(frac)
    for j in range(SUFFIX_ANCHORS + 1):
        w = jnp.maximum(0.0, 1.0 - jnp.abs(pos - j))
        dt = dt + w * ev[f"hddt_{j}"]
    net = ev["net_t"] * frac
    do = hdd_b > 0
    flushing = c["j_alive"]
    adv = flushing & allowed
    wall_alone = jnp.maximum(net, dt)
    wall_shared = jnp.maximum(net, dt * g["slowdown"])
    wall = jnp.where(adv, wall_shared, wall_alone)
    prog = c["j_rate"] * g["flush_frac"] * wall
    completed = do & adv & (prog >= c["j_left"])
    j_left = jnp.where(
        completed,
        0.0,
        jnp.where(do & adv, c["j_left"] - prog, c["j_left"]),
    )
    trim_p = jnp.where(completed, c["s_used"], 0).astype(
        jnp.float64
    ) / lane["ftl_page"]
    return {
        **c,
        "clock": c["clock"] + jnp.where(do, wall, 0.0),
        "pause": c["pause"]
        + jnp.where(do & flushing & ~adv, wall_alone, 0.0),
        "b_hdd": c["b_hdd"] + hdd_b,
        "flushes": c["flushes"] + _i32(completed),
        "s_used": jnp.where(completed, 0, c["s_used"]),
        "j_alive": c["j_alive"] & ~completed,
        "j_left": j_left,
        "ftl_live": jnp.where(
            lane["ftl_on"], c["ftl_live"] - trim_p, c["ftl_live"]
        ),
    }


def _gap_step(lane, st, sec):
    """Compute phase: the flusher gets the HDD to itself (Eq. 6 rate)."""

    need = st["j_left"] / st["j_rate"]
    full = st["j_alive"] & (need <= sec)
    partial = st["j_alive"] & ~full
    j_left = jnp.where(
        full, 0.0,
        jnp.where(partial, st["j_left"] - st["j_rate"] * sec, st["j_left"]),
    )
    trim_p = jnp.where(full, st["s_used"], 0).astype(
        jnp.float64
    ) / lane["ftl_page"]
    return {
        **st,
        "clock": st["clock"] + sec,
        "gap": st["gap"] + sec,
        "flushes": st["flushes"] + _i32(full),
        "s_used": jnp.where(full, 0, st["s_used"]),
        "j_alive": st["j_alive"] & ~full,
        "j_left": j_left,
        "ftl_live": jnp.where(
            lane["ftl_on"], st["ftl_live"] - trim_p, st["ftl_live"]
        ),
    }


def _stream_step(g, lane, st, ev):
    """One stream event for one lane (all schemes, flag-selected)."""

    scheme = lane["scheme"]
    is_tworeg = (scheme == 2) | (scheme == 3)

    dev_ssd, allowed, upd = _observe_and_route(g, lane, st, ev["pct"])

    c = _ssd_fill_loop(g, lane, st, ev, allowed, dev_ssd)
    # bytes headed to the HDD in the foreground: the whole stream when
    # HDD-routed, the unbuffered suffix when plain BB overflows
    hdd_b = jnp.where(
        dev_ssd, jnp.where(c["ovf"], c["rem"], 0), ev["nbytes"]
    )
    # SSD-path state only applies to SSD-routed streams
    base = {
        k: jnp.where(dev_ssd, c[k], st[k])
        for k in ("clock", "pause", "blocked", "b_ssd", "flushes",
                  "a_used", "s_used", "a_fs", "j_left", "j_rate",
                  "j_alive", "ftl_free", "ftl_live", "ftl_reloc")
    }
    base["b_hdd"] = st["b_hdd"]
    base["gap"] = st["gap"]
    base["peak"] = st["peak"]

    out = _hdd_advance(g, lane, base, hdd_b, ev["nbytes"], ev, allowed)
    # shift the cross-merge partner window one stream: this stream's
    # active-region fraction enters at distance 1 (an HDD-routed stream
    # enters as 0 — its bytes never reached the region)
    out["xf_1"] = jnp.where(dev_ssd, c["cur_xf"], 0.0)
    for d in range(2, XMERGE_D + 1):
        out[f"xf_{d}"] = jnp.where(
            dev_ssd, c[f"xf_{d - 1}"], st[f"xf_{d - 1}"]
        )
    # the oracle samples occupancy at END of stream — after the overflow
    # HDD writes, during which the flush may complete and reset the
    # region — so sample post-advance state
    out["peak"] = jnp.where(
        dev_ssd,
        jnp.maximum(st["peak"], out["a_used"] + out["s_used"]),
        st["peak"],
    )
    # threshold/routing state evolves on every stream of a threshold
    # scheme (observe happens whichever device served the stream)
    for k, v in upd.items():
        out[k] = jnp.where(is_tworeg, v, st[k])
    for k in ("win", "win_n", "win_p", "static_rand", "cur_ssd"):
        out.setdefault(k, st[k])
    return out


def _event_step(g, lane, st, ev):
    """The per-lane transition: gap, stream, or padded no-op."""

    strm = _stream_step(g, lane, st, ev)
    gap = _gap_step(lane, st, ev["gap_sec"])
    pick = lambda a, b, c_: jnp.where(
        ev["valid"], jnp.where(ev["is_gap"], a, b), c_
    )
    return {k: pick(gap[k], strm[k], st[k]) for k in st}


def _final_drain(g, st):
    """End-of-trace drain (vectorized over lanes): finish the in-flight
    job, then flush the still-buffered active region (Eq. 6)."""

    d1 = jnp.where(st["j_alive"], st["j_left"] / st["j_rate"], 0.0)
    has_active = st["a_used"] > 0
    a_f = st["a_used"].astype(jnp.float64)
    d2 = jnp.where(
        has_active,
        st["a_fs"] * g["seek_time"] + a_f / g["seq_bw"],
        0.0,
    )
    total = st["clock"] + d1 + d2
    return {
        "io_seconds": st["clock"] - st["gap"],
        "total_seconds": total,
        "bytes_to_ssd": st["b_ssd"],
        "bytes_to_hdd_direct": st["b_hdd"],
        "flushes": st["flushes"] + _i32(st["j_alive"]) + _i32(has_active),
        "flush_paused_seconds": st["pause"],
        "blocked_seconds": st["blocked"],
        "peak_ssd_occupancy": st["peak"],
        # FTL diagnostics (zeros on constant-backend lanes)
        "ftl_reloc_pages": st["ftl_reloc"],
        "ftl_live_pages": st["ftl_live"],
    }


def _replay_program(g, lanes, state0, events):
    def scan_step(st, ev):
        new = jax.vmap(
            lambda lane, s, e: _event_step(g, lane, s, e)
        )(lanes, st, ev)
        return new, None

    final, _ = lax.scan(scan_step, state0, events)
    return _final_drain(g, final)


@functools.lru_cache(maxsize=1)
def _jitted_program():
    return jax.jit(_replay_program)


def _check_outputs(out):
    """checkify guards over the replay outputs (sanitize mode): any
    NaN/Inf produced inside the scan propagates through the accumulated
    clocks/ledgers to an output and trips a finite check; byte ledgers
    must be non-negative and io time can never exceed total time.

    A separate program from the replay itself: checkify cannot traverse
    the region-fill ``while_loop`` under ``vmap`` (batched while), so the
    replay runs unchecked and this checker discharges over its results.
    """

    for k in ("io_seconds", "total_seconds", "flush_paused_seconds",
              "blocked_seconds"):
        checkify.check(
            jnp.all(jnp.isfinite(out[k])), f"non-finite {k} in device replay"
        )
        checkify.check(
            jnp.all(out[k] >= 0), f"negative {k} in device replay"
        )
    for k in ("bytes_to_ssd", "bytes_to_hdd_direct", "flushes",
              "peak_ssd_occupancy"):
        checkify.check(
            jnp.all(out[k] >= 0), f"negative {k} in device replay"
        )
    checkify.check(
        jnp.all(out["total_seconds"] >= out["io_seconds"]),
        "io_seconds exceeds total_seconds in device replay",
    )


@functools.lru_cache(maxsize=1)
def _jitted_output_checker():
    checked = checkify.checkify(_check_outputs, errors=checkify.user_checks)
    return jax.jit(checked)


def _globals(
    hdd: HDDModel, interference: InterferenceModel
) -> dict[str, np.float64]:
    return {
        "seek_time": np.float64(hdd.seek_time),
        "seq_bw": np.float64(hdd.seq_bw),
        "slowdown": np.float64(interference.foreground_slowdown()),
        "flush_frac": np.float64(interference.flush_rate_fraction()),
        "default_thr": np.float64(DEFAULT_THRESHOLD),
        "static_high": np.float64(0.45),
        "static_low": np.float64(0.30),
    }


def replay_lanes(
    events: Mapping[str, np.ndarray],
    lanes: Mapping[str, np.ndarray],
    state0: Mapping[str, np.ndarray],
    hdd: HDDModel | None = None,
    interference: InterferenceModel | None = None,
    sanitize: bool | None = None,
) -> dict[str, np.ndarray]:
    """Run every lane's replay in one jitted device call.

    Accuracy contract: float64 on device, accurate to the
    ``DEVICE_TOLERANCES`` tiers against the batched numpy oracle (scan
    reassociates float accumulation, so bit-exactness is not promised).

    ``events`` is the stacked ``(S, L)`` tape (:func:`stack_events`),
    ``lanes``/``state0`` are stacked ``(L,)``/``(L, ...)`` structs.
    Returns per-lane result arrays (io/total seconds, byte splits, flush
    and pause counters, peak occupancy) as host numpy.

    With ``sanitize`` on (``True``/``REPRO_SANITIZE=1``/the
    :func:`repro.analysis.sanitize.sanitizing` override) the program runs
    under :mod:`jax.experimental.checkify` — NaN/Inf reaching any
    result, negative ledgers, or a backwards clock raise
    :class:`~repro.analysis.sanitize.SanitizerError`.
    """

    _require_jax()
    g = _globals(hdd or HDDModel(), interference or InterferenceModel())
    with enable_x64():
        out = _jitted_program()(
            g, dict(lanes), dict(state0), dict(events)
        )
        if _sanitize.resolve(sanitize):
            err, _ = _jitted_output_checker()(out)
            try:
                err.throw()
            except Exception as e:
                raise _sanitize.SanitizerError(
                    f"device replay invariant violated: {e}"
                ) from e
        return {k: np.asarray(v) for k, v in out.items()}


# ---------------------------------------------------------------------------
# single-lane entry point (IONodeSimulator engine="device")
# ---------------------------------------------------------------------------


def per_app_bytes(batch) -> dict[int, int]:
    """Per-app byte totals (order-independent, scheme-independent)."""

    if not batch.num_requests:
        return {}
    apps, inverse = np.unique(batch.app_ids, return_inverse=True)
    sums = np.zeros(len(apps), dtype=np.int64)
    np.add.at(sums, inverse, batch.sizes)
    return {int(a): int(s) for a, s in zip(apps, sums)}


def simulate_device(
    batch,
    scores,
    scheme: str = "ssdup+",
    ssd_capacity: int = 8 << 30,
    hdd: HDDModel | None = None,
    ssd: "SSDModel | object | None" = None,
    link: IngestLink | None = None,
    interference: InterferenceModel | None = None,
    stream_len: int = DEFAULT_STREAM_LEN,
    flush_gate: float | str = 0.5,
    adaptive_window: int = 64,
    threshold_warmup: Sequence[float] | None = None,
    sanitize: bool | None = None,
):
    """Replay one shard on one lane; returns a
    :class:`~repro.core.simulator.SimResult` (see the module docstring
    for the accuracy contract vs the numpy engines)."""

    from .simulator import SimResult  # deferred: simulator imports us lazily

    _require_jax()
    tape = build_events(
        batch, scores, stream_len=stream_len, hdd=hdd, ssd=ssd, link=link
    )
    events = stack_events([tape])
    lanes = _stack_lanes(
        [lane_consts(scheme, ssd_capacity, flush_gate, ssd=ssd)]
    )
    state0 = _stack_lanes(
        [initial_lane_state(scheme, adaptive_window, threshold_warmup,
                            ssd=ssd)]
    )
    out = replay_lanes(events, lanes, state0, hdd=hdd,
                       interference=interference, sanitize=sanitize)
    b_ssd = int(out["bytes_to_ssd"][0])
    b_hdd = int(out["bytes_to_hdd_direct"][0])
    return SimResult(
        scheme=scheme,
        io_seconds=float(out["io_seconds"][0]),
        total_seconds=float(out["total_seconds"][0]),
        total_bytes=b_ssd + b_hdd,
        bytes_to_ssd=b_ssd,
        bytes_to_hdd_direct=b_hdd,
        flushes=int(out["flushes"][0]),
        flush_paused_seconds=float(out["flush_paused_seconds"][0]),
        blocked_seconds=float(out["blocked_seconds"][0]),
        peak_ssd_occupancy=int(out["peak_ssd_occupancy"][0]),
        metadata_bytes=0,
        per_app_bytes=per_app_bytes(batch),
    )
