"""Data redirector — SSDUP+ Algorithm 1 (paper Section 2.3).

The redirector consumes request streams, scores each with the random factor,
feeds the score to a threshold policy (adaptive by default, SSDUP's static
watermarks as the baseline), and decides which *device* the NEXT stream's
requests are sent to.  Note the one-stream lag in the paper's algorithm: the
percentage of the latest completed stream guides the direction of *upcoming*
requests ("the comparison between percentage and threshold is used to guide
the direction of the upcoming requests", Section 2.3.2) — HPC access patterns
are stable enough for the lag to be harmless.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Iterable, Protocol, Sequence

from .random_factor import (
    DEFAULT_STREAM_LEN,
    Request,
    StreamGrouper,
    stream_percentage,
)
from .adaptive import AdaptiveThreshold


class Device(enum.Enum):
    HDD = "hdd"  # slow tier, written directly
    SSD = "ssd"  # fast tier (burst buffer)


class ThresholdPolicy(Protocol):
    def observe(self, percentage: float) -> float: ...
    @property
    def threshold(self) -> float: ...
    def reset(self) -> None: ...


@dataclasses.dataclass(frozen=True, slots=True)
class RoutedStream:
    """One stream plus the routing decision that applied to it."""

    stream: tuple[Request, ...]
    device: Device
    percentage: float  # of THIS stream (informational)
    threshold: float  # threshold in effect when the decision was made
    index: int

    @property
    def bytes(self) -> int:
        return sum(r.size for r in self.stream)


class DataRedirector:
    """Algorithm 1: route request streams to the fast or slow tier."""

    def __init__(
        self,
        policy: ThresholdPolicy | None = None,
        stream_len: int = DEFAULT_STREAM_LEN,
        initial_device: Device = Device.HDD,
    ):
        self.policy = policy if policy is not None else AdaptiveThreshold()
        self.grouper = StreamGrouper(stream_len)
        # Paper: "When the execution of an application starts, the data is
        # written to HDD" — detection needs history before redirecting.
        self.current_device = initial_device
        self._index = 0
        self.bytes_to = {Device.HDD: 0, Device.SSD: 0}
        self.streams_to = {Device.HDD: 0, Device.SSD: 0}
        self.decisions: list[tuple[float, float, Device]] = []  # (pct, thr, dev)

    # ------------------------------------------------------------------
    def route_scored(self, nbytes: int, percentage: float) -> Device:
        """Route one already-scored stream without materializing requests.

        The batched replay engine's entry point: identical policy/device
        evolution to :meth:`route_stream` (same observe, same hysteresis,
        same stats), driven by the stream's byte count and precomputed
        random percentage alone — no per-request Python.
        """

        # The device for THIS stream was decided by the previous stream
        # (Algorithm 1's "send requests of next stream to ...").
        device = self.current_device
        threshold_in_effect = self.policy.threshold
        self.policy.observe(percentage)

        self._index += 1
        self.bytes_to[device] += nbytes
        self.streams_to[device] += 1
        self.decisions.append((percentage, threshold_in_effect, device))

        # Decide where the NEXT stream goes (hysteresis: equality keeps).
        new_threshold = self.policy.threshold
        if percentage > new_threshold and device is Device.HDD:
            self.current_device = Device.SSD
        elif percentage < new_threshold and device is Device.SSD:
            self.current_device = Device.HDD
        return device

    def route_stream(
        self, stream: Sequence[Request], percentage: float | None = None
    ) -> RoutedStream:
        """Route one complete stream; updates the policy and device state.

        ``percentage`` lets a caller that already scored the stream (e.g.
        the simulator replaying with precomputed batched scores) skip the
        per-stream sort here; it must equal ``stream_percentage(stream)``.
        """

        pct = stream_percentage(stream) if percentage is None else percentage
        index = self._index
        threshold_in_effect = self.policy.threshold
        nbytes = sum(r.size for r in stream)
        device = self.route_scored(nbytes, pct)
        return RoutedStream(
            stream=tuple(stream),
            device=device,
            percentage=pct,
            threshold=threshold_in_effect,
            index=index,
        )

    def route(self, requests: Iterable[Request]) -> Iterable[RoutedStream]:
        """Stream-group an arriving request sequence and route each stream."""

        for stream in self.grouper.push_many(requests):
            yield self.route_stream(stream)

    def finish(self) -> RoutedStream | None:
        """Route the trailing partial stream, if any."""

        tail = self.grouper.flush()
        if tail is None:
            return None
        return self.route_stream(tail)

    # -- stats ----------------------------------------------------------
    @property
    def ssd_byte_ratio(self) -> float:
        total = self.bytes_to[Device.HDD] + self.bytes_to[Device.SSD]
        return self.bytes_to[Device.SSD] / total if total else 0.0

    @property
    def ssd_stream_ratio(self) -> float:
        total = self.streams_to[Device.HDD] + self.streams_to[Device.SSD]
        return self.streams_to[Device.SSD] / total if total else 0.0

    def reset(self) -> None:
        self.policy.reset()
        self.current_device = Device.HDD
