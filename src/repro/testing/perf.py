"""BENCH perf-trajectory artifact + regression gate.

``python -m benchmarks.run`` emits ``experiments/BENCH_<n>.json`` (one
``n`` per PR) so every PR carries its performance trajectory against the
previous anchor:

.. code-block:: json

    {
      "schema": "bench-trajectory/v1",
      "index": 6,
      "anchor": "BENCH_5.json",            // null on the first emission
      "regression_threshold": 0.15,
      "suites": {
        "fleet": {
          "us_per_call": 41605782.1,       // sum over the suite's rows
          "rows": {"fleet_ssdup+_8n": 2612733.4, ...},
          "matched_rows": 24,              // rows shared with the anchor
          "speedup_vs_anchor": 1.03,       // anchor_us / current_us
          "regression": false              // speedup < 1 - threshold
        }, ...
      },
      "any_regression": false
    }

Speedups are computed over the rows *shared* with the anchor (renamed or
new rows never poison the ratio); a suite absent from the anchor gets
``speedup_vs_anchor: null``.  ``--check`` exits nonzero iff any suite
regresses by more than the threshold (default +/-15%).  Partial runs
(``--only``) merge into the existing artifact instead of truncating it,
and every file write here is atomic (temp file + ``os.replace``), so an
interrupted run can never leave a half-written artifact behind.
"""

from __future__ import annotations

import json
import os
import pathlib
import re
import tempfile
from typing import Mapping, Sequence

SCHEMA = "bench-trajectory/v1"
CURRENT_INDEX = 8  # bump per PR; the previous artifact becomes the anchor
REGRESSION_THRESHOLD = 0.15

_BENCH_RE = re.compile(r"^BENCH_(\d+)\.json$")


def bench_filename(index: int) -> str:
    return f"BENCH_{index}.json"


def atomic_write_text(path: str | os.PathLike, text: str) -> None:
    """Write ``text`` to ``path`` via temp file + rename (same directory,
    so the replace is atomic); an interrupted writer leaves the previous
    file contents untouched."""

    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(
        dir=path.parent, prefix=f".{path.name}.", suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def find_anchor(directory: str | os.PathLike,
                index: int) -> tuple[int, pathlib.Path] | None:
    """Highest-numbered ``BENCH_k.json`` with ``k < index``, if any."""

    best = None
    for p in pathlib.Path(directory).glob("BENCH_*.json"):
        m = _BENCH_RE.match(p.name)
        if m and int(m.group(1)) < index:
            k = int(m.group(1))
            if best is None or k > best[0]:
                best = (k, p)
    return best


def build_trajectory(
    rows_by_suite: Mapping[str, Mapping[str, float]],
    index: int = CURRENT_INDEX,
    anchor_payload: Mapping | None = None,
    anchor_name: str | None = None,
    threshold: float = REGRESSION_THRESHOLD,
) -> dict:
    """Assemble the trajectory payload from per-suite ``{row: us}`` maps."""

    anchor_suites = (anchor_payload or {}).get("suites", {})
    suites = {}
    for name, rows in rows_by_suite.items():
        rows = {k: float(v) for k, v in rows.items()}
        anchor_rows = anchor_suites.get(name, {}).get("rows", {})
        matched = sorted(set(rows) & set(anchor_rows))
        speedup = None
        if matched:
            cur = sum(rows[k] for k in matched)
            anc = sum(float(anchor_rows[k]) for k in matched)
            speedup = anc / cur if cur > 0 else None
        suites[name] = {
            "us_per_call": sum(rows.values()),
            "rows": rows,
            "matched_rows": len(matched),
            "speedup_vs_anchor": speedup,
            "regression": speedup is not None and speedup < 1.0 - threshold,
        }
    return {
        "schema": SCHEMA,
        "index": index,
        "anchor": anchor_name,
        "regression_threshold": threshold,
        "suites": suites,
        "any_regression": any(s["regression"] for s in suites.values()),
    }


def emit_trajectory(
    rows_by_suite: Mapping[str, Mapping[str, float]],
    directory: str | os.PathLike = "experiments",
    index: int = CURRENT_INDEX,
    threshold: float = REGRESSION_THRESHOLD,
) -> tuple[pathlib.Path, dict]:
    """Build and atomically write ``BENCH_<index>.json``.

    Suites from an existing same-index artifact that were *not* run this
    time are carried over verbatim, so a partial ``--only`` run refreshes
    its suites without truncating the rest.
    """

    directory = pathlib.Path(directory)
    anchor = find_anchor(directory, index)
    anchor_payload = None
    anchor_name = None
    if anchor is not None:
        anchor_name = anchor[1].name
        with open(anchor[1]) as f:
            anchor_payload = json.load(f)

    payload = build_trajectory(
        rows_by_suite, index, anchor_payload, anchor_name, threshold)

    out = directory / bench_filename(index)
    if out.exists():
        with open(out) as f:
            previous = json.load(f)
        for name, entry in previous.get("suites", {}).items():
            payload["suites"].setdefault(name, entry)
        payload["any_regression"] = any(
            s["regression"] for s in payload["suites"].values())

    atomic_write_text(out, json.dumps(payload, indent=1, sort_keys=True) + "\n")
    return out, payload


def check_trajectory(payload: Mapping) -> list[str]:
    """Human-readable regression findings; empty list == gate passes."""

    problems = []
    for name in sorted(payload.get("suites", {})):
        s = payload["suites"][name]
        if s.get("regression"):
            problems.append(
                f"suite {name!r} regressed: speedup_vs_anchor="
                f"{s['speedup_vs_anchor']:.3f} over {s['matched_rows']} "
                f"matched rows (threshold "
                f"{payload.get('regression_threshold')})"
            )
    return problems


def format_trajectory(payload: Mapping) -> str:
    """Compact per-suite table for stdout."""

    lines = [f"{'suite':18s} {'us_per_call':>14s} {'vs anchor':>10s}"]
    for name in sorted(payload.get("suites", {})):
        s = payload["suites"][name]
        speedup = s.get("speedup_vs_anchor")
        vs = f"{speedup:9.2f}x" if speedup is not None else "        --"
        flag = "  REGRESSION" if s.get("regression") else ""
        lines.append(f"{name:18s} {s['us_per_call']:14.1f} {vs}{flag}")
    return "\n".join(lines)


def merge_csv(existing_text: str | None,
              rows: Sequence) -> str:
    """Merge bench ``Row``s into existing CSV text by row name.

    Rows measured this run replace same-named rows in place; rows from
    suites not run this time are preserved; genuinely new rows append.
    This keeps ``--only`` runs from truncating the committed results.
    """

    header = "name,us_per_call,derived"
    order: list[str] = []
    lines: dict[str, str] = {}
    if existing_text:
        for line in existing_text.splitlines():
            line = line.strip()
            if not line or line == header:
                continue
            name = line.split(",", 1)[0]
            if name not in lines:
                order.append(name)
            lines[name] = line
    for r in rows:
        if r.name not in lines:
            order.append(r.name)
        lines[r.name] = r.csv()
    return "\n".join([header] + [lines[n] for n in order]) + "\n"
