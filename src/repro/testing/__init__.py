"""Differential-testing and regression-gating infrastructure.

* :mod:`repro.testing.traces` — the deterministic golden-trace protocol:
  named canonical workloads rebuilt from fixed seeds, plus a content
  fingerprint so trace drift (RNG/protocol changes) is distinguished
  from replay-engine drift.
* :mod:`repro.testing.golden` — the golden-fixture store: serialized
  ``SimResult``/``FleetResult`` snapshots committed under
  ``tests/golden/``, and a diff reporter that names the *first* diverging
  field in causal order (routing before byte accounting before clocks).
* :mod:`repro.testing.perf` — the BENCH perf-trajectory artifact
  (``experiments/BENCH_<n>.json``): per-suite timings, speedup vs the
  previous anchor, and a +/-15% regression gate used by
  ``python -m benchmarks.run --check``.
"""

from .golden import (
    CAUSAL_FIELD_ORDER,
    GOLDEN_DIR,
    GoldenTraceMismatch,
    diff_fleet,
    diff_sim,
    first_divergence,
    fixture_name,
    fixture_path,
    fleet_result_to_dict,
    generate_all,
    load_fixture,
    make_fixture,
    replay_fixture,
    sim_result_to_dict,
)
from .perf import (
    CURRENT_INDEX,
    REGRESSION_THRESHOLD,
    atomic_write_text,
    bench_filename,
    build_trajectory,
    check_trajectory,
    emit_trajectory,
    find_anchor,
)
from .traces import GOLDEN_WORKLOADS, golden_trace, trace_fingerprint

__all__ = [
    "CAUSAL_FIELD_ORDER",
    "CURRENT_INDEX",
    "GOLDEN_DIR",
    "GOLDEN_WORKLOADS",
    "GoldenTraceMismatch",
    "REGRESSION_THRESHOLD",
    "atomic_write_text",
    "bench_filename",
    "build_trajectory",
    "check_trajectory",
    "diff_fleet",
    "diff_sim",
    "emit_trajectory",
    "find_anchor",
    "first_divergence",
    "fixture_name",
    "fixture_path",
    "fleet_result_to_dict",
    "generate_all",
    "golden_trace",
    "load_fixture",
    "make_fixture",
    "replay_fixture",
    "sim_result_to_dict",
    "trace_fingerprint",
]
