"""Golden-fixture store + first-divergence diff reporter.

A fixture pins one ``(scheme x workload x shard-policy x engine)`` fleet
replay: the trace is rebuilt from :mod:`repro.testing.traces` (never
stored), the expected :class:`FleetResult` is stored field-by-field as
JSON.  Python floats round-trip exactly through JSON (``repr`` is
shortest-round-trip), so fixture comparison is bit-exact — any drift in
either numpy replay engine, either extent-index backend, the scoring
path, or the timing model trips a golden test.  The device engine is
stream-granular and compares through *tolerance tiers* instead: each
fixture embeds the ``device_tolerance`` table it was verified against
(``field -> [rtol, atol]``, ``[0, 0]`` = exact), and
``tests/test_engine_device.py`` replays the matrix under
``engine="device"`` with that embedded contract.

The diff reporter walks fields in **causal order** — routing inputs
before byte accounting before flush counters before clocks — across all
nodes, so the first reported divergence is the causally-earliest effect,
not whichever field happens to sort first::

    node[3].bytes_to_ssd: expected 148897792, got 148635648

Regenerate fixtures after an *intentional* behavior change with::

    PYTHONPATH=src python -m repro.testing.golden --write

and review the fixture diff like any other code diff.  ``--check``
replays every committed fixture and exits nonzero on the first
divergence (same check the golden tests run).
"""

from __future__ import annotations

import argparse
import contextlib
import json
import pathlib
from typing import Sequence

from repro.analysis import sanitize as _sanitize
from repro.core import FleetSimulator, FleetResult, SimResult

from .traces import golden_trace, trace_fingerprint

SCHEMA = "golden-fixture/v1"

# repo-root/tests/golden (this file lives at src/repro/testing/golden.py)
GOLDEN_DIR = pathlib.Path(__file__).resolve().parents[3] / "tests" / "golden"

# Causally ordered SimResult fields: a divergence in an earlier field
# explains divergences in later ones (routing decides bytes, bytes decide
# flush quanta, flush quanta decide clocks), so the reporter scans in
# this order and names the first mismatch.
CAUSAL_FIELD_ORDER = (
    "scheme",
    "total_bytes",
    "per_app_bytes",
    "bytes_to_ssd",
    "bytes_to_hdd_direct",
    "metadata_bytes",
    "flushes",
    "peak_ssd_occupancy",
    "blocked_seconds",
    "flush_paused_seconds",
    "io_seconds",
    "total_seconds",
)

# The committed fixture matrix (acceptance floor: >=3 schemes x 2
# workloads x 2 policies).  Engines: fixtures are generated with the
# default batched engine; tests replay them under the per-request oracle
# and the AVL index too, which pins all four engine/backend combinations
# to one snapshot instead of committing near-duplicate files.
FIXTURE_SCHEMES = ("orangefs", "orangefs-bb", "ssdup", "ssdup+")
FIXTURE_WORKLOADS = ("mixed-burst", "strided-gaps")
FIXTURE_POLICIES = ("range-offset", "round-robin-app")
FIXTURE_NODES = 4


class GoldenTraceMismatch(AssertionError):
    """The rebuilt trace does not match the fixture's fingerprint —
    the *trace protocol* drifted (RNG stream, workload generator), not
    the replay engine."""


class GoldenStorageMismatch(AssertionError):
    """The replay's storage-model configuration does not match the
    fixture's embedded fingerprint — the snapshot was recorded under a
    different SSD backend (or differently tuned FTL geometry), so a
    result divergence would be meaningless.  Regenerate the fixtures
    under the new backend, or replay with the recorded one."""


# -- serialization -----------------------------------------------------


def sim_result_to_dict(r: SimResult) -> dict:
    return {
        "scheme": r.scheme,
        "total_bytes": int(r.total_bytes),
        "per_app_bytes": {str(k): int(v)
                          for k, v in sorted(r.per_app_bytes.items())},
        "bytes_to_ssd": int(r.bytes_to_ssd),
        "bytes_to_hdd_direct": int(r.bytes_to_hdd_direct),
        "metadata_bytes": int(r.metadata_bytes),
        "flushes": int(r.flushes),
        "peak_ssd_occupancy": int(r.peak_ssd_occupancy),
        "blocked_seconds": float(r.blocked_seconds),
        "flush_paused_seconds": float(r.flush_paused_seconds),
        "io_seconds": float(r.io_seconds),
        "total_seconds": float(r.total_seconds),
    }


def fleet_result_to_dict(fr: FleetResult) -> dict:
    return {
        "scheme": fr.scheme,
        "policy": fr.policy,
        "num_nodes": int(fr.num_nodes),
        "nodes": [sim_result_to_dict(r) for r in fr.node_results],
    }


# -- diff reporter -----------------------------------------------------


def _normalize(field: str, value):
    if field == "per_app_bytes":
        return {str(k): int(v) for k, v in dict(value).items()}
    return value


def _within(e, a, rtol: float, atol: float) -> bool:
    """One value within ``max(rtol*|e|, atol)`` — dicts compare per key."""

    if isinstance(e, dict) or isinstance(a, dict):
        if not isinstance(e, dict) or not isinstance(a, dict):
            return False
        if e.keys() != a.keys():
            return False
        return all(_within(e[k], a[k], rtol, atol) for k in e)
    if isinstance(e, str) or isinstance(a, str):
        return e == a
    return abs(a - e) <= max(rtol * abs(e), atol)


def _field_matches(field: str, e, a, tolerances) -> bool:
    """Bit-exact unless ``tolerances`` carries a tier for this field.

    ``tolerances`` maps ``field -> (rtol, atol)`` — the tolerance-tiered
    comparison mode used for the device engine, whose documented
    approximations (:data:`repro.core.engine_device.DEVICE_TOLERANCES`)
    are bounded but not bit-exact.  A ``(0.0, 0.0)`` tier degenerates to
    exact equality, so the table is self-documenting about which fields
    the device engine reproduces exactly.
    """

    if not tolerances or field not in tolerances:
        return e == a
    rtol, atol = tolerances[field]
    return _within(e, a, float(rtol), float(atol))


def diff_sim(expected: dict, actual: dict, prefix: str = "",
             tolerances: dict | None = None) -> list[str]:
    """All diverging SimResult fields, causally ordered."""

    out = []
    for field in CAUSAL_FIELD_ORDER:
        e = _normalize(field, expected[field])
        a = _normalize(field, actual[field])
        if not _field_matches(field, e, a, tolerances):
            out.append(f"{prefix}{field}: expected {e!r}, got {a!r}")
    return out

def diff_fleet(expected: dict, actual: dict,
               tolerances: dict | None = None) -> list[str]:
    """Diverging fields across a fleet snapshot, causally ordered.

    Field-major scan: a routing divergence on *any* node is reported
    before a clock divergence on any other, because the former causes
    the latter.  ``tolerances`` (``field -> (rtol, atol)``) switches the
    named fields from bit-exact to within-tolerance comparison — the
    mode the device-engine parity tests run in.
    """

    out = []
    for field in ("scheme", "policy", "num_nodes"):
        if expected[field] != actual[field]:
            out.append(
                f"{field}: expected {expected[field]!r}, "
                f"got {actual[field]!r}"
            )
    exp_nodes, act_nodes = expected["nodes"], actual["nodes"]
    if len(exp_nodes) != len(act_nodes):
        out.append(
            f"nodes: expected {len(exp_nodes)} results, got {len(act_nodes)}"
        )
        return out
    for field in CAUSAL_FIELD_ORDER:
        for i, (e, a) in enumerate(zip(exp_nodes, act_nodes)):
            ef, af = _normalize(field, e[field]), _normalize(field, a[field])
            if not _field_matches(field, ef, af, tolerances):
                out.append(
                    f"node[{i}].{field}: expected {ef!r}, got {af!r}"
                )
    return out


def first_divergence(expected: dict, actual: dict) -> str | None:
    """The causally-first diverging field of a fleet snapshot, or None."""

    diffs = diff_fleet(expected, actual)
    return diffs[0] if diffs else None


# -- fixture store -----------------------------------------------------


def fixture_name(scheme: str, workload: str, policy: str,
                 engine: str = "batched") -> str:
    return f"{scheme}__{workload}__{policy}__{engine}.json"


def fixture_path(scheme: str, workload: str, policy: str,
                 engine: str = "batched",
                 directory: pathlib.Path | None = None) -> pathlib.Path:
    return (directory or GOLDEN_DIR) / fixture_name(
        scheme, workload, policy, engine)


def _node_capacity(total_bytes: int) -> int:
    # half the per-node share of the trace: forces region swaps, writer
    # blocking, and eager flushes on every buffered scheme
    return total_bytes // FIXTURE_NODES // 2


def device_tolerance_metadata() -> dict[str, list[float]]:
    """The device engine's documented tolerance table, JSON-shaped.

    Embedded into every fixture at ``--write`` time so the fixture file
    records the accuracy contract its device replay was verified against
    (``tests/test_engine_device.py`` asserts against the embedded copy,
    not the live table — a tolerance loosening therefore shows up as a
    fixture diff, reviewable like any behavior change).
    """

    from repro.core.engine_device import DEVICE_TOLERANCES

    return {f: [float(r), float(a)] for f, (r, a) in DEVICE_TOLERANCES.items()}


def storage_model_metadata(ssd=None, capacity: int = 0) -> dict:
    """Config fingerprint of the storage model a replay would use.

    Embedded into every fixture next to ``device_tolerance`` so the
    snapshot records *which* SSD backend (and geometry) produced it;
    :func:`replay_fixture` refuses to compare across backends.
    """

    from repro.core.device_model import make_storage_model

    return dict(
        make_storage_model(ssd, logical_bytes=capacity).config_fingerprint()
    )


def make_fixture(scheme: str, workload: str, policy: str,
                 engine: str = "batched", ssd=None) -> dict:
    """Run one fixture configuration and build its JSON payload."""

    batch = golden_trace(workload)
    capacity = _node_capacity(batch.total_bytes)
    fr = _run(batch, scheme, policy, engine, ssd=ssd)
    return {
        "schema": SCHEMA,
        "key": {
            "scheme": scheme,
            "workload": workload,
            "policy": policy,
            "engine": engine,
            "num_nodes": FIXTURE_NODES,
            "ssd_capacity": capacity,
        },
        "trace": trace_fingerprint(batch),
        "result": fleet_result_to_dict(fr),
        "device_tolerance": device_tolerance_metadata(),
        "storage_model": storage_model_metadata(ssd, capacity),
    }


def _run(batch, scheme: str, policy: str, engine: str,
         index_backend: str = "numpy", ssd=None) -> FleetResult:
    return FleetSimulator(
        num_nodes=FIXTURE_NODES,
        scheme=scheme,
        policy=policy,
        ssd_capacity=_node_capacity(batch.total_bytes),
        engine=engine,
        index_backend=index_backend,
        ssd=ssd,
    ).run(batch)


def load_fixture(path: pathlib.Path) -> dict:
    with open(path) as f:
        payload = json.load(f)
    if payload.get("schema") != SCHEMA:
        raise ValueError(
            f"{path}: schema {payload.get('schema')!r}, expected {SCHEMA!r}"
        )
    return payload


def replay_fixture(payload: dict, engine: str | None = None,
                   index_backend: str = "numpy", ssd=None) -> FleetResult:
    """Rebuild the fixture's trace and replay its configuration.

    ``engine``/``index_backend`` may override the fixture's own (that is
    how one snapshot pins the per-request oracle and the AVL index).
    Raises :class:`GoldenTraceMismatch` if the rebuilt trace does not
    match the stored fingerprint, and :class:`GoldenStorageMismatch` if
    ``ssd`` resolves to a storage backend other than the one the
    snapshot was recorded under.
    """

    key = payload["key"]
    batch = golden_trace(key["workload"])
    fp = trace_fingerprint(batch)
    if fp != payload["trace"]:
        raise GoldenTraceMismatch(
            f"golden trace {key['workload']!r} drifted: rebuilt "
            f"fingerprint {fp} != stored {payload['trace']} — the trace "
            "protocol changed (RNG stream or generator), not the engine"
        )
    stored = payload.get("storage_model")
    if stored is not None:
        actual = storage_model_metadata(ssd, key["ssd_capacity"])
        if actual != stored:
            raise GoldenStorageMismatch(
                f"storage backend mismatch: fixture recorded {stored}, "
                f"replay would use {actual} — comparing results across "
                "SSD models is meaningless; regenerate with --write or "
                "replay under the recorded backend"
            )
    return _run(batch, key["scheme"], key["policy"],
                engine or key["engine"], index_backend, ssd=ssd)


def check_fixture(payload: dict, result: FleetResult,
                  tolerances: dict | None = None) -> list[str]:
    """Causally ordered divergences of ``result`` vs the stored snapshot.

    Bit-exact by default (the numpy engines' contract); pass
    ``tolerances=payload["device_tolerance"]`` to compare a device-engine
    replay against its documented accuracy tiers instead.
    """

    return diff_fleet(payload["result"], fleet_result_to_dict(result),
                      tolerances=tolerances)


def generate_all(directory: pathlib.Path | None = None,
                 schemes: Sequence[str] = FIXTURE_SCHEMES,
                 workloads: Sequence[str] = FIXTURE_WORKLOADS,
                 policies: Sequence[str] = FIXTURE_POLICIES) -> list[pathlib.Path]:
    directory = directory or GOLDEN_DIR
    directory.mkdir(parents=True, exist_ok=True)
    written = []
    for workload in workloads:
        for scheme in schemes:
            for policy in policies:
                payload = make_fixture(scheme, workload, policy)
                path = directory / fixture_name(scheme, workload, policy)
                path.write_text(
                    json.dumps(payload, indent=1, sort_keys=True) + "\n")
                written.append(path)
    return written


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="golden-fixture store: regenerate or verify")
    ap.add_argument("--write", action="store_true",
                    help="(re)generate every fixture under tests/golden/")
    ap.add_argument("--check", action="store_true",
                    help="replay committed fixtures; nonzero on divergence")
    ap.add_argument("--sanitize", action="store_true",
                    help="replay with runtime invariant checks armed "
                         "(equivalent to REPRO_SANITIZE=1); results must "
                         "stay bit-identical")
    args = ap.parse_args(argv)
    with contextlib.ExitStack() as stack:
        if args.sanitize:
            stack.enter_context(_sanitize.sanitizing())
        if args.write:
            for path in generate_all():
                print(f"wrote {path}")
            return 0
        if args.check:
            bad = 0
            for path in sorted(GOLDEN_DIR.glob("*__*.json")):
                payload = load_fixture(path)
                diffs = check_fixture(payload, replay_fixture(payload))
                status = diffs[0] if diffs else "ok"
                print(f"{path.name}: {status}")
                bad += bool(diffs)
            return 1 if bad else 0
    ap.print_help()
    return 2


if __name__ == "__main__":
    raise SystemExit(main())
