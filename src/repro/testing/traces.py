"""Deterministic golden-trace protocol.

A golden fixture must be reproducible from nothing but this module: each
named workload is rebuilt from fixed seeds through the public workload
generators, so a fixture file only stores the *name* plus a content
fingerprint of the materialized trace.  At replay time the fingerprint is
checked first — if the trace itself drifted (a NumPy RNG stream change, a
workload-generator edit), the diff reporter says so instead of blaming
the replay engine.

Workloads are sized so a full fixture replay (4 schemes x 2 policies,
4-node fleet) stays well under a second: golden tests run in the fast
suite on every push.
"""

from __future__ import annotations

import hashlib

import numpy as np

from repro.core import Gap, TraceBatch, ior, mixed, relabel
from repro.core.workloads import MiB


def _mixed_burst() -> TraceBatch:
    """The fleet benchmark's 4-app recipe at 1/8 scale (256 MiB).

    Same composition as ``benchmarks.bench_fleet.bench_scaling`` — one
    sequential app, two segmented-random, one strided — bursty arrival
    interleave, so golden replays exercise the exact trace family where
    the 8-16 node anomaly lives.
    """

    per_app = 64 * MiB
    apps = [
        relabel(ior("segmented-contiguous", 8, total_bytes=per_app, seed=1),
                app_id=0, file_id=0),
        relabel(ior("segmented-random", 8, total_bytes=per_app, seed=2),
                app_id=1, file_id=1),
        relabel(ior("strided", 32, total_bytes=per_app, seed=3),
                app_id=2, file_id=2),
        relabel(ior("segmented-random", 16, total_bytes=per_app, seed=4),
                app_id=3, file_id=3),
    ]
    return TraceBatch.from_items(mixed(*apps, burst_requests=256).trace)


def _strided_gaps() -> TraceBatch:
    """Strided + random phases separated by compute gaps, ragged tail.

    Covers the paths the mixed burst does not: ``Gap`` replication across
    shards, the compute-gap flush drain, a partial final stream (37
    requests trimmed off the strided phase), and the end-of-trace drain
    after a trailing gap.
    """

    w1 = relabel(ior("strided", 32, total_bytes=96 * MiB, seed=5),
                 app_id=0, file_id=0)
    w2 = relabel(ior("segmented-random", 8, total_bytes=64 * MiB, seed=6),
                 app_id=1, file_id=1)
    items = list(w1.trace)[:-37]
    items.append(Gap(2.0))
    items.extend(w2.trace)
    items.append(Gap(5.0))
    return TraceBatch.from_items(items)


GOLDEN_WORKLOADS = {
    "mixed-burst": _mixed_burst,
    "strided-gaps": _strided_gaps,
}


def golden_trace(name: str) -> TraceBatch:
    """Materialize a named canonical trace (deterministic)."""

    try:
        build = GOLDEN_WORKLOADS[name]
    except KeyError:
        raise ValueError(
            f"unknown golden workload {name!r}; "
            f"choose from {sorted(GOLDEN_WORKLOADS)}"
        ) from None
    return build()


def trace_fingerprint(batch: TraceBatch) -> dict:
    """Content fingerprint of a materialized trace.

    The sha256 covers every request column plus the gap schedule, in
    fixed dtypes, so any byte of drift in the generated trace changes it.
    """

    h = hashlib.sha256()
    for arr, dtype in (
        (batch.offsets, np.int64),
        (batch.sizes, np.int64),
        (batch.file_ids, np.int64),
        (batch.app_ids, np.int64),
        (batch.gap_positions, np.int64),
        (batch.gap_seconds, np.float64),
    ):
        h.update(np.ascontiguousarray(arr, dtype=dtype).tobytes())
    return {
        "num_requests": int(batch.num_requests),
        "num_gaps": int(len(batch.gap_positions)),
        "total_bytes": int(batch.total_bytes),
        "sha256": h.hexdigest(),
    }
