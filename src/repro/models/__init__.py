"""Assigned-architecture model zoo (see DESIGN.md §4)."""

from repro.models.registry import ModelApi, get_model, input_axes, input_specs

__all__ = ["ModelApi", "get_model", "input_specs", "input_axes"]
