"""Attention-free SSM LM (falcon-mamba-7b family, Mamba-1 blocks).

Decode state is O(1) in context length — conv window (K-1 inputs) + SSM
hidden (d_inner x state) per layer — which is why this family runs the
``long_500k`` cell: serve_step cost is independent of the 524288-token
context.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models.layers import SSMState

Tree = dict


def param_specs(cfg: ModelConfig) -> Tree:
    V, D = cfg.padded_vocab, cfg.d_model
    di, n, dr, K = cfg.d_inner, cfg.ssm_state, cfg.dt_rank_, cfg.d_conv
    nl = cfg.n_layers
    layers = {
        "norm": ((nl, D), ("layers", None)),
        "in_proj": ((nl, D, 2 * di), ("layers", "embed", "inner")),
        "conv_w": ((nl, K, di), ("layers", None, "inner")),
        "conv_b": ((nl, di), ("layers", "inner")),
        "x_proj": ((nl, di, dr + 2 * n), ("layers", "inner", None)),
        "dt_proj": ((nl, dr, di), ("layers", None, "inner")),
        "dt_bias": ((nl, di), ("layers", "inner")),
        "A_log": ((nl, di, n), ("layers", "inner", None)),
        "D": ((nl, di), ("layers", "inner")),
        "out_proj": ((nl, di, D), ("layers", "inner", "embed")),
    }
    return {
        "tok_emb": ((V, D), ("vocab", "embed")),
        "final_norm": ((D,), (None,)),
        "lm_head": ((D, V), ("embed", "vocab")),
        "layers": layers,
    }


def _map_specs(specs: Tree, fn) -> Tree:
    return {
        k: (_map_specs(v, fn) if isinstance(v, dict) else fn(*v))
        for k, v in specs.items()
    }


def abstract_params(cfg: ModelConfig) -> Tree:
    dt = L.dtype_of(cfg)

    def mk(sh, ax):
        # scan-dynamics params stay f32 for numerical stability
        if ax and "inner" in ax and len(sh) >= 2 and sh[-1] == cfg.ssm_state:
            return jax.ShapeDtypeStruct(sh, jnp.float32)
        return jax.ShapeDtypeStruct(sh, dt)

    return _map_specs(param_specs(cfg), mk)


def param_axes(cfg: ModelConfig) -> Tree:
    return _map_specs(param_specs(cfg), lambda sh, ax: ax)


def init_params(cfg: ModelConfig, key: jax.Array) -> Tree:
    dt = L.dtype_of(cfg)
    counter = [0]

    def walk(t):
        out = {}
        for k, v in t.items():
            if isinstance(v, dict):
                out[k] = walk(v)
                continue
            sh, _ax = v
            counter[0] += 1
            kk = jax.random.fold_in(key, counter[0])
            if "norm" in k or k == "D":
                out[k] = jnp.ones(sh, dt)
            elif k == "A_log":
                # S4D-real init: A = -(1..n) per channel
                a = jnp.broadcast_to(jnp.arange(1, sh[-1] + 1, dtype=jnp.float32), sh)
                out[k] = jnp.log(a)
            elif k == "dt_bias":
                out[k] = jnp.full(sh, -4.6, dt)  # softplus^-1(0.01)
            elif k.endswith("_b"):
                out[k] = jnp.zeros(sh, dt)
            else:
                out[k] = (jax.random.normal(kk, sh, jnp.float32) * 0.02).astype(dt)
        return out

    return walk(param_specs(cfg))


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, params: Tree, tokens: jax.Array,
            states: Tree | None = None, collect_state: bool = False):
    """states: stacked decode state {"conv": (L,B,K-1,DI), "h": (L,B,DI,N)}."""

    x = L.embed_tokens(cfg, params["tok_emb"], tokens)

    def body(carry, inp):
        if states is None:
            w = inp
            st = None
        else:
            w, conv, h = inp
            st = SSMState(conv=conv, h=h)
        y, new_state = L.mamba1_block(cfg, w, L.rms_norm(carry, w["norm"], cfg.norm_eps), st)
        out = carry + y
        ys = (new_state.conv, new_state.h) if (collect_state or states is not None) else None
        return out, ys

    if cfg.remat == "block":
        body = jax.checkpoint(body)
    xs = params["layers"] if states is None else (
        params["layers"], states["conv"], states["h"]
    )
    x, ys = L.scan(body, x, xs)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if ys is not None:
        conv, h = ys
        return x, {"conv": conv, "h": h}
    return x, None


def loss_fn(cfg: ModelConfig, params: Tree, batch: dict) -> jax.Array:
    hidden, _ = forward(cfg, params, batch["tokens"])
    logits = L.lm_logits(cfg, params, hidden)
    return L.cross_entropy(cfg, logits, batch["labels"])


def prefill(cfg: ModelConfig, params: Tree, batch: dict):
    hidden, state = forward(cfg, params, batch["tokens"], collect_state=True)
    logits = L.lm_logits(cfg, params, hidden[:, -1:, :])
    return logits, state


def decode_step(cfg: ModelConfig, params: Tree, state: Tree,
                tokens: jax.Array, pos: jax.Array):
    """SSM serve step ignores ``pos`` (state is position-free)."""

    del pos
    hidden, new_state = forward(cfg, params, tokens, states=state)
    logits = L.lm_logits(cfg, params, hidden)
    return logits, new_state


def abstract_cache(cfg: ModelConfig, batch: int, seq: int) -> Tree:
    """Decode state; ``seq`` is irrelevant (O(1) state) but kept for API."""

    del seq
    dt = L.dtype_of(cfg)
    nl, di, n, K = cfg.n_layers, cfg.d_inner, cfg.ssm_state, cfg.d_conv
    return {
        "conv": jax.ShapeDtypeStruct((nl, batch, K - 1, di), dt),
        "h": jax.ShapeDtypeStruct((nl, batch, di, n), jnp.float32),
    }


def cache_axes(cfg: ModelConfig) -> Tree:
    return {
        "conv": ("layers", "cache_batch", None, "inner"),
        "h": ("layers", "cache_batch", "inner", None),
    }
