"""Model building blocks shared by all assigned architectures.

Pure-function style: every block is ``f(cfg, params, x, ...)`` over nested
dict params, so the same code paths serve real arrays (smoke tests) and
ShapeDtypeStructs (dry-run lowering).  Activation sharding is annotated with
logical axis names via :func:`repro.distributed.sharding.constrain`; note
that activation *feature* dims stay replicated (the "data" mesh axis is
already spent on batch), while weights carry FSDP("data") x TP("model").

Memory discipline (these bounds are what make the 32k/500k cells lowerable):

* attention over long sequences is query-chunked (exact, per-chunk softmax)
  so the scores tensor is (B, H, q_chunk, S) instead of (B, H, S, S);
* Mamba's (B, S, d_inner, state) expansion never materializes: the chunked
  scan builds deltaA/deltaBx per chunk inside a rematerialized body;
* MoE dispatch is grouped: (B, G, g, E, C) with g = moe_group_size.

Numerics: bf16 matmuls, f32 softmax/norm/scan statistics.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain

Params = dict

ATTN_DIRECT_MAX_SEQ = 1024  # direct path below this, q-chunked above
ATTN_Q_CHUNK = 512
NEG_INF = float(np.finfo(np.float32).min)

# XLA's HLO cost analysis counts a while-loop body ONCE (not x trip count),
# so the dry-run's FLOP/byte/collective calibration lowers small UNROLLED
# depths and extrapolates (launch/dryrun.py).  This flag flips every scan in
# the model code to full unroll.
_SCAN_UNROLL: contextvars.ContextVar[bool] = contextvars.ContextVar(
    "repro_scan_unroll", default=False)


@contextlib.contextmanager
def unroll_scans():
    tok = _SCAN_UNROLL.set(True)
    try:
        yield
    finally:
        _SCAN_UNROLL.reset(tok)


def scan(body, init, xs, **kw):
    """lax.scan that honours the dry-run unroll context."""

    if _SCAN_UNROLL.get():
        kw = dict(kw, unroll=True)
    return jax.lax.scan(body, init, xs, **kw)


# Query-chunk size for long-sequence attention.  The calibration pass widens
# it (fewer unrolled bodies, same total FLOPs/bytes) to keep small-depth
# unrolled compiles tractable.
_Q_CHUNK: contextvars.ContextVar[int] = contextvars.ContextVar(
    "repro_attn_q_chunk", default=ATTN_Q_CHUNK)


@contextlib.contextmanager
def attn_q_chunk(n: int):
    tok = _Q_CHUNK.set(n)
    try:
        yield
    finally:
        _Q_CHUNK.reset(tok)


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def param_dtype_of(cfg: ModelConfig):
    """Storage dtype for parameters (weight-only quantization lever)."""

    return jnp.dtype(cfg.param_dtype) if cfg.param_dtype else jnp.dtype(cfg.dtype)


def wcast(cfg: ModelConfig, w: jax.Array) -> jax.Array:
    """Weight cast applied right before a matmul (perf lever).

    With ``matmul_weight_dtype="float8_e4m3fn"`` the cast is a
    sharding-preserving elementwise op, so GSPMD's FSDP all-gather moves the
    fp8 tensor — halving weight-gather collective bytes vs bf16.  The cast
    result feeds the MXU with f32 accumulation (preferred_element_type on
    einsum defaults); baseline (None) is a no-op.
    """

    if cfg.matmul_weight_dtype is None:
        return w
    return w.astype(jnp.dtype(cfg.matmul_weight_dtype))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions broadcastable to (..., seq)."""

    hd = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(hd, theta), dtype=jnp.float32)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention cores
# ---------------------------------------------------------------------------

def _gqa_scores(q: jax.Array, k: jax.Array, n_rep: int) -> jax.Array:
    """(B,Sq,H,hd) x (B,Sk,KV,hd) -> (B,H,Sq,Sk) with KV-head grouping."""

    b, sq, h, hd = q.shape
    kv = k.shape[2]
    qg = q.reshape(b, sq, kv, n_rep, hd)
    s = jnp.einsum("bqgrk,bsgk->bgrqs", qg, k)
    return s.reshape(b, h, sq, k.shape[1])


def _gqa_out(probs: jax.Array, v: jax.Array, n_rep: int) -> jax.Array:
    """(B,H,Sq,Sk) x (B,Sk,KV,hd) -> (B,Sq,H,hd)."""

    b, h, sq, sk = probs.shape
    kv = v.shape[2]
    pg = probs.reshape(b, kv, n_rep, sq, sk)
    o = jnp.einsum("bgrqs,bsgk->bqgrk", pg, v)
    return o.reshape(b, sq, h, v.shape[3])


def _softmax_lastdim(s, stats_dtype):
    """Softmax with selectable statistics dtype (perf lever softmax_dtype).

    bf16 mode keeps the max-subtraction in f32 (stability) but stores the
    exponentials in bf16 with f32-accumulated sums — roughly halving the
    attention-score HBM traffic in the XLA path."""

    if stats_dtype == jnp.float32:
        return jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    m = jnp.max(s.astype(jnp.float32), axis=-1, keepdims=True)
    e = (s.astype(jnp.float32) - m).astype(stats_dtype)
    e = jnp.exp(e)
    denom = jnp.sum(e.astype(jnp.float32), axis=-1, keepdims=True)
    return (e / denom.astype(stats_dtype))


def _attend_direct(q, k, v, n_rep, scale, causal, q_offset=0,
                   smax=jnp.float32):
    dt = q.dtype
    s = _gqa_scores(q * scale, k, n_rep)
    if causal:
        sq, sk = s.shape[-2], s.shape[-1]
        qpos = q_offset + jnp.arange(sq)[:, None]
        kpos = jnp.arange(sk)[None, :]
        s = jnp.where(qpos >= kpos, s, NEG_INF)
    p = _softmax_lastdim(s, smax).astype(dt)
    return _gqa_out(p, v, n_rep)


def _attend_chunked(q, k, v, n_rep, scale, causal, smax=jnp.float32):
    """Exact attention with query chunking: scores stay (B,H,qc,S)."""

    b, sq, h, hd = q.shape
    qc = min(_Q_CHUNK.get(), sq)
    if sq % qc != 0:
        raise ValueError(f"seq {sq} not divisible by query chunk {qc}")
    nq = sq // qc
    qs = q.reshape(b, nq, qc, h, hd).swapaxes(0, 1)  # (nq,B,qc,H,hd)
    offsets = jnp.arange(nq) * qc

    def body(_, inp):
        qi, off = inp
        o = _attend_direct(qi, k, v, n_rep, scale, causal, q_offset=off,
                           smax=smax)
        return None, o

    body = jax.checkpoint(body)
    _, outs = scan(body, None, (qs, offsets))
    return outs.swapaxes(0, 1).reshape(b, sq, h, hd)


# ---------------------------------------------------------------------------
# GQA attention layer (train / prefill / decode / cross)
# ---------------------------------------------------------------------------

def _project_qkv(cfg: ModelConfig, w: Params, x: jax.Array):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, wcast(cfg, w["wq"]),
                   preferred_element_type=dt)
    k = jnp.einsum("bsd,dhk->bshk", x, wcast(cfg, w["wk"]),
                   preferred_element_type=dt)
    v = jnp.einsum("bsd,dhk->bshk", x, wcast(cfg, w["wv"]),
                   preferred_element_type=dt)
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "kv_heads", None)
    v = constrain(v, "batch", None, "kv_heads", None)
    if cfg.qk_norm:
        q = rms_norm(q, w["q_norm"], cfg.norm_eps)
        k = rms_norm(k, w["k_norm"], cfg.norm_eps)
    return q, k, v


def attention(
    cfg: ModelConfig,
    w: Params,
    x: jax.Array,
    *,
    positions: jax.Array,
    causal: bool = True,
    kv_cache: tuple[jax.Array, jax.Array] | None = None,
    cache_position: jax.Array | int | None = None,
    cross_kv: tuple[jax.Array, jax.Array] | None = None,
) -> tuple[jax.Array, tuple[jax.Array, jax.Array] | None]:
    """GQA attention.

    Train/prefill (``kv_cache=None``): full self attention over ``x``
    (query-chunked beyond ATTN_DIRECT_MAX_SEQ); returns (k, v) so prefill
    can emit a cache.

    Decode (``kv_cache=(k, v)``): single new token against an S_ctx cache
    whose sequence dim is sharded over "model" (SP; the f32 softmax over the
    sharded axis lowers to partial reductions + all-reduce under GSPMD —
    flash-decoding's split-KV scheme).  ``cache_position`` is the scalar
    write index.

    Cross attention (``cross_kv``): precomputed encoder (k, v); no mask.
    """

    hd = cfg.head_dim_
    n_rep = cfg.n_heads // cfg.n_kv_heads
    scale = 1.0 / np.sqrt(hd)

    if cross_kv is not None:
        q = jnp.einsum("bsd,dhk->bshk", x, wcast(cfg, w["wq"]),
                       preferred_element_type=x.dtype)
        q = constrain(q, "batch", None, "heads", None)
        k, v = cross_kv
        o = _attend_direct(q, k, v, n_rep, scale, causal=False)
        out = jnp.einsum("bshk,hkd->bsd", o, wcast(cfg, w["wo"]),
                         preferred_element_type=x.dtype)
        return constrain(out, "batch", None, None), None

    q, k, v = _project_qkv(cfg, w, x)
    if cfg.rope_theta > 0:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    if kv_cache is None:
        sq = x.shape[1]
        if cfg.attention_impl == "pallas":
            # TPU kernel path (interpret mode off-TPU); see kernels/
            from repro.kernels.flash_attention.ops import flash_attention_bshd

            o = flash_attention_bshd(q, k, v, causal=causal, scale=scale)
        elif sq <= ATTN_DIRECT_MAX_SEQ or sq % min(_Q_CHUNK.get(), sq):
            o = _attend_direct(q, k, v, n_rep, scale, causal,
                               smax=jnp.dtype(cfg.softmax_dtype))
        else:
            o = _attend_chunked(q, k, v, n_rep, scale, causal,
                                smax=jnp.dtype(cfg.softmax_dtype))
        new_cache = (k, v)
    else:
        if x.shape[1] != 1:
            raise ValueError("decode path expects one new token")
        ck, cv = kv_cache  # (B, S_ctx, KV, hd); seq dim sharded "cache_seq"
        pos = cache_position
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), pos, axis=1)
        ck = constrain(ck, "batch", "cache_seq", None, None)
        cv = constrain(cv, "batch", "cache_seq", None, None)
        s = _gqa_scores(q * scale, ck, n_rep)  # (B,H,1,S_ctx)
        valid = jnp.arange(ck.shape[1])[None, None, None, :] <= pos
        s = jnp.where(valid, s, NEG_INF)
        p = _softmax_lastdim(s, jnp.dtype(cfg.softmax_dtype)).astype(q.dtype)
        o = _gqa_out(p, cv, n_rep)
        new_cache = (ck, cv)

    out = jnp.einsum("bshk,hkd->bsd", o, wcast(cfg, w["wo"]),
                     preferred_element_type=x.dtype)
    return constrain(out, "batch", None, None), new_cache


# ---------------------------------------------------------------------------
# MLP (SwiGLU or GELU)
# ---------------------------------------------------------------------------

def mlp(cfg: ModelConfig, w: Params, x: jax.Array) -> jax.Array:
    dt = x.dtype
    h = jnp.einsum("bsd,df->bsf", x, wcast(cfg, w["w1"]),
                   preferred_element_type=dt)
    h = constrain(h, "batch", None, "mlp")
    if cfg.swiglu:
        g = jnp.einsum("bsd,df->bsf", x, wcast(cfg, w["w3"]),
                       preferred_element_type=dt)
        g = constrain(g, "batch", None, "mlp")
        h = jax.nn.silu(h) * g
    else:
        h = jax.nn.gelu(h)
    out = jnp.einsum("bsf,fd->bsd", h, wcast(cfg, w["w2"]),
                     preferred_element_type=dt)
    return constrain(out, "batch", None, None)


# ---------------------------------------------------------------------------
# MoE (top-k, capacity-based grouped dispatch; EP over the "experts" axis)
# ---------------------------------------------------------------------------

def moe_mlp(cfg: ModelConfig, w: Params, x: jax.Array) -> jax.Array:
    """Top-k capacity-dropped MoE with routing groups.

    Dispatch memory is bounded to (B, G, g, E, C) with group size
    ``g = cfg.moe_group_size`` and per-group capacity
    ``C = ceil(g * k / E * capacity_factor)`` — i.e. ~T * g * k * cf floats
    regardless of E.  Experts shard over "model" when E divides it
    (moonshot: 64/16 — true EP); otherwise experts replicate and the expert
    FFN dim carries the model axis (grok: 8 experts, d_ff/16), automatically
    via the divisibility rule in ``spec_for``.
    """

    b, s, d = x.shape
    e = cfg.n_experts
    k = cfg.experts_per_token
    g = min(cfg.moe_group_size, s)
    if s % g != 0:
        raise ValueError(f"seq {s} not divisible by moe group {g}")
    ng = s // g
    cap = max(int(np.ceil(g * k / e * cfg.capacity_factor)), 1)

    xg = x.reshape(b, ng, g, d)
    logits = jnp.einsum("bngd,de->bnge", xg, wcast(cfg, w["router"]),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # (B,NG,g,k)
    gate_vals = gate_vals / (jnp.sum(gate_vals, axis=-1, keepdims=True) + 1e-9)

    # position of each (token, choice) in its expert's capacity buffer
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)  # (B,NG,g,k,E)
    flat = onehot.reshape(b, ng, g * k, e)
    pos_in_expert = (jnp.cumsum(flat, axis=2) - flat).reshape(b, ng, g, k, e)
    kept = (pos_in_expert < cap) * onehot  # drop beyond capacity
    cap_slot = jax.nn.one_hot(
        jnp.sum(pos_in_expert * onehot, axis=-1).astype(jnp.int32), cap,
        dtype=jnp.float32,
    )  # (B,NG,g,k,C)

    ddt = jnp.dtype(cfg.moe_dispatch_dtype)
    dispatch = jnp.einsum("bngke,bngkc->bngec", kept, cap_slot,
                          preferred_element_type=ddt).astype(ddt)
    combine = jnp.einsum("bngke,bngkc,bngk->bngec", kept, cap_slot,
                         gate_vals, preferred_element_type=ddt).astype(ddt)
    dispatch = constrain(dispatch, "batch", None, None, "experts", None)

    dt = x.dtype
    ein = jnp.einsum("bngd,bngec->bnecd", xg, dispatch.astype(dt))
    ein = constrain(ein, "batch", None, "experts", None, None)
    h = jnp.einsum("bnecd,edf->bnecf", ein, wcast(cfg, w["w1"]),
                   preferred_element_type=dt)
    h = constrain(h, "batch", None, "experts", None, "mlp")
    if cfg.swiglu:
        gp = jnp.einsum("bnecd,edf->bnecf", ein, wcast(cfg, w["w3"]),
                        preferred_element_type=dt)
        gp = constrain(gp, "batch", None, "experts", None, "mlp")
        h = jax.nn.silu(h) * gp
    else:
        h = jax.nn.gelu(h)
    eout = jnp.einsum("bnecf,efd->bnecd", h, wcast(cfg, w["w2"]),
                      preferred_element_type=dt)
    eout = constrain(eout, "batch", None, "experts", None, None)
    out = jnp.einsum("bnecd,bngec->bngd", eout, combine.astype(dt))
    return constrain(out.reshape(b, s, d), "batch", None, None)


# ---------------------------------------------------------------------------
# selective-scan machinery (shared by Mamba-1/2)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SSMState:
    """Recurrent state for decode: conv window + SSM hidden state."""

    conv: jax.Array  # (B, d_conv-1, conv_channels)
    h: jax.Array  # (B, d_inner, state) f32


def _causal_conv1d(x: jax.Array, kernel: jax.Array, bias: jax.Array,
                   prepend: jax.Array | None):
    """Depthwise causal conv over seq.  x: (B,S,C); kernel: (K,C)."""

    k = kernel.shape[0]
    if prepend is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = prepend.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+K-1, C)
    out = sum(
        xp[:, i: i + x.shape[1], :] * kernel[i][None, None, :] for i in range(k)
    )
    out = jax.nn.silu(out + bias[None, None, :])
    tail = xp[:, -(k - 1):, :] if k > 1 else jnp.zeros(
        (x.shape[0], 0, x.shape[2]), x.dtype
    )
    return out, tail


def _ssm_scan(delta, B_ssm, C_ssm, xi, h0, chunk, *, A_full=None, A_head=None,
              headdim=1):
    """Chunked selective scan; the (B, chunk, DI, N) expansion happens inside
    the rematerialized chunk body, never for the whole sequence.

    delta: (B,S,DI) f32  (mamba-1)  or (B,S,H) f32 (mamba-2, per-head)
    B_ssm/C_ssm: (B,S,N) f32;  xi: (B,S,DI);  h0: (B,DI,N) f32.
    A_full: (DI,N) f32 (mamba-1) or A_head: (H,) f32 (mamba-2).
    Returns y: (B,S,DI) (xi dtype), h_last: (B,DI,N) f32.
    """

    b, s, di = xi.shape
    n = B_ssm.shape[-1]
    pad = (-s) % chunk
    if pad:
        # zero padding is exact: delta=0 -> a=exp(0)=1, bx=0 (identity
        # updates that leave h_last untouched); padded y rows are sliced off
        padfn = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        delta, B_ssm, C_ssm, xi = map(padfn, (delta, B_ssm, C_ssm, xi))
        s += pad
    nc = s // chunk

    def split(t):
        return t.reshape(b, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    xs = (split(delta), split(B_ssm), split(C_ssm), split(xi))

    def chunk_step(h, inp):
        d, bm, cm, xc = inp  # (B,chunk,DI|H), (B,chunk,N) x2, (B,chunk,DI)
        if A_full is not None:
            a = jnp.exp(d[..., None] * A_full[None, None])  # (B,chunk,DI,N)
            d_di = d
        else:
            dah = jnp.exp(d * A_head[None, None, :])  # (B,chunk,H)
            a = jnp.broadcast_to(
                jnp.repeat(dah, headdim, axis=-1)[..., None], (b, chunk, di, n)
            )
            d_di = jnp.repeat(d, headdim, axis=-1)
        bx = d_di[..., None] * bm[:, :, None, :] * xc.astype(jnp.float32)[..., None]

        def combine(left, right):
            al, bl = left
            ar, br = right
            return al * ar, bl * ar + br

        a_acc, bx_acc = jax.lax.associative_scan(combine, (a, bx), axis=1)
        hs = a_acc * h[:, None] + bx_acc  # (B,chunk,DI,N)
        y = jnp.einsum("bldn,bln->bld", hs, cm)
        return hs[:, -1], y.astype(xi.dtype)

    chunk_step = jax.checkpoint(chunk_step)
    h_last, ys = scan(chunk_step, h0, xs)
    y = ys.swapaxes(0, 1).reshape(b, s, di)
    if pad:
        y = y[:, : s - pad]
    return y, h_last


def _ssm_scan_fused_m1(cfg: ModelConfig, w: Params, xi: jax.Array,
                       h0: jax.Array, chunk: int):
    """Mamba-1 scan with x_proj/dt_proj fused INTO the chunk body (perf
    lever ``mamba_fused_proj``): the full-sequence f32 ``delta`` (B,S,DI)
    and the (B,S,dr+2n) projection never materialize — only per-chunk
    transients inside the rematerialized body.  Exactness under padding is
    kept by masking delta beyond the true length (a=exp(0)=1, bx=0)."""

    b, s, di = xi.shape
    n, dr = cfg.ssm_state, cfg.dt_rank_
    s_orig = s
    pad = (-s) % chunk
    if pad:
        xi = jnp.pad(xi, ((0, 0), (0, pad), (0, 0)))
        s += pad
    nc = s // chunk
    xs_x = xi.reshape(b, nc, chunk, di).swapaxes(0, 1)
    offs = jnp.arange(nc) * chunk
    A = -jnp.exp(w["A_log"].astype(jnp.float32))  # (DI, N)

    def chunk_step(h, inp):
        xc, off = inp  # (B, chunk, DI), scalar
        proj = jnp.einsum("bli,ie->ble", xc, w["x_proj"])
        delta_r, B_c, C_c = jnp.split(proj, [dr, dr + n], axis=-1)
        delta = jax.nn.softplus(
            jnp.einsum("blr,ri->bli", delta_r, w["dt_proj"]).astype(jnp.float32)
            + w["dt_bias"].astype(jnp.float32))
        valid = (off + jnp.arange(chunk) < s_orig)[None, :, None]
        delta = jnp.where(valid, delta, 0.0)
        a = jnp.exp(delta[..., None] * A[None, None])
        bx = (delta[..., None] * B_c.astype(jnp.float32)[:, :, None, :]
              * xc.astype(jnp.float32)[..., None])

        def combine(left, right):
            al, bl = left
            ar, br = right
            return al * ar, bl * ar + br

        a_acc, bx_acc = jax.lax.associative_scan(combine, (a, bx), axis=1)
        hs = a_acc * h[:, None] + bx_acc
        y = jnp.einsum("bldn,bln->bld", hs, C_c.astype(jnp.float32))
        return hs[:, -1], y.astype(xc.dtype)

    chunk_step = jax.checkpoint(chunk_step)
    h_last, ys = scan(chunk_step, h0, (xs_x, offs))
    y = ys.swapaxes(0, 1).reshape(b, s, di)
    if pad:
        y = y[:, :s_orig]
    return y, h_last


def _ssm_step(delta, B_ssm, C_ssm, xi, h0, *, A_full=None, A_head=None,
              headdim=1):
    """Single decode step of the scan (S == 1 specialization)."""

    if A_full is not None:
        a = jnp.exp(delta[:, 0, :, None] * A_full[None])  # (B,DI,N)
        d_di = delta[:, 0]
    else:
        dah = jnp.exp(delta[:, 0] * A_head[None, :])  # (B,H)
        a = jnp.repeat(dah, headdim, axis=-1)[..., None]
        d_di = jnp.repeat(delta[:, 0], headdim, axis=-1)
    bx = d_di[..., None] * B_ssm[:, 0, None, :] * xi.astype(jnp.float32)[:, 0, :, None]
    h1 = a * h0 + bx
    y = jnp.einsum("bdn,bn->bd", h1, C_ssm[:, 0])[:, None].astype(xi.dtype)
    return y, h1


# ---------------------------------------------------------------------------
# Mamba-1 block (falcon-mamba family)
# ---------------------------------------------------------------------------

def mamba1_block(
    cfg: ModelConfig,
    w: Params,
    x: jax.Array,
    state: SSMState | None = None,
) -> tuple[jax.Array, SSMState]:
    """Mamba-1 (S6) block.  x: (B,S,D).  With ``state`` and S==1 it runs one
    decode step, updating the conv window + hidden state."""

    b, s, d = x.shape
    di, n, dr = cfg.d_inner, cfg.ssm_state, cfg.dt_rank_
    dt = x.dtype

    xz = jnp.einsum("bsd,de->bse", x, w["in_proj"])  # (B,S,2*DI)
    xz = constrain(xz, "batch", None, "inner")
    xi, z = jnp.split(xz, 2, axis=-1)

    prepend = state.conv if state is not None else None
    xi, conv_tail = _causal_conv1d(xi, w["conv_w"], w["conv_b"], prepend)
    xi = constrain(xi, "batch", None, "inner")

    h0 = (
        state.h.astype(jnp.float32)
        if state is not None
        else jnp.zeros((b, di, n), jnp.float32)
    )
    if cfg.mamba_fused_proj and s > 1:
        y, h_last = _ssm_scan_fused_m1(cfg, w, xi, h0, min(cfg.scan_chunk, s))
    else:
        proj = jnp.einsum("bsi,ie->bse", xi, w["x_proj"])  # (B,S,dr+2n)
        delta_r, B_ssm, C_ssm = jnp.split(proj, [dr, dr + n], axis=-1)
        delta = jax.nn.softplus(
            jnp.einsum("bsr,ri->bsi", delta_r, w["dt_proj"]).astype(jnp.float32)
            + w["dt_bias"].astype(jnp.float32)
        )  # (B,S,DI) f32
        delta = constrain(delta, "batch", None, "inner")

        A = -jnp.exp(w["A_log"].astype(jnp.float32))  # (DI,N)
        B32, C32 = B_ssm.astype(jnp.float32), C_ssm.astype(jnp.float32)
        if s == 1:
            y, h_last = _ssm_step(delta, B32, C32, xi, h0, A_full=A)
        elif cfg.ssm_impl == "pallas":
            # VMEM-resident scan kernel (kernels/ssm_scan); h0 must be zero
            # here (prefill/train start) — decode goes through _ssm_step
            from repro.kernels.ssm_scan.ops import ssm_scan_op

            y, h_last = ssm_scan_op(
                delta, B32, C32, xi, A,
                block_d=min(512, di), chunk=min(cfg.scan_chunk, s))
        else:
            y, h_last = _ssm_scan(delta, B32, C32, xi, h0,
                                  min(cfg.scan_chunk, s), A_full=A)
    y = y + xi * w["D"][None, None, :].astype(dt)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, w["out_proj"])
    out = constrain(out, "batch", None, None)
    return out, SSMState(conv=conv_tail, h=h_last)


# ---------------------------------------------------------------------------
# Mamba-2 block (zamba2 family) — SSD with per-head scalar decay
# ---------------------------------------------------------------------------

def mamba2_block(
    cfg: ModelConfig,
    w: Params,
    x: jax.Array,
    state: SSMState | None = None,
) -> tuple[jax.Array, SSMState]:
    """Mamba-2 (SSD) block: heads of ``mamba_headdim`` channels share B/C;
    A is a scalar per head.  Heads are contiguous channel blocks of the
    (B, S, d_inner) activation."""

    b, s, d = x.shape
    di, n = cfg.d_inner, cfg.ssm_state
    nh, p = cfg.mamba_heads, cfg.mamba_headdim
    dt = x.dtype

    zxbcdt = jnp.einsum("bsd,de->bse", x, w["in_proj"])
    z, xBC, delta_in = jnp.split(zxbcdt, [di, di + di + 2 * n], axis=-1)
    z = constrain(z, "batch", None, "inner")

    prepend = state.conv if state is not None else None
    xBC, conv_tail = _causal_conv1d(xBC, w["conv_w"], w["conv_b"], prepend)
    xi, B_ssm, C_ssm = jnp.split(xBC, [di, di + n], axis=-1)
    xi = constrain(xi, "batch", None, "inner")

    delta = jax.nn.softplus(
        delta_in.astype(jnp.float32) + w["dt_bias"][None, None, :]
    )  # (B,S,H) f32
    A = -jnp.exp(w["A_log"].astype(jnp.float32))  # (H,)
    h0 = (
        state.h.astype(jnp.float32)
        if state is not None
        else jnp.zeros((b, di, n), jnp.float32)
    )
    B32, C32 = B_ssm.astype(jnp.float32), C_ssm.astype(jnp.float32)
    if s == 1:
        y, h_last = _ssm_step(delta, B32, C32, xi, h0, A_head=A, headdim=p)
    else:
        y, h_last = _ssm_scan(delta, B32, C32, xi, h0,
                              min(cfg.scan_chunk, s), A_head=A, headdim=p)
    y = y + xi * jnp.repeat(w["D"], p)[None, None, :].astype(dt)
    y = rms_norm(y * jax.nn.silu(z), w["out_norm"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, w["out_proj"])
    out = constrain(out, "batch", None, None)
    return out, SSMState(conv=conv_tail, h=h_last)


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def embed_tokens(cfg: ModelConfig, emb: jax.Array, tokens: jax.Array) -> jax.Array:
    if cfg.embed_onehot:
        # one-hot matmul keeps the vocab-sharded table in place (each shard
        # contracts its vocab slice + all-reduce) instead of GSPMD's
        # replicate-then-gather fallback
        oh = jax.nn.one_hot(tokens, emb.shape[0], dtype=emb.dtype)
        x = jnp.einsum("bsv,vd->bsd", oh, emb).astype(dtype_of(cfg))
    else:
        x = jnp.take(emb, tokens, axis=0).astype(dtype_of(cfg))
    return constrain(x, "batch", None, None)


def lm_logits(cfg: ModelConfig, params: Params, x: jax.Array) -> jax.Array:
    head = params["lm_head"] if not cfg.tie_embeddings else params["tok_emb"].T
    logits = jnp.einsum("bsd,dv->bsv", x, wcast(cfg, head),
                        preferred_element_type=x.dtype)
    return constrain(logits, "batch", None, "vocab")


def cross_entropy(cfg: ModelConfig, logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean NLL over all positions; labels < 0 are masked (padding)."""

    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    mask = labels >= 0
    safe = jnp.where(mask, labels, 0)
    nll = -jnp.take_along_axis(lp, safe[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
