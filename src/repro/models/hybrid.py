"""Hybrid SSM + shared-attention LM (zamba2-2.7b family).

Mamba-2 backbone with ONE shared attention+MLP block (a single weight set)
applied after every ``cfg.shared_attn_every``-th Mamba layer — Zamba2's
weight-shared global block.  (Zamba2's embedding-concat input to the shared
block and its per-application LoRA deltas are omitted; DESIGN.md §8.)

Structure: layers are grouped as ``n_groups = n_layers // every`` groups of
``every`` Mamba layers followed by one shared-attention application.  Decode
carries ``n_groups`` KV caches for the shared block plus per-layer SSM
states; with the cache sequence dim sharded over "model", the hybrid runs
the long_500k cell (one O(S) cache sweep for 9 shared applications + O(1)
SSM state updates).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models.layers import SSMState

Tree = dict


def n_groups(cfg: ModelConfig) -> int:
    if cfg.shared_attn_every <= 0:
        raise ValueError("shared_attn_every must be positive")
    if cfg.n_layers % cfg.shared_attn_every != 0:
        raise ValueError(
            f"n_layers {cfg.n_layers} not divisible by "
            f"shared_attn_every {cfg.shared_attn_every}"
        )
    return cfg.n_layers // cfg.shared_attn_every


def param_specs(cfg: ModelConfig) -> Tree:
    V, D, F = cfg.padded_vocab, cfg.d_model, cfg.d_ff
    di, n, K = cfg.d_inner, cfg.ssm_state, cfg.d_conv
    nh = cfg.mamba_heads
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    g, e = n_groups(cfg), cfg.shared_attn_every
    conv_ch = di + 2 * n  # mamba-2 convolves x, B and C together
    mamba = {
        "norm": ((g, e, D), ("layers", None, None)),
        "in_proj": ((g, e, D, 2 * di + 2 * n + nh), ("layers", None, "embed", "inner")),
        "conv_w": ((g, e, K, conv_ch), ("layers", None, None, "inner")),
        "conv_b": ((g, e, conv_ch), ("layers", None, "inner")),
        "dt_bias": ((g, e, nh), ("layers", None, None)),
        "A_log": ((g, e, nh), ("layers", None, None)),
        "D": ((g, e, nh), ("layers", None, None)),
        "out_norm": ((g, e, di), ("layers", None, "inner")),
        "out_proj": ((g, e, di, D), ("layers", None, "inner", "embed")),
    }
    shared = {
        "attn_norm": ((D,), (None,)),
        "mlp_norm": ((D,), (None,)),
        "wq": ((D, H, hd), ("embed", "heads", None)),
        "wk": ((D, KV, hd), ("embed", "kv_heads", None)),
        "wv": ((D, KV, hd), ("embed", "kv_heads", None)),
        "wo": ((H, hd, D), ("heads", None, "embed")),
        "w1": ((D, F), ("embed", "mlp")),
        "w3": ((D, F), ("embed", "mlp")),
        "w2": ((F, D), ("mlp", "embed")),
    }
    return {
        "tok_emb": ((V, D), ("vocab", "embed")),
        "final_norm": ((D,), (None,)),
        "lm_head": ((D, V), ("embed", "vocab")),
        "mamba": mamba,
        "shared": shared,
    }


def _map_specs(specs: Tree, fn) -> Tree:
    return {
        k: (_map_specs(v, fn) if isinstance(v, dict) else fn(*v))
        for k, v in specs.items()
    }


def abstract_params(cfg: ModelConfig) -> Tree:
    dt = L.dtype_of(cfg)
    return _map_specs(param_specs(cfg), lambda sh, ax: jax.ShapeDtypeStruct(sh, dt))


def param_axes(cfg: ModelConfig) -> Tree:
    return _map_specs(param_specs(cfg), lambda sh, ax: ax)


def init_params(cfg: ModelConfig, key: jax.Array) -> Tree:
    dt = L.dtype_of(cfg)
    counter = [0]

    def walk(t):
        out = {}
        for k, v in t.items():
            if isinstance(v, dict):
                out[k] = walk(v)
                continue
            sh, _ax = v
            counter[0] += 1
            kk = jax.random.fold_in(key, counter[0])
            if "norm" in k or k == "D":
                out[k] = jnp.ones(sh, dt)
            elif k == "A_log":
                out[k] = jnp.zeros(sh, jnp.float32)  # A = -1 per head
            elif k == "dt_bias":
                out[k] = jnp.full(sh, -4.6, jnp.float32)
            elif k.endswith("_b"):
                out[k] = jnp.zeros(sh, dt)
            else:
                out[k] = (jax.random.normal(kk, sh, jnp.float32) * 0.02).astype(dt)
        return out

    return walk(param_specs(cfg))


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _run(cfg: ModelConfig, params: Tree, tokens: jax.Array,
         positions: jax.Array, state: Tree | None,
         cache_position=None, collect_state: bool = False):
    """state (decode): {"conv": (G,E,B,K-1,C), "h": (G,E,B,DI,N),
    "attn_k"/"attn_v": (G,B,S,KV,hd)}."""

    x = L.embed_tokens(cfg, params["tok_emb"], tokens)
    shared = params["shared"]
    want_state = collect_state or state is not None

    def group_body(carry, inp):
        if state is None:
            gw = inp
            conv = h = ck = cv = None
        else:
            gw, conv, h, ck, cv = inp

        def layer_body(c, linp):
            if state is None:
                lw = linp
                st = None
            else:
                lw, lconv, lh = linp
                st = SSMState(conv=lconv, h=lh)
            y, ns = L.mamba2_block(
                cfg, lw, L.rms_norm(c, lw["norm"], cfg.norm_eps), st)
            ys = (ns.conv, ns.h) if want_state else None
            return c + y, ys

        if cfg.remat == "block":
            layer_body = jax.checkpoint(layer_body)
        xs = gw if state is None else (gw, conv, h)
        y, lys = L.scan(layer_body, carry, xs)

        # shared attention + MLP application
        hn = L.rms_norm(y, shared["attn_norm"], cfg.norm_eps)
        if state is None:
            o, cache = L.attention(cfg, shared, hn, positions=positions)
        else:
            o, cache = L.attention(cfg, shared, hn, positions=positions,
                                   kv_cache=(ck, cv),
                                   cache_position=cache_position)
        y = y + o
        hn = L.rms_norm(y, shared["mlp_norm"], cfg.norm_eps)
        y = y + L.mlp(cfg, shared, hn)
        ys_out = None
        if want_state:
            conv_s, h_s = lys
            ys_out = (conv_s, h_s, cache[0], cache[1])
        return y, ys_out

    if state is None:
        xs = params["mamba"]
    else:
        xs = (params["mamba"], state["conv"], state["h"],
              state["attn_k"], state["attn_v"])
    x, ys = L.scan(group_body, x, xs)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    new_state = None
    if want_state and ys is not None:
        conv_s, h_s, ak, av = ys
        new_state = {
            "conv": conv_s, "h": h_s,
            "attn_k": constrain(ak, None, "batch", "cache_seq", None, None),
            "attn_v": constrain(av, None, "batch", "cache_seq", None, None),
        }
    return x, new_state


def loss_fn(cfg: ModelConfig, params: Tree, batch: dict) -> jax.Array:
    positions = jnp.arange(batch["tokens"].shape[1])
    hidden, _ = _run(cfg, params, batch["tokens"], positions, None)
    logits = L.lm_logits(cfg, params, hidden)
    return L.cross_entropy(cfg, logits, batch["labels"])


def prefill(cfg: ModelConfig, params: Tree, batch: dict):
    positions = jnp.arange(batch["tokens"].shape[1])
    hidden, st = _run(cfg, params, batch["tokens"], positions, None,
                      collect_state=True)
    logits = L.lm_logits(cfg, params, hidden[:, -1:, :])
    return logits, st


def decode_step(cfg: ModelConfig, params: Tree, state: Tree,
                tokens: jax.Array, pos: jax.Array):
    positions = jnp.full((tokens.shape[0], 1), pos, jnp.int32)
    hidden, new_state = _run(cfg, params, tokens, positions, state,
                             cache_position=pos)
    logits = L.lm_logits(cfg, params, hidden)
    return logits, new_state


def abstract_cache(cfg: ModelConfig, batch: int, seq: int) -> Tree:
    dt = L.dtype_of(cfg)
    g, e = n_groups(cfg), cfg.shared_attn_every
    di, n, K = cfg.d_inner, cfg.ssm_state, cfg.d_conv
    conv_ch = di + 2 * n
    kv = (g, batch, seq, cfg.n_kv_heads, cfg.head_dim_)
    return {
        "conv": jax.ShapeDtypeStruct((g, e, batch, K - 1, conv_ch), dt),
        "h": jax.ShapeDtypeStruct((g, e, batch, di, n), jnp.float32),
        "attn_k": jax.ShapeDtypeStruct(kv, dt),
        "attn_v": jax.ShapeDtypeStruct(kv, dt),
    }


def cache_axes(cfg: ModelConfig) -> Tree:
    return {
        "conv": ("layers", None, "cache_batch", None, "inner"),
        "h": ("layers", None, "cache_batch", "inner", None),
        "attn_k": ("layers", "cache_batch", "cache_seq", "kv_heads", None),
        "attn_v": ("layers", "cache_batch", "cache_seq", "kv_heads", None),
    }
