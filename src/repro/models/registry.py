"""Uniform model API over the architecture families (``--arch`` dispatch)."""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, hybrid, mamba, transformer
from repro.models import layers as L

Tree = dict


@dataclasses.dataclass(frozen=True)
class ModelApi:
    cfg: ModelConfig
    init_params: Callable
    abstract_params: Callable
    param_axes: Callable
    loss_fn: Callable  # (params, batch) -> scalar
    prefill: Callable  # (params, batch) -> (logits, cache)
    decode_step: Callable  # (params, cache, tokens, pos) -> (logits, cache)
    abstract_cache: Callable  # (batch, seq) -> cache tree
    cache_axes: Callable  # () -> cache logical-axes tree


_FAMILY_MODULES = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "encdec": encdec,
    "ssm": mamba,
    "hybrid": hybrid,
}


def get_model(cfg: ModelConfig) -> ModelApi:
    mod = _FAMILY_MODULES[cfg.family]
    return ModelApi(
        cfg=cfg,
        init_params=lambda key: mod.init_params(cfg, key),
        abstract_params=lambda: mod.abstract_params(cfg),
        param_axes=lambda: mod.param_axes(cfg),
        loss_fn=lambda params, batch: mod.loss_fn(cfg, params, batch),
        prefill=lambda params, batch: mod.prefill(cfg, params, batch),
        decode_step=lambda params, cache, tokens, pos: mod.decode_step(
            cfg, params, cache, tokens, pos),
        abstract_cache=lambda batch, seq: mod.abstract_cache(cfg, batch, seq),
        cache_axes=lambda: mod.cache_axes(cfg),
    )


# ---------------------------------------------------------------------------
# abstract inputs per shape cell (the dry-run's input_specs)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, kind: str, batch: int, seq: int) -> Tree:
    """ShapeDtypeStruct stand-ins for every model input of a step kind.

    ``train``   -> batch for loss/train_step
    ``prefill`` -> prompt batch
    ``decode``  -> (cache, tokens, pos) handled by the launcher; this
                   returns just the token batch (cache comes from
                   ``abstract_cache``).
    Modality frontends are stubs: vlm adds ``patch_embeds``; encdec adds
    ``frames`` (both precomputed embeddings per the assignment).
    """

    toks = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    if kind in ("train",):
        batch_tree: Tree = {
            "tokens": toks,
            "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        }
    elif kind == "prefill":
        batch_tree = {"tokens": toks}
    elif kind == "decode":
        batch_tree = {"tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32)}
    else:
        raise ValueError(kind)

    if cfg.family == "vlm" and kind != "decode":
        batch_tree["patch_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_patches, cfg.d_model), L.dtype_of(cfg))
    if cfg.family == "encdec" and kind != "decode":
        batch_tree["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_frames, cfg.d_model), L.dtype_of(cfg))
    return batch_tree


def input_axes(cfg: ModelConfig, kind: str) -> Tree:
    axes: Tree = {"tokens": ("batch", None)}
    if kind == "train":
        axes["labels"] = ("batch", None)
    if cfg.family == "vlm" and kind != "decode":
        axes["patch_embeds"] = ("batch", None, None)
    if cfg.family == "encdec" and kind != "decode":
        axes["frames"] = ("batch", "frames", None)
    return axes
