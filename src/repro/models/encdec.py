"""Encoder-decoder trunk (Whisper-tiny family).

The audio conv frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed frame embeddings (B, n_frames, D) — i.e. the output of
Whisper's two conv layers.  Sinusoidal positions on both stacks, LayerNorm,
GELU MLP, MHA (kv == heads), no RoPE; biases omitted (documented
simplification, DESIGN.md §8).

Decode shapes lower the *decoder* serve step: self-attention KV cache of the
assigned context length + precomputed cross-attention KV over the frames.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import layers as L

Tree = dict


def _sinusoid(length: int, dim: int) -> np.ndarray:
    pos = np.arange(length)[:, None]
    i = np.arange(dim // 2)[None, :]
    angle = pos / np.power(10_000.0, 2 * i / dim)
    return np.concatenate([np.sin(angle), np.cos(angle)], axis=-1).astype(np.float32)


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

def _attn_specs(nl: int, cfg: ModelConfig, prefix: str):
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    return {
        f"{prefix}wq": ((nl, D, H, hd), ("layers", "embed", "heads", None)),
        f"{prefix}wk": ((nl, D, KV, hd), ("layers", "embed", "kv_heads", None)),
        f"{prefix}wv": ((nl, D, KV, hd), ("layers", "embed", "kv_heads", None)),
        f"{prefix}wo": ((nl, H, hd, D), ("layers", "heads", None, "embed")),
    }


def param_specs(cfg: ModelConfig) -> Tree:
    V, D, F = cfg.padded_vocab, cfg.d_model, cfg.d_ff
    ne, nd = cfg.encoder_layers, cfg.n_layers
    enc: Tree = {
        "attn_norm": ((ne, D), ("layers", None)),
        "attn_norm_b": ((ne, D), ("layers", None)),
        "mlp_norm": ((ne, D), ("layers", None)),
        "mlp_norm_b": ((ne, D), ("layers", None)),
        "w1": ((ne, D, F), ("layers", "embed", "mlp")),
        "w2": ((ne, F, D), ("layers", "mlp", "embed")),
        **_attn_specs(ne, cfg, ""),
    }
    dec: Tree = {
        "self_norm": ((nd, D), ("layers", None)),
        "self_norm_b": ((nd, D), ("layers", None)),
        "cross_norm": ((nd, D), ("layers", None)),
        "cross_norm_b": ((nd, D), ("layers", None)),
        "mlp_norm": ((nd, D), ("layers", None)),
        "mlp_norm_b": ((nd, D), ("layers", None)),
        "w1": ((nd, D, F), ("layers", "embed", "mlp")),
        "w2": ((nd, F, D), ("layers", "mlp", "embed")),
        **_attn_specs(nd, cfg, "self_"),
        **_attn_specs(nd, cfg, "cross_"),
    }
    return {
        "tok_emb": ((V, D), ("vocab", "embed")),
        "enc_final_norm": ((D,), (None,)),
        "enc_final_norm_b": ((D,), (None,)),
        "dec_final_norm": ((D,), (None,)),
        "dec_final_norm_b": ((D,), (None,)),
        "encoder": enc,
        "decoder": dec,
    }


def _map_specs(specs: Tree, fn) -> Tree:
    return {
        k: (_map_specs(v, fn) if isinstance(v, dict) else fn(*v))
        for k, v in specs.items()
    }


def abstract_params(cfg: ModelConfig) -> Tree:
    dt = L.dtype_of(cfg)
    return _map_specs(param_specs(cfg), lambda sh, ax: jax.ShapeDtypeStruct(sh, dt))


def param_axes(cfg: ModelConfig) -> Tree:
    return _map_specs(param_specs(cfg), lambda sh, ax: ax)


def init_params(cfg: ModelConfig, key: jax.Array) -> Tree:
    dt = L.dtype_of(cfg)
    counter = [0]
    specs = param_specs(cfg)

    def walk(t):
        out = {}
        for k, v in t.items():
            if isinstance(v, dict):
                out[k] = walk(v)
            else:
                sh, ax = v
                if "norm" in k and not k.endswith("_b"):
                    out[k] = jnp.ones(sh, dt)
                elif k.endswith("_b"):
                    out[k] = jnp.zeros(sh, dt)
                else:
                    counter[0] += 1
                    kk = jax.random.fold_in(key, counter[0])
                    out[k] = (jax.random.normal(kk, sh, jnp.float32) * 0.02).astype(dt)
        return out

    return walk(specs)


# ---------------------------------------------------------------------------
# encoder / decoder blocks
# ---------------------------------------------------------------------------

def _sub(w: Tree, prefix: str) -> Tree:
    return {k[len(prefix):]: v for k, v in w.items() if k.startswith(prefix)}


def encode(cfg: ModelConfig, params: Tree, frames: jax.Array) -> jax.Array:
    dt = L.dtype_of(cfg)
    T = frames.shape[1]
    x = frames.astype(dt) + jnp.asarray(
        _sinusoid(T, cfg.d_model), dt
    )[None]
    x = constrain(x, "batch", "frames", None)
    positions = jnp.arange(T)

    def body(carry, w):
        h = L.layer_norm(carry, w["attn_norm"], w["attn_norm_b"], cfg.norm_eps)
        attn_w = {k: w[k] for k in ("wq", "wk", "wv", "wo")}
        o, _ = L.attention(cfg, attn_w, h, positions=positions, causal=False)
        x1 = carry + o
        h = L.layer_norm(x1, w["mlp_norm"], w["mlp_norm_b"], cfg.norm_eps)
        x2 = x1 + L.mlp(cfg, w, h)
        return x2, None

    if cfg.remat == "block":
        body = jax.checkpoint(body)
    x, _ = L.scan(body, x, params["encoder"])
    return L.layer_norm(x, params["enc_final_norm"], params["enc_final_norm_b"],
                        cfg.norm_eps)


def _decoder_pass(cfg: ModelConfig, params: Tree, tokens: jax.Array,
                  enc_out: jax.Array, collect_cache: bool = False):
    dt = L.dtype_of(cfg)
    S = tokens.shape[1]
    x = L.embed_tokens(cfg, params["tok_emb"], tokens)
    x = x + jnp.asarray(_sinusoid(S, cfg.d_model), dt)[None]
    positions = jnp.arange(S)
    enc_positions = jnp.arange(enc_out.shape[1])

    def body(carry, w):
        h = L.layer_norm(carry, w["self_norm"], w["self_norm_b"], cfg.norm_eps)
        self_w = _sub(w, "self_")
        o, cache = L.attention(cfg, self_w, h, positions=positions, causal=True)
        x1 = carry + o
        # cross attention: project encoder K/V with this layer's weights
        cross_w = _sub(w, "cross_")
        ck = jnp.einsum("btd,dhk->bthk", enc_out, cross_w["wk"])
        cv = jnp.einsum("btd,dhk->bthk", enc_out, cross_w["wv"])
        h = L.layer_norm(x1, w["cross_norm"], w["cross_norm_b"], cfg.norm_eps)
        o, _ = L.attention(cfg, cross_w, h, positions=positions,
                           cross_kv=(ck, cv))
        x2 = x1 + o
        h = L.layer_norm(x2, w["mlp_norm"], w["mlp_norm_b"], cfg.norm_eps)
        x3 = x2 + L.mlp(cfg, w, h)
        ys = (cache, (ck, cv)) if collect_cache else None
        return x3, ys

    if cfg.remat == "block":
        body = jax.checkpoint(body)
    x, ys = L.scan(body, x, params["decoder"])
    x = L.layer_norm(x, params["dec_final_norm"], params["dec_final_norm_b"],
                     cfg.norm_eps)
    return x, ys


def loss_fn(cfg: ModelConfig, params: Tree, batch: dict) -> jax.Array:
    enc_out = encode(cfg, params, batch["frames"])
    hidden, _ = _decoder_pass(cfg, params, batch["tokens"], enc_out)
    logits = jnp.einsum("bsd,vd->bsv", hidden, params["tok_emb"])  # tied head
    logits = constrain(logits, "batch", None, "vocab")
    return L.cross_entropy(cfg, logits, batch["labels"])


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def prefill(cfg: ModelConfig, params: Tree, batch: dict):
    enc_out = encode(cfg, params, batch["frames"])
    hidden, ys = _decoder_pass(cfg, params, batch["tokens"], enc_out,
                               collect_cache=True)
    (k, v), (ck, cv) = ys
    cache = {
        "k": constrain(k, None, "batch", "cache_seq", None, None),
        "v": constrain(v, None, "batch", "cache_seq", None, None),
        "cross_k": ck,
        "cross_v": cv,
    }
    logits = jnp.einsum("bsd,vd->bsv", hidden[:, -1:, :], params["tok_emb"])
    return constrain(logits, "batch", None, "vocab"), cache


def _sinusoid_at(pos: jax.Array, dim: int) -> jax.Array:
    """Sinusoidal position row for a dynamic position (decode step)."""

    i = jnp.arange(dim // 2, dtype=jnp.float32)
    angle = pos.astype(jnp.float32) / jnp.power(10_000.0, 2 * i / dim)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


def decode_step(cfg: ModelConfig, params: Tree, cache: dict,
                tokens: jax.Array, pos: jax.Array):
    dt = L.dtype_of(cfg)
    x = L.embed_tokens(cfg, params["tok_emb"], tokens)
    x = x + _sinusoid_at(pos, cfg.d_model).astype(dt)[None, None, :]
    positions = jnp.full((tokens.shape[0], 1), pos, jnp.int32)

    def body(carry, inp):
        w, ck_self, cv_self, ck_x, cv_x = inp
        h = L.layer_norm(carry, w["self_norm"], w["self_norm_b"], cfg.norm_eps)
        self_w = _sub(w, "self_")
        o, new_cache = L.attention(cfg, self_w, h, positions=positions,
                                   kv_cache=(ck_self, cv_self),
                                   cache_position=pos)
        x1 = carry + o
        cross_w = _sub(w, "cross_")
        h = L.layer_norm(x1, w["cross_norm"], w["cross_norm_b"], cfg.norm_eps)
        o, _ = L.attention(cfg, cross_w, h, positions=positions,
                           cross_kv=(ck_x, cv_x))
        x2 = x1 + o
        h = L.layer_norm(x2, w["mlp_norm"], w["mlp_norm_b"], cfg.norm_eps)
        x3 = x2 + L.mlp(cfg, w, h)
        return x3, new_cache

    x, (k, v) = L.scan(
        body, x,
        (params["decoder"], cache["k"], cache["v"],
         cache["cross_k"], cache["cross_v"]),
    )
    x = L.layer_norm(x, params["dec_final_norm"], params["dec_final_norm_b"],
                     cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["tok_emb"])
    new_cache = dict(cache)
    new_cache["k"] = constrain(k, None, "batch", "cache_seq", None, None)
    new_cache["v"] = constrain(v, None, "batch", "cache_seq", None, None)
    return constrain(logits, "batch", None, "vocab"), new_cache


def abstract_cache(cfg: ModelConfig, batch: int, seq: int) -> Tree:
    dt = L.dtype_of(cfg)
    kv = (cfg.n_layers, batch, seq, cfg.n_kv_heads, cfg.head_dim_)
    cross = (cfg.n_layers, batch, cfg.n_frames, cfg.n_kv_heads, cfg.head_dim_)
    return {
        "k": jax.ShapeDtypeStruct(kv, dt),
        "v": jax.ShapeDtypeStruct(kv, dt),
        "cross_k": jax.ShapeDtypeStruct(cross, dt),
        "cross_v": jax.ShapeDtypeStruct(cross, dt),
    }


def cache_axes(cfg: ModelConfig) -> Tree:
    kv = ("layers", "cache_batch", "cache_seq", "kv_heads", None)
    cross = ("layers", "cache_batch", "frames", "kv_heads", None)
    return {"k": kv, "v": kv, "cross_k": cross, "cross_v": cross}
