"""Decoder-only LM trunk: dense, MoE, and VLM (prefix patch embeds) families.

Layers are stacked on a leading L axis and executed with ``jax.lax.scan``
(compile time independent of depth; per-block remat via ``jax.checkpoint``
when ``cfg.remat == "block"``).  Params are nested dicts; every leaf has a
logical-axes tuple from :func:`param_axes` that the launcher maps to the
mesh (FSDP over "data" x TP over "model"; see distributed/sharding.py).

Entry points (used by smoke tests, dry-run, train/serve launchers):

* ``loss_fn(cfg, params, batch)``                — train loss
* ``prefill(cfg, params, batch)``                — logits + KV cache
* ``decode_step(cfg, params, cache, tokens, pos)`` — 1-token serve step
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import layers as L

Tree = dict


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

def _layer_specs(cfg: ModelConfig) -> dict[str, tuple[tuple[int, ...], tuple]]:
    D, F = cfg.d_model, cfg.d_ff
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    nl = cfg.n_layers
    s: dict[str, tuple[tuple[int, ...], tuple]] = {
        "attn_norm": ((nl, D), ("layers", None)),
        "mlp_norm": ((nl, D), ("layers", None)),
        "wq": ((nl, D, H, hd), ("layers", "embed", "heads", None)),
        "wk": ((nl, D, KV, hd), ("layers", "embed", "kv_heads", None)),
        "wv": ((nl, D, KV, hd), ("layers", "embed", "kv_heads", None)),
        "wo": ((nl, H, hd, D), ("layers", "heads", None, "embed")),
    }
    if cfg.qk_norm:
        s["q_norm"] = ((nl, hd), ("layers", None))
        s["k_norm"] = ((nl, hd), ("layers", None))
    if cfg.family == "moe":
        E = cfg.n_experts
        s["router"] = ((nl, D, E), ("layers", "embed", None))
        s["w1"] = ((nl, E, D, F), ("layers", "experts", "embed", "mlp"))
        s["w2"] = ((nl, E, F, D), ("layers", "experts", "mlp", "embed"))
        if cfg.swiglu:
            s["w3"] = ((nl, E, D, F), ("layers", "experts", "embed", "mlp"))
    else:
        s["w1"] = ((nl, D, F), ("layers", "embed", "mlp"))
        s["w2"] = ((nl, F, D), ("layers", "mlp", "embed"))
        if cfg.swiglu:
            s["w3"] = ((nl, D, F), ("layers", "embed", "mlp"))
    return s


def param_specs(cfg: ModelConfig) -> Tree:
    V, D = cfg.padded_vocab, cfg.d_model
    top: Tree = {
        "tok_emb": ((V, D), ("vocab", "embed")),
        "final_norm": ((D,), (None,)),
        "layers": _layer_specs(cfg),
    }
    if not cfg.tie_embeddings:
        top["lm_head"] = ((D, V), ("embed", "vocab"))
    return top


def _map_specs(specs: Tree, fn) -> Tree:
    out = {}
    for k, v in specs.items():
        out[k] = _map_specs(v, fn) if isinstance(v, dict) else fn(*v)
    return out


def abstract_params(cfg: ModelConfig) -> Tree:
    dt = L.param_dtype_of(cfg)
    return _map_specs(param_specs(cfg), lambda shape, ax: jax.ShapeDtypeStruct(shape, dt))


def param_axes(cfg: ModelConfig) -> Tree:
    return _map_specs(param_specs(cfg), lambda shape, ax: ax)


def init_params(cfg: ModelConfig, key: jax.Array) -> Tree:
    dt = L.param_dtype_of(cfg)
    specs = param_specs(cfg)
    flat: list[tuple[tuple[str, ...], tuple]] = []

    def walk(t, path):
        for k, v in t.items():
            if isinstance(v, dict):
                walk(v, path + (k,))
            else:
                flat.append((path + (k,), v))

    walk(specs, ())
    keys = jax.random.split(key, len(flat))
    out: Tree = {}
    for (path, (shape, _ax)), kk in zip(flat, keys):
        leaf_name = path[-1]
        if "norm" in leaf_name:
            val = jnp.ones(shape, dt)
        else:
            scale = 0.02 if "emb" in leaf_name or "router" in leaf_name else (
                0.02 / np.sqrt(2 * cfg.n_layers)
            )
            val = (jax.random.normal(kk, shape, jnp.float32) * scale).astype(dt)
        node = out
        for p in path[:-1]:
            node = node.setdefault(p, {})
        node[leaf_name] = val
    return out


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _block(cfg: ModelConfig, w: Tree, x: jax.Array, positions: jax.Array,
           kv_cache=None, cache_position=None):
    h, new_cache = L.attention(
        cfg, w, L.rms_norm(x, w["attn_norm"], cfg.norm_eps),
        positions=positions, kv_cache=kv_cache, cache_position=cache_position,
    )
    x = x + h
    xn = L.rms_norm(x, w["mlp_norm"], cfg.norm_eps)
    if cfg.family == "moe":
        x = x + L.moe_mlp(cfg, w, xn)
    else:
        x = x + L.mlp(cfg, w, xn)
    return x, new_cache


def _embed_inputs(cfg: ModelConfig, params: Tree, tokens: jax.Array,
                  patch_embeds: jax.Array | None) -> jax.Array:
    x = L.embed_tokens(cfg, params["tok_emb"], tokens)
    if cfg.family == "vlm":
        if patch_embeds is None:
            raise ValueError("vlm family needs patch_embeds")
        p = patch_embeds.shape[1]
        x = jnp.concatenate(
            [patch_embeds.astype(x.dtype), x[:, p:, :]], axis=1
        )  # patches occupy the first P positions (stubbed ViT frontend)
        x = constrain(x, "batch", None, None)
    return x


def forward(cfg: ModelConfig, params: Tree, tokens: jax.Array,
            patch_embeds: jax.Array | None = None,
            collect_cache: bool = False):
    """Full-sequence forward.  Returns (hidden, stacked_kv or None)."""

    x = _embed_inputs(cfg, params, tokens, patch_embeds)
    positions = jnp.arange(tokens.shape[1])

    def body(carry, lw):
        y, cache = _block(cfg, lw, carry, positions)
        return y, (cache if collect_cache else None)

    if cfg.remat == "block":
        body = jax.checkpoint(body)
    x, caches = L.scan(body, x, params["layers"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, caches


def loss_fn(cfg: ModelConfig, params: Tree, batch: dict) -> jax.Array:
    hidden, _ = forward(cfg, params, batch["tokens"],
                        batch.get("patch_embeds"))
    logits = L.lm_logits(cfg, params, hidden)
    return L.cross_entropy(cfg, logits, batch["labels"])


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def prefill(cfg: ModelConfig, params: Tree, batch: dict):
    """Run the full prompt; returns last-position logits + stacked KV cache
    {"k": (L,B,S,KV,hd), "v": ...} with the cache seq dim SP-sharded."""

    hidden, caches = forward(cfg, params, batch["tokens"],
                             batch.get("patch_embeds"), collect_cache=True)
    k, v = caches
    cache = {
        "k": constrain(k, None, "batch", "cache_seq", None, None),
        "v": constrain(v, None, "batch", "cache_seq", None, None),
    }
    logits = L.lm_logits(cfg, params, hidden[:, -1:, :])
    return logits, cache


def decode_step(cfg: ModelConfig, params: Tree, cache: dict,
                tokens: jax.Array, pos: jax.Array):
    """One serve step: ``tokens`` is (B, 1); ``pos`` is the scalar write
    index into the (B, S_ctx) cache.  Returns (logits, updated cache)."""

    x = L.embed_tokens(cfg, params["tok_emb"], tokens)
    positions = jnp.full((tokens.shape[0], 1), pos, jnp.int32)

    def body(carry, inp):
        lw, ck, cv = inp
        y, new_cache = _block(cfg, lw, carry, positions,
                              kv_cache=(ck, cv), cache_position=pos)
        return y, new_cache

    x, (k, v) = L.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_logits(cfg, params, x)
    new_cache = {
        "k": constrain(k, None, "batch", "cache_seq", None, None),
        "v": constrain(v, None, "batch", "cache_seq", None, None),
    }
    return logits, new_cache


# ---------------------------------------------------------------------------
# abstract inputs / caches (dry-run)
# ---------------------------------------------------------------------------

def abstract_cache(cfg: ModelConfig, batch: int, seq: int) -> Tree:
    dt = L.dtype_of(cfg)
    shape = (cfg.n_layers, batch, seq, cfg.n_kv_heads, cfg.head_dim_)
    return {
        "k": jax.ShapeDtypeStruct(shape, dt),
        "v": jax.ShapeDtypeStruct(shape, dt),
    }


def cache_axes(cfg: ModelConfig) -> Tree:
    ax = ("layers", "cache_batch", "cache_seq", "kv_heads", None)
    return {"k": ax, "v": ax}
