"""Architecture configs: one module per assigned arch (``--arch <id>``)."""

from repro.configs.base import (
    SHAPE_CELLS,
    ModelConfig,
    ShapeCell,
    applicable_cells,
    smoke_variant,
)


def _modname(arch: str) -> str:
    """``qwen3-1.7b`` -> ``qwen3_1p7b`` (dashes -> _, dots -> p)."""

    return arch.replace("-", "_").replace(".", "p")


def get_config(arch: str) -> ModelConfig:
    """Load ``configs/<arch>.py``."""

    import importlib

    mod = importlib.import_module(f"repro.configs.{_modname(arch)}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    import importlib

    mod = importlib.import_module(f"repro.configs.{_modname(arch)}")
    return mod.SMOKE


ARCHITECTURES = [
    "internvl2-26b",
    "qwen3-1.7b",
    "stablelm-3b",
    "starcoder2-3b",
    "phi4-mini-3.8b",
    "whisper-tiny",
    "grok-1-314b",
    "moonshot-v1-16b-a3b",
    "zamba2-2.7b",
    "falcon-mamba-7b",
]

__all__ = [
    "ModelConfig",
    "ShapeCell",
    "SHAPE_CELLS",
    "applicable_cells",
    "smoke_variant",
    "get_config",
    "get_smoke_config",
    "ARCHITECTURES",
]
