"""grok-1-314b [moe] — 8 experts top-2 [hf:xai-org/grok-1; unverified].

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072, MoE 8e top-2.
8 experts do not divide the 16-way model axis -> experts replicate and each
expert's d_ff (32768/16) carries TP (automatic fallback; DESIGN.md §5).
"""

from repro.configs.base import ModelConfig, smoke_variant

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab_size=131_072,
    head_dim=128,
    swiglu=True,
    rope_theta=10_000.0,
    n_experts=8,
    experts_per_token=2,
)

SMOKE = smoke_variant(CONFIG)
