"""stablelm-3b [dense] — [hf:stabilityai/stablelm-2-1_6b family; unverified].

32L d_model=2560 32H (MHA kv=32) d_ff=6912 vocab=50304.  StableLM-2 style:
GELU MLP (no gating), standard RoPE.
"""

from repro.configs.base import ModelConfig, smoke_variant

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=6912,
    vocab_size=50_304,
    head_dim=80,
    swiglu=False,
    rope_theta=10_000.0,
)

SMOKE = smoke_variant(CONFIG)
