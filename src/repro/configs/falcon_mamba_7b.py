"""falcon-mamba-7b [ssm] — pure Mamba-1, attention-free [arXiv:2410.05355].

64L d_model=4096 (attn-free) vocab=65024, ssm_state=16, d_inner=8192
(expand 2), dt_rank=256, conv k=4.  Runs the long_500k cell: decode state is
O(1) in context length.
"""

from repro.configs.base import ModelConfig, smoke_variant

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,  # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab_size=65_024,
    head_dim=64,
    ssm_state=16,
    mamba_version=1,
    expand=2,
    d_conv=4,
    dt_rank=256,
)

SMOKE = smoke_variant(CONFIG, n_heads=1, n_kv_heads=1, d_ff=0)
