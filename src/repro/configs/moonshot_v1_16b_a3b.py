"""moonshot-v1-16b-a3b [moe] — 64 experts top-6
[hf:moonshotai/Moonlight-16B-A3B].

48L d_model=2048 16H (MHA kv=16) d_ff=1408 (per expert) vocab=163840,
MoE 64e top-6.  Experts shard 64/16 over the model axis — true expert
parallelism (the shared-expert and MLA pieces of Moonlight are omitted;
DESIGN.md §8).
"""

from repro.configs.base import ModelConfig, smoke_variant

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163_840,
    head_dim=128,
    swiglu=True,
    rope_theta=50_000.0,
    n_experts=64,
    experts_per_token=6,
)

SMOKE = smoke_variant(CONFIG)
