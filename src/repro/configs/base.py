"""Model/config schema for the assigned architectures and shape cells.

Each assigned architecture gets one ``configs/<id>.py`` exporting ``CONFIG``
(the exact published shape) and ``SMOKE`` (a reduced same-family config for
CPU smoke tests).  The dry-run lowers the full configs abstractly
(ShapeDtypeStruct only, no allocation).

Shape cells (assignment):

* ``train_4k``     seq 4096,   global batch 256 — lowers ``train_step``
* ``prefill_32k``  seq 32768,  global batch 32  — lowers ``prefill_step``
* ``decode_32k``   seq 32768,  global batch 128 — lowers ``serve_step``
* ``long_500k``    seq 524288, global batch 1   — ``serve_step``; only for
  sub-quadratic families (ssm/hybrid), skipped for pure full-attention archs
  (see DESIGN.md §Shape cells).
"""

from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int | None = None
    qk_norm: bool = False
    swiglu: bool = True
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-5

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 256  # routing-group tokens (bounds dispatch memory)

    # SSM (Mamba-1/2)
    ssm_state: int = 0
    d_conv: int = 4
    expand: int = 2
    mamba_version: int = 1
    dt_rank: int | None = None  # default ceil(d_model/16)
    mamba_headdim: int = 64  # mamba-2 head dim
    scan_chunk: int = 256  # chunked-scan length (bounds residual memory)

    # hybrid (Zamba2-style)
    shared_attn_every: int = 0  # shared attention block cadence; 0 = none

    # encoder-decoder (Whisper-style)
    encoder_layers: int = 0
    n_frames: int = 1500  # stubbed conv-frontend output length

    # VLM (InternVL-style)
    n_patches: int = 0  # stubbed ViT patch embeddings prepended to the text

    # numerics / system
    vocab_pad_to: int = 128
    dtype: str = "bfloat16"
    remat: str = "block"  # none | block  (activation checkpoint policy)
    attention_impl: str = "xla"  # xla | pallas (pallas = TPU only)

    # ---- perf levers (hillclimb; defaults = paper-faithful baseline) ----
    # cast weights to this dtype right before matmuls: the FSDP all-gather
    # then moves the casted tensor (fp8 halves collective bytes vs bf16)
    matmul_weight_dtype: str | None = None  # e.g. "float8_e4m3fn"
    # embedding lookup as one-hot matmul instead of gather (avoids GSPMD's
    # "involuntary full rematerialization" replication of the table)
    embed_onehot: bool = False
    # compute Mamba x_proj/dt_proj inside the rematerialized chunk body so
    # the full-sequence f32 delta/(B,S,dr+2n) tensors never materialize
    mamba_fused_proj: bool = False
    # gradient accumulation: split the global batch into microbatches of
    # this many sequences (per step); activation memory scales down ~B/mb
    microbatch: int | None = None
    # softmax statistics dtype: "float32" (baseline) or "bfloat16" (halves
    # attention-score HBM traffic in the XLA path; max-subtraction stays f32)
    softmax_dtype: str = "float32"
    # logical-axis rule overrides, e.g. (("batch", ()),) replicates
    # activation batch over the data axis — for serving, this converts the
    # per-token FSDP weight all-gathers into tiny activation all-reduces
    # (contracting-dim sharded matmuls)
    shard_rules_override: tuple = ()
    # store parameters in this dtype (weight-only quantized serving): the
    # FSDP gathers then move fp8 bytes — unlike matmul_weight_dtype, the
    # cast cannot be hoisted past the collective because storage IS fp8
    param_dtype: str | None = None
    # dtype of the MoE one-hot dispatch/combine tensors (T x E x C each —
    # THE memory elephant in MoE cells; bf16 halves it, routing-group size
    # divides it further)
    moe_dispatch_dtype: str = "float32"
    # selective-scan implementation: "xla" (chunked associative scan) or
    # "pallas" (kernels/ssm_scan — VMEM-resident state; TPU target,
    # interpret mode off-TPU)
    ssm_impl: str = "xla"

    # ------------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return ((self.vocab_size + p - 1) // p) * p

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def dt_rank_(self) -> int:
        return self.dt_rank if self.dt_rank is not None else math.ceil(self.d_model / 16)

    @property
    def mamba_heads(self) -> int:
        return self.d_inner // self.mamba_headdim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """May run the long_500k cell (SSM state or hybrid w/ O(1) blocks)."""

        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Approximate parameter count (embedding + trunk), for 6ND."""

        D, F, L, V = self.d_model, self.d_ff, self.n_layers, self.padded_vocab
        hd = self.head_dim_
        emb = V * D * (1 if self.tie_embeddings else 2)
        if self.family in ("dense", "moe", "vlm"):
            attn = D * (self.n_heads + 2 * self.n_kv_heads) * hd + self.n_heads * hd * D
            mlp_mult = 3 if self.swiglu else 2
            if self.family == "moe":
                mlp = self.n_experts * mlp_mult * D * F + D * self.n_experts
            else:
                mlp = mlp_mult * D * F
            return emb + L * (attn + mlp)
        if self.family == "ssm":
            di, st, dr = self.d_inner, self.ssm_state, self.dt_rank_
            per = (D * 2 * di) + (self.d_conv * di) + di * (dr + 2 * st) + dr * di + di * st + di + di * D
            return emb + L * per
        if self.family == "hybrid":
            di, st = self.d_inner, self.ssm_state
            nh = self.mamba_heads
            per = D * 2 * di + self.d_conv * (di + 2 * st * nh) + di * st * 0 + di + di * D + di * (2 * st)
            shared_attn = D * (self.n_heads + 2 * self.n_kv_heads) * hd + self.n_heads * hd * D + 3 * D * F
            return emb + L * per + shared_attn
        if self.family == "encdec":
            enc = self.encoder_layers * (4 * D * D + 2 * D * F)
            dec = L * (4 * D * D + 4 * D * D + 2 * D * F)
            return emb + enc + dec
        raise ValueError(self.family)

    def active_param_count(self) -> int:
        """Active params per token (= param_count for non-MoE)."""

        if self.family != "moe":
            return self.param_count()
        D, F, L = self.d_model, self.d_ff, self.n_layers
        hd = self.head_dim_
        attn = D * (self.n_heads + 2 * self.n_kv_heads) * hd + self.n_heads * hd * D
        mlp_mult = 3 if self.swiglu else 2
        active_mlp = self.experts_per_token * mlp_mult * D * F + D * self.n_experts
        emb = self.padded_vocab * D * (1 if self.tie_embeddings else 2)
        return emb + L * (attn + active_mlp)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPE_CELLS: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


def applicable_cells(cfg: ModelConfig) -> list[str]:
    """Shape cells that run for this arch (assignment skip rules)."""

    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        cells.append("long_500k")
    return cells


def smoke_variant(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""

    base = dict(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads else 0,
        d_ff=128,
        vocab_size=256,
        head_dim=16,
        moe_group_size=16,
        scan_chunk=8,
        n_frames=12 if cfg.family == "encdec" else cfg.n_frames,
        n_patches=4 if cfg.family == "vlm" else 0,
        encoder_layers=2 if cfg.encoder_layers else 0,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        experts_per_token=min(cfg.experts_per_token, 2) if cfg.experts_per_token else 0,
        ssm_state=min(cfg.ssm_state, 8) if cfg.ssm_state else 0,
        shared_attn_every=2 if cfg.shared_attn_every else 0,
        dt_rank=8 if cfg.family == "ssm" else None,
        mamba_headdim=16 if cfg.family in ("ssm", "hybrid") else cfg.mamba_headdim,
        name=cfg.name + "-smoke",
    )
    base.update(overrides)
    return dataclasses.replace(cfg, **base)
