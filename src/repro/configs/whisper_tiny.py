"""whisper-tiny [audio] — enc-dec, conv frontend stubbed [arXiv:2212.04356].

4L d_model=384 6H (MHA kv=6) d_ff=1536 vocab=51865 (padded 51968).
Encoder: 4 layers over 1500 stubbed frame embeddings.  Sinusoidal positions
(rope_theta=0), GELU MLP.  Decode shapes lower the decoder serve step.
"""

from repro.configs.base import ModelConfig, smoke_variant

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51_865,
    head_dim=64,
    swiglu=False,
    rope_theta=0.0,  # sinusoidal absolute positions
    encoder_layers=4,
    n_frames=1500,
    tie_embeddings=True,
)

SMOKE = smoke_variant(CONFIG)
