"""internvl2-26b [vlm] — InternViT + InternLM2-20B backbone [arXiv:2404.16821].

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553 (padded to 92672 for
TP divisibility).  The ViT frontend is a stub: ``input_specs()`` provides
256 precomputed patch embeddings (InternVL's 1024 patches after 0.25x pixel
shuffle) occupying the first positions of the sequence.
"""

from repro.configs.base import ModelConfig, smoke_variant

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=92_553,
    head_dim=128,
    swiglu=True,
    rope_theta=1e6,
    n_patches=256,
)

SMOKE = smoke_variant(CONFIG)
