"""zamba2-2.7b [hybrid] — Mamba2 + shared attention blocks [arXiv:2411.15242].

54L d_model=2560 (32H GQA kv=32 in the shared block) d_ff=10240 vocab=32000,
ssm_state=64.  One weight-shared attention+MLP block applied after every 6th
Mamba-2 layer (9 applications).  Runs the long_500k cell (hybrid: O(1) SSM
state + 9 shared-attn cache sweeps).
"""

from repro.configs.base import ModelConfig, smoke_variant

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32_000,
    head_dim=80,
    swiglu=True,
    rope_theta=10_000.0,
    ssm_state=64,
    mamba_version=2,
    mamba_headdim=64,
    expand=2,
    shared_attn_every=6,
)

SMOKE = smoke_variant(CONFIG, n_layers=4, shared_attn_every=2)
