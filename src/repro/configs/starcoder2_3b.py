"""starcoder2-3b [dense] — GQA, RoPE [arXiv:2402.19173].

30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152.  GELU MLP.
24 heads do not divide the 16-way model axis -> attention projections stay
head-replicated and the MLP carries TP (DESIGN.md §4).
"""

from repro.configs.base import ModelConfig, smoke_variant

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_ff=12288,
    vocab_size=49_152,
    head_dim=128,
    swiglu=False,
    rope_theta=100_000.0,
)

SMOKE = smoke_variant(CONFIG)
