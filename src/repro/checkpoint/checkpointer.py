"""Checkpoint manager: async double-buffered saves, restore, elastic reshard.

The async save IS the paper's two-region pipeline one level up: snapshot N
is handed to a background writer (region A flushing) while training
continues and snapshot N+1 accumulates (region B buffering); the writer
itself pushes bytes through the SSDUP+ burst buffer (tiered_store).  A save
is only *committed* when its manifest lands — torn checkpoints are invisible
to restart.

Elastic reshard: checkpoints are saved as full logical arrays per host
shard-set with deterministic leaf paths, so a restart under a different
mesh/topology simply loads the leaves it needs (TieredCheckpointStore.load
accepts a path subset) and re-shards via the new topology's shardings.
"""

from __future__ import annotations

import concurrent.futures as cf
import threading
import time
from typing import Any

import jax
import numpy as np

from repro.checkpoint.tiered_store import TieredCheckpointStore

Tree = Any


class Checkpointer:
    def __init__(self, store: TieredCheckpointStore, keep: int = 3):
        self.store = store
        self.keep = keep
        self._pool = cf.ThreadPoolExecutor(max_workers=1,
                                           thread_name_prefix="ckpt-writer")
        self._inflight: cf.Future | None = None
        self._lock = threading.Lock()
        self.saves_started = 0
        self.saves_completed = 0
        self.save_seconds: list[float] = []

    # -- save path ----------------------------------------------------------
    def save_async(self, step: int, tree: Tree) -> None:
        """Snapshot to host memory and write in the background.

        Blocks only if the previous save is still in flight (both pipeline
        regions occupied — the paper's 'wait until a region frees up')."""

        self.wait()  # at most one background save (two-region semantics)
        snapshot = jax.tree.map(lambda x: np.asarray(x), tree)
        self.saves_started += 1

        def work():
            t0 = time.time()
            self.store.save(step, snapshot)
            with self._lock:
                self.saves_completed += 1
                self.save_seconds.append(time.time() - t0)

        self._inflight = self._pool.submit(work)

    def save_blocking(self, step: int, tree: Tree) -> None:
        self.save_async(step, tree)
        self.wait()

    def wait(self) -> None:
        if self._inflight is not None:
            self._inflight.result()
            self._inflight = None

    # -- restore path ---------------------------------------------------------
    def restore_latest(self, like: Tree | None = None,
                       shardings: Tree | None = None) -> tuple[int, Tree] | None:
        """Load the newest committed checkpoint; optionally cast/placed like
        ``like`` (abstract tree) under ``shardings`` (elastic reshard)."""

        step = self.store.latest_step()
        if step is None:
            return None
        tree = self.store.load(step)
        if like is not None:
            tree = jax.tree.map(
                lambda l, v: np.asarray(v).astype(l.dtype).reshape(l.shape),
                like, tree)
        if shardings is not None:
            tree = jax.tree.map(jax.device_put, tree, shardings)
        return step, tree

    def close(self) -> None:
        self.wait()
        self._pool.shutdown(wait=True)
