"""Tiered checkpoint storage THROUGH the SSDUP+ burst buffer.

This is the paper's deployment story at framework level (DESIGN.md §2):
checkpoint dumps are the canonical bursty HPC write (paper §1), and on a
real cluster thousands of hosts write interleaved shards into a shared
filesystem — the offset stream at any storage target looks exactly like the
paper's mixed random/sequential traffic.  Each host therefore routes its
shard writes through a :class:`BurstBufferWriter`: sequential shard bodies
stream straight to the slow tier, while the interleaved small-extent
traffic (headers, scattered shards, optimizer-state fragments) is absorbed
by the fast tier's log and flushed sequentially in AVL order during the
next compute phase.

Format: one ``<step>/host<h>.bin`` data file per host per checkpoint step +
a JSON manifest with per-leaf (path, offset, size, dtype, shape) records.
Leaves are written at deterministic offsets so restore can read any subset
(elastic re-shard reads only the slices a new topology needs).
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any

import numpy as np

from repro.core.burst_buffer import BurstBufferWriter

Tree = Any


def _flatten(tree: Tree, prefix: str = "") -> list[tuple[str, np.ndarray]]:
    out = []
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.extend(_flatten(tree[k], f"{prefix}/{k}" if prefix else k))
    else:
        out.append((prefix, np.asarray(tree)))
    return out


def _unflatten(records: dict[str, np.ndarray]) -> Tree:
    root: Tree = {}
    for path, val in records.items():
        node = root
        parts = path.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return root


@dataclasses.dataclass(frozen=True)
class LeafRecord:
    path: str
    offset: int
    nbytes: int
    dtype: str
    shape: tuple[int, ...]


class TieredCheckpointStore:
    """Write/read checkpoints through the burst buffer on one host."""

    def __init__(self, root: str, host_id: int = 0,
                 fast_dir: str | None = None,
                 region_bytes: int = 64 << 20,
                 traffic_aware: bool = True,
                 stream_len: int = 32):
        self.root = root
        self.host_id = host_id
        self.fast_dir = fast_dir or os.path.join(root, f"_burst_host{host_id}")
        self.region_bytes = region_bytes
        self.traffic_aware = traffic_aware
        # checkpoint streams are short relative to IOR traces; a 32-request
        # window keeps the detector responsive for MiB-scale dumps
        self.stream_len = stream_len
        os.makedirs(root, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Tree, file_id: int | None = None,
             writers: int = 1, chunk: int = 1 << 20) -> dict:
        """Write one host's shard tree; returns burst-buffer stats.

        ``writers > 1`` emulates concurrent leaf writers: chunks are issued
        round-robin across ``writers`` leaf groups (server-side run-count
        randomness ~ writers/window).  ``writers == -1`` emulates the
        heavy-contention limit the paper measures at the I/O node (Fig. 3d:
        offsets effectively unordered) by shuffling the chunk arrival order
        outright — the detector must absorb nearly everything through the
        fast-tier log and the AVL-ordered flush must still reassemble every
        extent bit-exactly.
        """

        step_dir = os.path.join(self.root, f"step_{step:08d}")
        os.makedirs(step_dir, exist_ok=True)
        bb = BurstBufferWriter(
            fast_dir=self.fast_dir,
            slow_dir=step_dir,
            region_bytes=self.region_bytes,
            traffic_aware=self.traffic_aware,
            stream_len=self.stream_len,
        )
        fid = self.host_id if file_id is None else file_id
        leaves = _flatten(tree)
        manifest: list[dict] = []
        off = 0
        queues: list[list[tuple[int, bytes]]] = [[] for _ in range(max(writers, 1))]
        for i, (path, arr) in enumerate(leaves):
            data = np.ascontiguousarray(arr).tobytes()
            for lo in range(0, len(data), chunk):
                queues[i % max(writers, 1)].append(
                    (off + lo, data[lo: lo + chunk]))
            manifest.append(dataclasses.asdict(LeafRecord(
                path=path, offset=off, nbytes=len(data),
                dtype=str(arr.dtype), shape=tuple(arr.shape))))
            off += len(data)
        try:
            if writers == -1:
                flat = [item for q in queues for item in q]
                rng = np.random.default_rng(step)
                for idx in rng.permutation(len(flat)):
                    o, d = flat[idx]
                    bb.write(fid, o, d)
            else:
                live = [q for q in queues if q]
                cursors = [0] * len(live)
                while any(c < len(q) for c, q in zip(cursors, live)):
                    for wi, q in enumerate(live):
                        if cursors[wi] < len(q):
                            o, d = q[cursors[wi]]
                            bb.write(fid, o, d)
                            cursors[wi] += 1
            bb.drain()
            stats = bb.stats()
        finally:
            bb.close()
        man_path = os.path.join(step_dir, f"host{self.host_id}.manifest.json")
        with open(man_path + ".tmp", "w") as f:
            json.dump({
                "step": step,
                "host": self.host_id,
                "file_id": fid,
                "data_file": f"file_{fid}.bin",
                "leaves": manifest,
                "bb_stats": stats,
            }, f)
        os.replace(man_path + ".tmp", man_path)  # commit point
        return stats

    # -- load ---------------------------------------------------------------
    def manifest_path(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}",
                            f"host{self.host_id}.manifest.json")

    def load(self, step: int, only_paths: set[str] | None = None) -> Tree:
        with open(self.manifest_path(step)) as f:
            man = json.load(f)
        data_path = os.path.join(self.root, f"step_{step:08d}", man["data_file"])
        records: dict[str, np.ndarray] = {}
        with open(data_path, "rb") as f:
            for leaf in man["leaves"]:
                if only_paths is not None and leaf["path"] not in only_paths:
                    continue
                f.seek(leaf["offset"])
                buf = f.read(leaf["nbytes"])
                arr = np.frombuffer(buf, dtype=leaf["dtype"]).reshape(leaf["shape"])
                records[leaf["path"]] = arr
        return _unflatten(records)

    def latest_step(self) -> int | None:
        """Newest step with a committed manifest (restart entry point)."""

        if not os.path.isdir(self.root):
            return None
        best = None
        for name in os.listdir(self.root):
            if not name.startswith("step_"):
                continue
            step = int(name.split("_")[1])
            if os.path.exists(self.manifest_path(step)):
                best = step if best is None else max(best, step)
        return best
