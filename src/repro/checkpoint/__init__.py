"""Checkpoint substrate: tiered store through the burst buffer + async saves."""

from repro.checkpoint.checkpointer import Checkpointer
from repro.checkpoint.tiered_store import TieredCheckpointStore

__all__ = ["Checkpointer", "TieredCheckpointStore"]
