"""Service-level metrics: tail latency, degraded-mode throughput, recovery.

The offline fleet layer reports aggregate MB/s; a production burst-buffer
service is judged on its tails and its behaviour under failure.  This
module holds the accounting structs the service loop
(:mod:`repro.service.loop`) fills in:

* :class:`FaultRecord` — one injected fault's lifecycle: when it was
  injected, when the controller *detected* it (heartbeat timeout /
  straggler rule), when recovery (reshard + backlog replay) completed,
  and the bytes it stranded or replayed.
* :class:`ServiceMetrics` — per-scheme service accounting: request
  latency percentiles (p50/p99/p999; a request's latency is the wall
  time from its arrival to the completion of the 128-request window that
  carried it), healthy- vs degraded-mode throughput, and the byte ledger
  (completed / rejected / redirected / replayed / stranded / rebalanced).

Byte conservation is checked at two levels
(:meth:`ServiceMetrics.conservation_violations`):

* service level — every offered byte is either completed, rejected by
  admission control, or unserved (no surviving node):
  ``completed + rejected + unserved == offered``.
* SSD level — every byte written to a burst buffer is either flushed to
  the HDD, replayed on a takeover node after a crash, stranded (lost,
  ``replay=False``), or superseded by a newer version of the same extent
  before it was flushed (log-structure dedup):
  ``written_ssd == flushed + replayed + stranded + deduped``.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class FaultRecord:
    """Lifecycle of one injected fault, as the controller saw it."""

    kind: str  # "crash" | "slow" | "ssd_degrade" | "stall"
    node: int
    injected_at: float
    detected_at: float | None = None  # controller declared it (None: never)
    recovered_at: float | None = None  # reshard + backlog replay done
    stranded_bytes: int = 0
    replayed_bytes: int = 0

    @property
    def detection_seconds(self) -> float | None:
        if self.detected_at is None:
            return None
        return self.detected_at - self.injected_at

    @property
    def recovery_seconds(self) -> float | None:
        if self.recovered_at is None:
            return None
        return self.recovered_at - self.injected_at


@dataclasses.dataclass
class ServiceMetrics:
    """Per-scheme service accounting (see module docstring)."""

    scheme: str
    offered_bytes: int = 0

    # -- byte ledger (service level) -----------------------------------
    completed_bytes: int = 0  # fed through a node simulator
    rejected_bytes: int = 0  # admission control: reject
    redirected_bytes: int = 0  # admission control: redirect-to-HDD
    unserved_bytes: int = 0  # no surviving node to run them
    rebalanced_bytes: int = 0  # moved off stragglers/degraded nodes

    # -- byte ledger (SSD level) ---------------------------------------
    written_ssd_bytes: int = 0  # appended to some burst buffer
    written_hdd_bytes: int = 0  # HDD-direct foreground writes
    flushed_bytes: int = 0  # drained SSD -> HDD
    replayed_bytes: int = 0  # unflushed backlog replayed on takeover
    stranded_bytes: int = 0  # unflushed backlog lost (replay=False)
    deduped_bytes: int = 0  # superseded in the log before flushing

    # -- time accounting ------------------------------------------------
    makespan_seconds: float = 0.0  # last lane's wall at completion
    healthy_seconds: float = 0.0
    degraded_seconds: float = 0.0
    healthy_bytes: int = 0  # completed while the fleet was healthy
    degraded_bytes: int = 0  # completed while any node was impaired

    faults: list[FaultRecord] = dataclasses.field(default_factory=list)

    _latency_chunks: list[np.ndarray] = dataclasses.field(
        default_factory=list, repr=False
    )

    # -- latency ---------------------------------------------------------
    def record_latencies(self, seconds: np.ndarray) -> None:
        arr = np.asarray(seconds, dtype=np.float64)
        if arr.size:
            self._latency_chunks.append(arr)

    @property
    def latencies(self) -> np.ndarray:
        if not self._latency_chunks:
            return np.zeros(0, dtype=np.float64)
        return np.concatenate(self._latency_chunks)

    def latency_percentile(self, q: float) -> float:
        lat = self.latencies
        if not lat.size:
            return 0.0
        return float(np.percentile(lat, q, method="nearest"))

    @property
    def p50_latency(self) -> float:
        return self.latency_percentile(50.0)

    @property
    def p99_latency(self) -> float:
        return self.latency_percentile(99.0)

    @property
    def p999_latency(self) -> float:
        return self.latency_percentile(99.9)

    # -- throughput ------------------------------------------------------
    @property
    def throughput_mbs(self) -> float:
        if not self.makespan_seconds:
            return 0.0
        return self.completed_bytes / self.makespan_seconds / 1e6

    @property
    def healthy_throughput_mbs(self) -> float:
        if not self.healthy_seconds:
            return 0.0
        return self.healthy_bytes / self.healthy_seconds / 1e6

    @property
    def degraded_throughput_mbs(self) -> float:
        if not self.degraded_seconds:
            return 0.0
        return self.degraded_bytes / self.degraded_seconds / 1e6

    @property
    def recovery_seconds(self) -> float | None:
        """Worst recovery time across recovered faults (None: no fault
        completed recovery)."""

        times = [
            f.recovery_seconds for f in self.faults
            if f.recovery_seconds is not None
        ]
        return max(times) if times else None

    # -- conservation ----------------------------------------------------
    def conservation_violations(self) -> list[str]:
        """Byte-ledger identities that must hold; non-empty = bug."""

        out: list[str] = []
        served = (
            self.completed_bytes + self.rejected_bytes + self.unserved_bytes
        )
        if served != self.offered_bytes:
            out.append(
                f"service ledger: completed({self.completed_bytes}) + "
                f"rejected({self.rejected_bytes}) + "
                f"unserved({self.unserved_bytes}) = {served} "
                f"!= offered({self.offered_bytes})"
            )
        ssd_out = (
            self.flushed_bytes + self.replayed_bytes
            + self.stranded_bytes + self.deduped_bytes
        )
        if ssd_out != self.written_ssd_bytes:
            out.append(
                f"SSD ledger: flushed({self.flushed_bytes}) + "
                f"replayed({self.replayed_bytes}) + "
                f"stranded({self.stranded_bytes}) + "
                f"deduped({self.deduped_bytes}) = {ssd_out} "
                f"!= written_ssd({self.written_ssd_bytes})"
            )
        if self.deduped_bytes < 0:
            out.append(f"negative dedup: {self.deduped_bytes}")
        return out
