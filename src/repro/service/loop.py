"""Online, failure-aware burst-buffer service over the fleet engines.

:class:`BurstBufferService` turns the offline fleet replay into a
discrete-event *service*: an offered load (a timestamped
:class:`~repro.core.trace.TraceBatch`, e.g. from
:mod:`repro.service.arrivals`) is sharded across N I/O-node lanes with
the same policies the offline :class:`~repro.core.fleet.FleetSimulator`
uses, and each lane replays its windows through the incremental session
API of :class:`~repro.core.simulator.IONodeSimulator` as they *arrive* —
a window starts no earlier than its last request's arrival time and no
earlier than the lane is free.

The failure model wires the previously dormant
:mod:`repro.distributed.fault_tolerance` into the fleet:

* every lane heartbeats the :class:`HeartbeatTable` each epoch with its
  per-window wall times;
* a scripted :class:`~repro.service.injector.FaultInjector` crashes,
  slows, degrades, or stalls lanes mid-run;
* the :class:`FaultToleranceController`'s recovery actions *execute*:
  a death declaration reshards the dead lane's pending windows to
  survivors (:func:`repro.distributed.sharding.reshard_to_survivors`),
  replays its buffered-but-unflushed SSD backlog on the least-loaded
  survivor (Eq. 6 flush costing; with ``replay=False`` the backlog is
  accounted as stranded data loss), a ``steal_shard`` straggler verdict
  moves queued windows off the slow lane (LBICA-style rebalancing), and
  a ``rejoin`` brings a wrongly-declared-dead lane (stall longer than
  the heartbeat timeout) back with a fresh simulator.
* admission control (optional): when a lane's burst buffer is nearly
  full, new windows are redirected to the HDD (``force_hdd``) or
  rejected outright instead of blocking the writer.

Two clocks, deliberately separate: each lane's **wall** clock orders
arrivals, faults, and heartbeats; the simulator's internal ``st.clock``
accumulates pure service time exactly as the offline engine does.  A
no-fault service run therefore produces per-node :class:`SimResult`\\ s
**bit-identical** to ``FleetSimulator.run`` on the same trace — the
equality the service tests pin for all four schemes.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Sequence

import numpy as np

from repro.analysis import sanitize as _sanitize
from repro.core.fleet import FleetResult
from repro.core.random_factor import DEFAULT_STREAM_LEN
from repro.core.simulator import IONodeSimulator, SimResult
from repro.core.trace import TraceBatch, TraceItem
from repro.distributed.fault_tolerance import (
    FaultToleranceController,
    HeartbeatTable,
    Topology,
)
from repro.distributed.sharding import (
    TRACE_POLICIES,
    assign_nodes,
    reshard_to_survivors,
)

from .injector import FaultEvent, FaultInjector
from .metrics import FaultRecord, ServiceMetrics

ADMISSION_ACTIONS = ("redirect", "reject")


@dataclasses.dataclass
class _Window:
    """One ≤ stream_len request window queued on a lane."""

    offsets: np.ndarray
    sizes: np.ndarray
    file_ids: np.ndarray
    app_ids: np.ndarray
    times: np.ndarray

    def __post_init__(self):
        self.nbytes = int(self.sizes.sum())
        self.ready = float(self.times.max()) if len(self.times) else 0.0


class _Lane:
    """One I/O-node lane: simulator session + wall clock + work queue."""

    def __init__(self, node_id: int, sim: IONodeSimulator):
        self.node_id = node_id
        self.sim = sim
        self.wall = 0.0
        self.queue: collections.deque = collections.deque()
        self.crash_at: float | None = None
        self.declared_dead = False
        self.stall_at = float("inf")
        self.stall_until = 0.0
        self.slow_factor = 1.0
        self.ssd_degraded = False
        self.results: list[SimResult] = []
        self.epoch_steps: list[float] = []

    @property
    def serving(self) -> bool:
        return not self.declared_dead

    def impaired(self, now: float) -> bool:
        return (
            self.crash_at is not None
            or self.declared_dead
            or self.stall_until > now
            or self.slow_factor > 1.0
            or self.ssd_degraded
        )

    def queued_window_bytes(self) -> int:
        return sum(w.nbytes for k, w in self.queue if k == "win")


@dataclasses.dataclass(frozen=True)
class ServiceResult:
    """One scheme's service run: per-node results + service metrics."""

    scheme: str
    policy: str
    num_nodes: int
    node_results: tuple[SimResult, ...]
    metrics: ServiceMetrics

    @property
    def fleet(self) -> FleetResult:
        """The run viewed through the offline aggregate accounting."""

        return FleetResult(
            scheme=self.scheme, policy=self.policy,
            num_nodes=self.num_nodes, node_results=self.node_results,
        )


def _merge_results(scheme: str, results: Sequence[SimResult]) -> SimResult:
    """Fold a lane's session results (salvaged partials + final) into one."""

    if len(results) == 1:
        return results[0]
    per_app: dict[int, int] = {}
    for r in results:
        for a, b in r.per_app_bytes.items():
            per_app[a] = per_app.get(a, 0) + b
    return SimResult(
        scheme=scheme,
        io_seconds=sum(r.io_seconds for r in results),
        total_seconds=sum(r.total_seconds for r in results),
        total_bytes=sum(r.total_bytes for r in results),
        bytes_to_ssd=sum(r.bytes_to_ssd for r in results),
        bytes_to_hdd_direct=sum(r.bytes_to_hdd_direct for r in results),
        flushes=sum(r.flushes for r in results),
        flush_paused_seconds=sum(r.flush_paused_seconds for r in results),
        blocked_seconds=sum(r.blocked_seconds for r in results),
        peak_ssd_occupancy=max(
            (r.peak_ssd_occupancy for r in results), default=0
        ),
        metadata_bytes=sum(r.metadata_bytes for r in results),
        per_app_bytes=per_app,
    )


class BurstBufferService:
    """Discrete-event service loop over N :class:`IONodeSimulator` lanes.

    Parameters mirror :class:`~repro.core.fleet.FleetSimulator`
    (``node_kwargs`` pass through to every lane's simulator;
    ``ssd_capacity`` is per node), plus the service knobs:

    epoch_seconds:
        Wall-clock granularity of the event loop: heartbeats are
        recorded and the fault-tolerance controller ticks once per
        epoch.  Window timing itself is exact (a window's completion is
        its start plus its service time, not rounded to epochs).
    heartbeat_timeout / straggler_factor:
        Passed to :class:`HeartbeatTable` — a lane silent for longer
        than the timeout is declared dead; a lane whose median window
        wall time exceeds ``straggler_factor`` x the fleet median is a
        straggler.
    injector:
        A :class:`FaultInjector` script (None: no faults).
    replay:
        On failover, replay the dead lane's unflushed SSD backlog on the
        least-loaded survivor (True) or account it as stranded data loss
        (False).
    admission_occupancy / admission_action:
        When a lane's buffered SSD bytes reach this fraction of its
        buffer capacity, newly started windows are ``"redirect"``-ed to
        the HDD (served, but bypassing the buffer) or ``"reject"``-ed
        (dropped; the ledger counts them).  None disables admission
        control — required for bit-exact no-fault replay.
    rebalance_fraction:
        Fraction of a straggler's queued windows moved per
        ``steal_shard`` action.
    """

    def __init__(
        self,
        scheme: str = "ssdup+",
        num_nodes: int = 2,
        policy: str = "round-robin-app",
        stream_len: int = DEFAULT_STREAM_LEN,
        epoch_seconds: float = 1.0,
        heartbeat_timeout: float = 5.0,
        straggler_factor: float = 1.5,
        injector: FaultInjector | None = None,
        replay: bool = True,
        admission_occupancy: float | None = None,
        admission_action: str = "redirect",
        rebalance_fraction: float = 0.5,
        max_epochs: int = 1_000_000,
        sanitize: bool | None = None,
        **node_kwargs,
    ):
        if num_nodes < 1:
            raise ValueError(f"num_nodes must be >= 1, got {num_nodes}")
        if policy not in TRACE_POLICIES:
            raise ValueError(
                f"unknown policy {policy!r}; choose from "
                f"{sorted(TRACE_POLICIES)}"
            )
        if epoch_seconds <= 0:
            raise ValueError("epoch_seconds must be > 0")
        if admission_action not in ADMISSION_ACTIONS:
            raise ValueError(
                f"admission_action must be one of {ADMISSION_ACTIONS}"
            )
        if admission_occupancy is not None and not (
            0 < admission_occupancy <= 1
        ):
            raise ValueError("admission_occupancy must be in (0, 1]")
        self.scheme = scheme
        self.num_nodes = num_nodes
        self.policy = policy
        self.stream_len = stream_len
        self.epoch_seconds = epoch_seconds
        self.heartbeat_timeout = heartbeat_timeout
        self.straggler_factor = straggler_factor
        self.injector = injector or FaultInjector()
        self.replay = replay
        self.admission_occupancy = admission_occupancy
        self.admission_action = admission_action
        self.rebalance_fraction = rebalance_fraction
        self.max_epochs = max_epochs
        self.sanitize = _sanitize.resolve(sanitize)
        self.node_kwargs = node_kwargs
        self._now = 0.0

    # ------------------------------------------------------------------
    def _make_sim(self) -> IONodeSimulator:
        sim = IONodeSimulator(
            scheme=self.scheme, stream_len=self.stream_len,
            engine="batched", sanitize=self.sanitize, **self.node_kwargs,
        )
        sim.begin_session()
        return sim

    def _build_queue(self, shard: TraceBatch) -> collections.deque:
        """Lane work queue with the offline engine's exact gap/stream
        interleaving (``_run_batched``'s fire-before rule)."""

        q: collections.deque = collections.deque()
        bounds = shard.stream_bounds(self.stream_len)
        n_streams = len(bounds) - 1 if shard.num_requests else 0
        gp, gs = shard.gap_positions, shard.gap_seconds
        gi, ng = 0, len(gp)
        nreq = shard.num_requests
        for s in range(n_streams):
            a, b = int(bounds[s]), int(bounds[s + 1])
            fire_before = b if b - a == self.stream_len else nreq + 1
            while gi < ng and gp[gi] < fire_before:
                q.append(("gap", float(gs[gi])))
                gi += 1
            q.append(("win", _Window(
                offsets=shard.offsets[a:b], sizes=shard.sizes[a:b],
                file_ids=shard.file_ids[a:b], app_ids=shard.app_ids[a:b],
                times=shard.times[a:b],
            )))
        while gi < ng:
            q.append(("gap", float(gs[gi])))
            gi += 1
        return q

    # ------------------------------------------------------------------
    def run(self, trace: TraceBatch | Sequence[TraceItem]) -> ServiceResult:
        batch = (
            trace if isinstance(trace, TraceBatch)
            else TraceBatch.from_items(trace)
        )
        metrics = ServiceMetrics(
            scheme=self.scheme, offered_bytes=batch.total_bytes
        )
        shards = batch.shard(
            assign_nodes(
                self.policy, batch.offsets, batch.file_ids, batch.app_ids,
                self.num_nodes,
            ),
            self.num_nodes,
        )
        lanes = []
        for i, shard in enumerate(shards):
            lane = _Lane(i, self._make_sim())
            lane.queue = self._build_queue(shard)
            lanes.append(lane)

        self._now = 0.0
        table = HeartbeatTable(
            timeout=self.heartbeat_timeout,
            straggler_factor=self.straggler_factor,
            clock=lambda: self._now,
        )
        for lane in lanes:
            table.register(lane.node_id)
        controller = FaultToleranceController(
            table, Topology(pods=1, data=self.num_nodes, model=1)
        )
        events = collections.deque(self.injector.events)
        self._records: dict[tuple[int, str], FaultRecord] = {}

        epochs = 0
        while any(l.queue for l in lanes):
            epochs += 1
            if epochs > self.max_epochs:
                raise RuntimeError(
                    f"service loop exceeded max_epochs={self.max_epochs}"
                )
            epoch_end = self._now + self.epoch_seconds
            while events and events[0].at <= epoch_end:
                self._apply_event(lanes, events.popleft(), metrics)
            degraded = any(l.impaired(self._now) for l in lanes)

            epoch_bytes = 0
            for lane in lanes:
                epoch_bytes += self._advance_lane(lane, epoch_end, metrics)
            self._now = epoch_end
            if degraded:
                metrics.degraded_seconds += self.epoch_seconds
                metrics.degraded_bytes += epoch_bytes
            else:
                metrics.healthy_seconds += self.epoch_seconds
                metrics.healthy_bytes += epoch_bytes

            # -- heartbeats: silent while crashed or stalled ------------
            for lane in lanes:
                if lane.crash_at is not None:
                    continue
                if lane.stall_at <= self._now < lane.stall_until:
                    continue
                if lane.epoch_steps:
                    for dt in lane.epoch_steps:
                        table.heartbeat(lane.node_id, dt)
                else:
                    table.heartbeat(lane.node_id)
                lane.epoch_steps.clear()

            # -- detection + recovery -----------------------------------
            try:
                actions = controller.tick()
            except RuntimeError:
                # no data replicas left: total outage
                self._total_outage(lanes, metrics)
                break
            for action in actions:
                if action.kind == "restart_from_checkpoint":
                    for hid in action.detail["newly_dead"]:
                        self._failover(lanes, hid, metrics)
                elif action.kind == "rejoin":
                    for hid in action.detail["hosts"]:
                        self._rejoin(lanes, hid)
                elif action.kind == "steal_shard":
                    self._rebalance(
                        lanes, action.detail["from_host"], metrics
                    )

        # -- finalize: drain surviving sessions -------------------------
        for lane in lanes:
            if lane.sim._session is not None:
                res = lane.sim.end_session(drain=True)
                lane.results.append(res)
                self._account_session(lane.sim, res, 0, metrics)
        metrics.makespan_seconds = max((l.wall for l in lanes), default=0.0)
        if self.sanitize:
            violations = metrics.conservation_violations()
            _sanitize.check(
                not violations,
                "service byte ledger violated: %s", "; ".join(violations),
            )
        return ServiceResult(
            scheme=self.scheme,
            policy=self.policy,
            num_nodes=self.num_nodes,
            node_results=tuple(
                _merge_results(self.scheme, lane.results) for lane in lanes
            ),
            metrics=metrics,
        )

    # ------------------------------------------------------------------
    def _advance_lane(
        self, lane: _Lane, epoch_end: float, metrics: ServiceMetrics
    ) -> int:
        """Run the lane's queue until nothing more can START this epoch."""

        if not lane.serving:
            return 0
        done = 0
        while lane.queue:
            kind, payload = lane.queue[0]
            if kind == "gap":
                start = lane.wall
            else:
                start = max(lane.wall, payload.ready)
            if lane.stall_at <= start < lane.stall_until:
                start = lane.stall_until
            if lane.crash_at is not None and start >= lane.crash_at:
                break  # the node died before this item could start
            if start >= epoch_end:
                break
            if kind == "gap":
                lane.sim.feed_gap(payload)
                lane.wall = start + payload
                lane.queue.popleft()
                continue
            win: _Window = payload
            force_hdd = False
            if self.admission_occupancy is not None and self._overloaded(
                lane.sim
            ):
                if self.admission_action == "reject":
                    metrics.rejected_bytes += win.nbytes
                    lane.queue.popleft()
                    continue
                force_hdd = True
                metrics.redirected_bytes += win.nbytes
            dt = lane.sim.feed_window(
                win.offsets, win.sizes, win.file_ids, win.app_ids,
                force_hdd=force_hdd,
            )
            wall_dt = dt * lane.slow_factor
            lane.wall = start + wall_dt
            lane.epoch_steps.append(wall_dt)
            metrics.completed_bytes += win.nbytes
            metrics.record_latencies(lane.wall - win.times)
            done += win.nbytes
            lane.queue.popleft()
        return done

    def _overloaded(self, sim: IONodeSimulator) -> bool:
        if sim.pipeline is None:
            return False
        cap = sum(r.capacity for r in sim.pipeline.regions)
        return sim.pipeline.buffered_bytes >= self.admission_occupancy * cap

    # ------------------------------------------------------------------
    def _apply_event(
        self, lanes: list[_Lane], ev: FaultEvent, metrics: ServiceMetrics
    ) -> None:
        lane = lanes[ev.node]
        record = FaultRecord(kind=ev.kind, node=ev.node, injected_at=ev.at)
        self._records[(ev.node, ev.kind)] = record
        metrics.faults.append(record)
        if ev.kind == "crash":
            lane.crash_at = ev.at
        elif ev.kind == "slow":
            lane.slow_factor = ev.factor
        elif ev.kind == "ssd_degrade":
            # delegated to the storage model: the constant backend returns
            # a scaled copy, the FTL slows t_prog/t_erase/read_bw in place
            # (preserving identity, so pipeline trim hooks keep working)
            lane.sim.ssd = lane.sim.ssd.degraded(ev.factor)
            lane.ssd_degraded = True
        elif ev.kind == "stall":
            lane.stall_at = ev.at
            lane.stall_until = ev.at + ev.duration

    # ------------------------------------------------------------------
    def _salvage(
        self, lane: _Lane, metrics: ServiceMetrics
    ) -> tuple[int, float]:
        """End a dead lane's session without the final drain; returns
        ``(outstanding_bytes, replay_seconds)`` of the unflushed
        backlog (Eq. 6 costing)."""

        if lane.sim._session is None:
            return 0, 0.0
        partial = lane.sim.end_session(drain=False)
        lane.results.append(partial)
        pipe = lane.sim.pipeline
        outstanding = 0
        replay_dt = 0.0
        if pipe is not None:
            storage = lane.sim.ssd if lane.sim.ssd_stateful else None
            for job in pipe.drain():
                outstanding += job.bytes_left
                replay_dt += job.bytes_left / job.effective_rate(
                    lane.sim.hdd, storage
                )
        self._account_session(lane.sim, partial, outstanding, metrics)
        return outstanding, replay_dt

    def _account_session(
        self,
        sim: IONodeSimulator,
        res: SimResult,
        outstanding: int,
        metrics: ServiceMetrics,
    ) -> None:
        """Fold one session into the SSD byte ledger.  ``deduped`` is the
        log-structure savings: appended bytes whose extents were
        superseded before they were flushed."""

        metrics.written_ssd_bytes += res.bytes_to_ssd
        metrics.written_hdd_bytes += res.bytes_to_hdd_direct
        if sim.pipeline is None:
            return
        flushed = sim.pipeline.total_flushed_bytes
        metrics.flushed_bytes += flushed
        metrics.deduped_bytes += res.bytes_to_ssd - flushed - outstanding

    def _failover(
        self, lanes: list[_Lane], hid: int, metrics: ServiceMetrics
    ) -> None:
        lane = lanes[hid]
        if lane.declared_dead:
            return
        lane.declared_dead = True
        record = (
            self._records.get((hid, "crash"))
            or self._records.get((hid, "stall"))
        )
        if record is not None and record.detected_at is None:
            record.detected_at = self._now

        outstanding, replay_dt = self._salvage(lane, metrics)
        survivors = [
            l for l in lanes
            if l is not lane and l.crash_at is None and not l.declared_dead
        ]
        recovered = self._now
        if outstanding:
            if self.replay and survivors:
                takeover = min(survivors, key=lambda l: l.wall)
                takeover.wall = max(takeover.wall, self._now) + replay_dt
                recovered = self._now + replay_dt
                metrics.replayed_bytes += outstanding
                if record is not None:
                    record.replayed_bytes = outstanding
            else:
                metrics.stranded_bytes += outstanding
                if record is not None:
                    record.stranded_bytes = outstanding
        if record is not None:
            record.recovered_at = recovered

        # -- reshard the dead lane's pending windows to survivors -------
        wins = [w for k, w in lane.queue if k == "win"]
        lane.queue.clear()  # survivors hold their own copies of the gaps
        if not wins:
            return
        offs = np.concatenate([w.offsets for w in wins])
        szs = np.concatenate([w.sizes for w in wins])
        fids = np.concatenate([w.file_ids for w in wins])
        aids = np.concatenate([w.app_ids for w in wins])
        tms = np.concatenate([w.times for w in wins])
        if not survivors:
            metrics.unserved_bytes += int(szs.sum())
            return
        new_assign = reshard_to_survivors(
            self.policy, offs, fids, aids,
            np.full(len(offs), hid, dtype=np.int64),
            [l.node_id for l in survivors],
        )
        for surv in survivors:
            idx = np.nonzero(new_assign == surv.node_id)[0]
            for a in range(0, len(idx), self.stream_len):
                sel = idx[a:a + self.stream_len]
                surv.queue.append(("win", _Window(
                    offsets=offs[sel], sizes=szs[sel],
                    file_ids=fids[sel], app_ids=aids[sel], times=tms[sel],
                )))

    def _rejoin(self, lanes: list[_Lane], hid: int) -> None:
        """A declared-dead lane heartbeats again (stall ended): bring it
        back with a fresh simulator (restarted daemon, cold detector)."""

        lane = lanes[hid]
        if not lane.declared_dead or lane.crash_at is not None:
            return
        lane.declared_dead = False
        lane.sim = self._make_sim()
        record = self._records.get((hid, "stall"))
        if record is not None:
            record.recovered_at = self._now

    def _rebalance(
        self, lanes: list[_Lane], hid: int, metrics: ServiceMetrics
    ) -> None:
        """LBICA-style: move the tail of a straggler's queued windows to
        the least-loaded healthy lane."""

        lane = lanes[hid]
        if not lane.serving or lane.crash_at is not None:
            return
        for kind in ("slow", "ssd_degrade"):
            record = self._records.get((hid, kind))
            if record is not None and record.detected_at is None:
                record.detected_at = self._now
        targets = [
            l for l in lanes
            if l is not lane and l.serving and l.crash_at is None
            and l.slow_factor == 1.0 and not l.ssd_degraded
            and l.stall_until <= self._now
        ]
        if not targets:
            return
        n_wins = sum(1 for k, _ in lane.queue if k == "win")
        k = int(n_wins * self.rebalance_fraction)
        if k < 1:
            return
        target = min(
            targets, key=lambda l: (l.wall + l.queued_window_bytes(), l.node_id)
        )
        moved: list[_Window] = []
        while k and lane.queue and lane.queue[-1][0] == "win":
            moved.append(lane.queue.pop()[1])
            k -= 1
        for w in reversed(moved):  # keep arrival order on the target
            target.queue.append(("win", w))
            metrics.rebalanced_bytes += w.nbytes

    def _total_outage(
        self, lanes: list[_Lane], metrics: ServiceMetrics
    ) -> None:
        """Every lane is dead: strand open sessions, drop queued work."""

        for lane in lanes:
            if lane.sim._session is not None:
                outstanding, _ = self._salvage(lane, metrics)
                metrics.stranded_bytes += outstanding
            metrics.unserved_bytes += lane.queued_window_bytes()
            lane.queue.clear()


def run_service_schemes(
    trace: TraceBatch | Sequence[TraceItem],
    schemes: Sequence[str] = ("orangefs", "orangefs-bb", "ssdup", "ssdup+"),
    **kwargs,
) -> dict[str, ServiceResult]:
    """Run the same offered load + fault script under several schemes —
    the paper's comparison set, *under failure*."""

    return {
        s: BurstBufferService(scheme=s, **kwargs).run(trace) for s in schemes
    }
