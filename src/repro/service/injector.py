"""Scripted, seeded fault injection for the burst-buffer service.

A :class:`FaultInjector` is an immutable, time-ordered script of
:class:`FaultEvent`\\ s the service loop applies as its wall clock passes
each event's timestamp.  Four fault kinds cover the failure modes an
I/O-node fleet actually sees:

* ``crash``       — the node stops instantly and permanently: heartbeats
  cease, buffered-but-unflushed SSD bytes are stranded (or replayed on a
  takeover node), queued work is resharded to survivors once the
  heartbeat timeout declares the node dead.
* ``slow``        — a straggler: every window's wall time is multiplied
  by ``factor`` (CPU contention, a failing NIC).  Detected by the
  heartbeat table's p95-of-medians straggler rule, answered with
  LBICA-style rebalancing.
* ``ssd_degrade`` — the node's SSD loses bandwidth (``factor`` < 1:
  a dying drive, internal GC storms).  Unlike ``slow`` this changes the
  *service* math — the node genuinely writes slower from that point on.
* ``stall``       — a transient full stop for ``duration`` seconds (GC
  pause, network partition).  A stall shorter than the heartbeat
  timeout is invisible to the controller; a longer one triggers a
  (correct!) death declaration, failover, and a ``rejoin`` when the
  node's heartbeats resume.

Scripts are either hand-written (deterministic scenario tests) or drawn
from a seeded generator (:meth:`FaultInjector.random`) for randomized
robustness sweeps — same seed, same scenario.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

FAULT_KINDS = ("crash", "slow", "ssd_degrade", "stall")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scripted fault.

    ``factor`` is the wall-time multiplier for ``slow`` (> 1) and the
    bandwidth multiplier for ``ssd_degrade`` (< 1); ``duration`` is the
    stall length for ``stall`` (ignored otherwise).
    """

    at: float
    kind: str
    node: int
    factor: float = 1.0
    duration: float = 0.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; choose from {FAULT_KINDS}"
            )
        if self.at < 0:
            raise ValueError(f"fault time must be >= 0, got {self.at}")
        if self.kind == "slow" and self.factor <= 1.0:
            raise ValueError("slow faults need factor > 1")
        if self.kind == "ssd_degrade" and not (0 < self.factor < 1.0):
            raise ValueError("ssd_degrade needs 0 < factor < 1")
        if self.kind == "stall" and self.duration <= 0:
            raise ValueError("stall faults need duration > 0")


class FaultInjector:
    """An immutable, time-sorted fault script."""

    def __init__(self, events: Iterable[FaultEvent] = ()):
        self.events: tuple[FaultEvent, ...] = tuple(
            sorted(events, key=lambda e: (e.at, e.node, e.kind))
        )

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self):
        return iter(self.events)

    # -- convenience constructors --------------------------------------
    @classmethod
    def crash_at(cls, t: float, node: int) -> "FaultInjector":
        return cls([FaultEvent(at=t, kind="crash", node=node)])

    @classmethod
    def random(
        cls,
        seed: int,
        num_nodes: int,
        horizon_seconds: float,
        crashes: int = 1,
        slows: int = 0,
        degrades: int = 0,
        stalls: int = 0,
        slow_factor: float = 3.0,
        degrade_factor: float = 0.25,
        stall_seconds: float = 10.0,
    ) -> "FaultInjector":
        """Seeded random scenario: the given number of each fault kind at
        uniform times over ``[0, horizon_seconds)`` on distinct uniform
        nodes (nodes may repeat across kinds, not within one kind)."""

        rng = np.random.default_rng(seed)
        events: list[FaultEvent] = []
        for kind, count in (
            ("crash", crashes), ("slow", slows),
            ("ssd_degrade", degrades), ("stall", stalls),
        ):
            if count <= 0:
                continue
            if count > num_nodes:
                raise ValueError(
                    f"{count} {kind} faults on {num_nodes} nodes"
                )
            nodes = rng.choice(num_nodes, size=count, replace=False)
            times = rng.uniform(0.0, horizon_seconds, size=count)
            for node, t in zip(nodes, times):
                events.append(FaultEvent(
                    at=float(t), kind=kind, node=int(node),
                    factor=(
                        slow_factor if kind == "slow"
                        else degrade_factor if kind == "ssd_degrade"
                        else 1.0
                    ),
                    duration=stall_seconds if kind == "stall" else 0.0,
                ))
        return cls(events)


def scripted(*events: FaultEvent | Sequence) -> FaultInjector:
    """Build an injector from events or ``(at, kind, node, ...)`` tuples."""

    out = []
    for e in events:
        out.append(e if isinstance(e, FaultEvent) else FaultEvent(*e))
    return FaultInjector(out)
