"""Open-loop arrival processes for the burst-buffer service.

The offline engines ignore request timestamps; the service loop does not:
a window can only start once its last request has *arrived*.  These
helpers compose the :mod:`repro.core.workloads` generators into
timestamped offered loads:

* :func:`poisson_arrivals` — re-stamp any trace with a Poisson arrival
  process of a given aggregate rate (exponential inter-arrivals); the
  request *order* and gap markers are untouched, so offline replay of
  the result is unchanged.
* :func:`zipf_mix` — interleave several app workloads with Zipf-skewed
  popularity (client mixes where a few hot apps dominate, the
  millions-of-clients regime), then Poisson-stamp the merge.
* :func:`checkpoint_arrivals` — checkpoint-burst waves
  (:func:`repro.core.workloads.checkpoint_wave`) as a TraceBatch:
  synchronized write spikes separated by compute gaps, the canonical
  burst-buffer traffic from the Wang et al. paper (PAPERS.md).

All are seeded and pure: same arguments, same offered load.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core.trace import TraceBatch
from repro.core.workloads import Workload, checkpoint_wave


def poisson_arrivals(
    trace: TraceBatch | Workload,
    rate_rps: float,
    seed: int = 0,
    start: float = 0.0,
) -> TraceBatch:
    """Re-stamp a trace's arrival times with a Poisson process.

    ``rate_rps`` is the aggregate request arrival rate (requests/second);
    inter-arrival gaps are iid exponential.  Only ``times`` changes —
    order, offsets, and gap markers stay, so scoring and offline replay
    are unaffected.
    """

    if rate_rps <= 0:
        raise ValueError(f"rate_rps must be > 0, got {rate_rps}")
    batch = (
        trace if isinstance(trace, TraceBatch)
        else TraceBatch.from_items(trace.trace)
    )
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_rps, batch.num_requests)
    return TraceBatch(
        offsets=batch.offsets,
        sizes=batch.sizes,
        file_ids=batch.file_ids,
        app_ids=batch.app_ids,
        times=start + np.cumsum(gaps),
        gap_positions=batch.gap_positions,
        gap_seconds=batch.gap_seconds,
    )


def zipf_mix(
    apps: Sequence[Workload],
    rate_rps: float,
    s: float = 1.2,
    seed: int = 0,
) -> TraceBatch:
    """Interleave app workloads with Zipf(``s``) popularity weights.

    App ``k`` (0-based, in the given order) is drawn with probability
    proportional to ``(k + 1) ** -s`` at every arrival slot until its
    requests are exhausted; each app's internal request order is
    preserved.  The merged trace is then Poisson-stamped at
    ``rate_rps``.  Gap markers inside the member workloads are dropped
    (a multi-tenant arrival mix has no global compute phase).
    """

    if not apps:
        raise ValueError("zipf_mix needs at least one workload")
    if s < 0:
        raise ValueError(f"zipf exponent must be >= 0, got {s}")
    rng = np.random.default_rng(seed)
    queues = [
        [r for r in w.trace if hasattr(r, "offset")] for w in apps
    ]
    weights = np.array(
        [(k + 1.0) ** -s for k in range(len(apps))], dtype=np.float64
    )
    cursors = [0] * len(apps)
    merged = []
    remaining = sum(len(q) for q in queues)
    while remaining:
        live = np.array(
            [cursors[i] < len(queues[i]) for i in range(len(apps))]
        )
        p = np.where(live, weights, 0.0)
        p = p / p.sum()
        i = int(rng.choice(len(apps), p=p))
        merged.append(queues[i][cursors[i]])
        cursors[i] += 1
        remaining -= 1
    batch = TraceBatch.from_items(merged)
    return poisson_arrivals(batch, rate_rps, seed=seed + 1)


def checkpoint_arrivals(
    nproc: int,
    waves: int = 4,
    compute_seconds: float = 30.0,
    seed: int = 0,
    **kwargs,
) -> TraceBatch:
    """Checkpoint-burst offered load: synchronized write waves separated
    by ``compute_seconds`` gaps (see
    :func:`repro.core.workloads.checkpoint_wave` for the knobs)."""

    wl = checkpoint_wave(
        nproc, waves=waves, compute_seconds=compute_seconds, seed=seed,
        **kwargs,
    )
    return TraceBatch.from_items(wl.trace)
