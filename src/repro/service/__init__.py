"""Online burst-buffer service: arrivals, fault injection, failover.

The production-scenario layer over the offline fleet engines
(ROADMAP "online multi-tenant service" item):

* :mod:`repro.service.arrivals` — open-loop offered loads
  (Poisson re-stamping, Zipf client mixes, checkpoint-burst waves).
* :mod:`repro.service.injector` — seeded, scripted fault scenarios
  (crash / slow / ssd_degrade / stall).
* :mod:`repro.service.loop` — the discrete-event service: epoch
  dispatch to per-node simulator sessions, heartbeat-driven failure
  detection (:mod:`repro.distributed.fault_tolerance`), executed
  recovery (reshard, backlog replay, rebalancing, admission control).
* :mod:`repro.service.metrics` — tail latency, degraded-mode
  throughput, recovery time, and the byte-conservation ledger.
"""

from .arrivals import checkpoint_arrivals, poisson_arrivals, zipf_mix
from .injector import FAULT_KINDS, FaultEvent, FaultInjector, scripted
from .loop import BurstBufferService, ServiceResult, run_service_schemes
from .metrics import FaultRecord, ServiceMetrics

__all__ = [
    "checkpoint_arrivals",
    "poisson_arrivals",
    "zipf_mix",
    "FAULT_KINDS",
    "FaultEvent",
    "FaultInjector",
    "scripted",
    "BurstBufferService",
    "ServiceResult",
    "run_service_schemes",
    "FaultRecord",
    "ServiceMetrics",
]
