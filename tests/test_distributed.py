"""Sharding rules, fault tolerance, elasticity, optimizer, compression, data."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.data import DataConfig, ShardedLoader
from repro.distributed.fault_tolerance import (
    ElasticPlan,
    FaultToleranceController,
    HeartbeatTable,
    Topology,
)
from repro.distributed.sharding import DEFAULT_RULES, spec_for
from repro.models import get_model
from repro.optim import (
    AdamWConfig,
    CompressionConfig,
    apply_updates,
    compress_tree,
    decode,
    encode,
    init_state,
    linear_warmup_cosine,
)


class FakeMesh:
    """Duck-typed mesh: spec_for only reads .shape (a dict)."""

    def __init__(self, **axes):
        self.shape = axes


MESH = FakeMesh(data=16, model=16)
MESH3 = FakeMesh(pod=2, data=16, model=16)


class TestShardingRules:
    def test_weight_fsdp_tp(self):
        # (L, D, F): layers replicated, D -> data (FSDP), F -> model (TP)
        assert spec_for((28, 2048, 6144), ("layers", "embed", "mlp"),
                        MESH, DEFAULT_RULES) == P(None, "data", "model")

    def test_heads_divisibility_fallback(self):
        # starcoder2: 24 heads % 16 != 0 -> replicate heads
        assert spec_for((30, 3072, 24, 128), ("layers", "embed", "heads", None),
                        MESH, DEFAULT_RULES) == P(None, "data", None, None)
        # internvl: 48 heads divide -> sharded
        assert spec_for((48, 6144, 48, 128), ("layers", "embed", "heads", None),
                        MESH, DEFAULT_RULES) == P(None, "data", "model", None)

    def test_moe_expert_axis_conflict_resolution(self):
        # moonshot (64 experts): experts take "model"; mlp falls back
        assert spec_for((48, 64, 2048, 1408),
                        ("layers", "experts", "embed", "mlp"),
                        MESH, DEFAULT_RULES) == P(None, "model", "data", None)
        # grok (8 experts): experts replicate; mlp takes "model"
        assert spec_for((64, 8, 6144, 32768),
                        ("layers", "experts", "embed", "mlp"),
                        MESH, DEFAULT_RULES) == P(None, None, "data", "model")

    def test_batch_pod_prefix(self):
        # batch 256 over (pod, data) on the multi-pod mesh
        assert spec_for((256, 4096), ("batch", None), MESH3,
                        DEFAULT_RULES) == P(("pod", "data"), None)
        # batch 1 (long_500k): nothing divides -> replicated
        assert spec_for((1, 1), ("batch", None), MESH3,
                        DEFAULT_RULES) == P(None, None)

    def test_vocab_padding_makes_vocab_shardable(self):
        for arch in ("internvl2-26b", "whisper-tiny"):
            cfg = get_config(arch)
            assert cfg.padded_vocab % 16 == 0
            assert spec_for((cfg.padded_vocab, cfg.d_model),
                            ("vocab", "embed"), MESH,
                            DEFAULT_RULES)[0] == "model"

    def test_no_mesh_is_noop(self):
        assert spec_for((4, 4), ("batch", "mlp"), None, None) == P(None, None)


class TestFaultTolerance:
    def _table(self):
        clock = [0.0]
        t = HeartbeatTable(timeout=30.0, clock=lambda: clock[0])
        return t, clock

    def test_dead_host_detection(self):
        t, clock = self._table()
        for h in range(4):
            t.register(h)  # registration counts as a beat at t=0
        clock[0] = 10.0
        for h in range(3):
            t.heartbeat(h)
        clock[0] = 35.0  # host 3 silent for 35s > 30s; others 25s
        assert t.dead_hosts() == [3]

    def test_straggler_detection_p95(self):
        t, clock = self._table()
        for h in range(8):
            t.register(h)
        for _ in range(6):
            clock[0] += 1
            for h in range(8):
                t.heartbeat(h, 2.0 if h == 5 else 1.0)
        assert t.stragglers() == [5]

    def test_straggler_needs_quorum(self):
        t, clock = self._table()
        for h in range(2):
            t.register(h)
            t.heartbeat(h, 1.0)
        assert t.stragglers() == []  # too few hosts to judge

    def test_elastic_plan_drops_whole_replicas(self):
        topo = Topology(pods=2, data=16, model=16)
        plan = ElasticPlan(topo)
        # host 35 lives in replica 35 // 16 = 2
        new = plan.replan([35])
        assert new.model == 16  # TP groups never break
        assert new.pods * new.data == 31
        assert new.pods == 1  # 31 not divisible by 2 pods

    def test_elastic_plan_exhaustion(self):
        plan = ElasticPlan(Topology(pods=1, data=1, model=4))
        with pytest.raises(RuntimeError):
            plan.replan([0])

    def test_controller_emits_actions(self):
        clock = [0.0]
        table = HeartbeatTable(timeout=30.0, clock=lambda: clock[0])
        topo = Topology(pods=1, data=4, model=2)
        ctl = FaultToleranceController(table, topo)
        for h in range(topo.n_hosts):
            table.register(h)
        for _ in range(5):
            clock[0] += 5
            for h in range(topo.n_hosts):
                if h != 7:
                    table.heartbeat(h, 1.0)
        clock[0] += 40
        for h in range(topo.n_hosts):
            if h != 7:
                table.heartbeat(h, 1.0)
        actions = ctl.tick()
        kinds = [a.kind for a in actions]
        assert "restart_from_checkpoint" in kinds
        assert ctl.topo.n_hosts < topo.n_hosts


class TestOptimizer:
    def test_adamw_reduces_loss_quadratic(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
        params = {"w": jnp.asarray([3.0, -2.0])}
        state = init_state(params)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}  # d/dw w^2
            params, state, m = apply_updates(cfg, params, grads, state)
        assert float(jnp.abs(params["w"]).max()) < 0.05
        assert int(state["step"]) == 200

    def test_grad_clip_metric(self):
        cfg = AdamWConfig(grad_clip=1.0)
        params = {"w": jnp.ones(4)}
        state = init_state(params)
        _, _, m = apply_updates(cfg, params, {"w": jnp.full(4, 100.0)}, state)
        assert float(m["grad_norm"]) == pytest.approx(200.0)

    def test_schedule_warmup(self):
        s = linear_warmup_cosine(10, 100)
        assert float(s(jnp.int32(0))) == 0.0
        assert float(s(jnp.int32(10))) == pytest.approx(1.0)
        assert float(s(jnp.int32(100))) == pytest.approx(0.1, abs=1e-6)


class TestCompression:
    def test_encode_decode_error_bound(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(64, 128)), jnp.float32)
        q, s = encode(x, bits=8)
        deq = decode(q, s)
        # symmetric int8: error <= scale/2 per element
        max_scale = float(jnp.max(s))
        assert float(jnp.max(jnp.abs(deq - x))) <= max_scale * 0.5 + 1e-6

    def test_error_feedback_accumulates(self):
        cfg = CompressionConfig(enabled=True)
        g = {"w": jnp.full((4, 4), 1e-6)}  # tiny grads vanish under int8
        deq, err = compress_tree(g, None, cfg)
        # the quantization error is carried, not lost
        total = jax.tree.map(lambda a, b: a + b, deq, err)
        np.testing.assert_allclose(np.asarray(total["w"]),
                                   np.asarray(g["w"]), rtol=1e-5)

    def test_disabled_is_identity(self):
        cfg = CompressionConfig(enabled=False)
        g = {"w": jnp.ones((2, 2))}
        deq, err = compress_tree(g, None, cfg)
        assert deq is g and err is None


class TestDataPipeline:
    def test_deterministic_and_sharded(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=8, n_hosts=4)
        l0 = ShardedLoader(cfg, host_id=0)
        l1 = ShardedLoader(cfg, host_id=1)
        a = l0.get(3)
        b = ShardedLoader(cfg, host_id=0).get(3)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])  # determinism
        assert a["tokens"].shape == (2, 16)
        assert not np.array_equal(a["tokens"], l1.get(3)["tokens"])

    def test_labels_shifted(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=2)
        batch = ShardedLoader(cfg, 0).get(0)
        assert batch["labels"].shape == batch["tokens"].shape

    def test_work_stealing_reissue(self):
        cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=8, n_hosts=4)
        backup = ShardedLoader(cfg, host_id=0)
        straggler = ShardedLoader(cfg, host_id=2)
        np.testing.assert_array_equal(
            backup.reissue(5, straggler_host=2)["tokens"],
            straggler.get(5)["tokens"])
