"""TraceBatch round-trips + batched stream scoring vs the scalar oracle."""

import numpy as np
import pytest

from repro.core import (
    Gap,
    Request,
    StreamGrouper,
    TraceBatch,
    compute_stream_scores,
    ior,
    stream_percentage,
)
from repro.core.random_factor import (
    random_factor_sum,
    sorted_seek_distance,
    stream_stats_batch,
    stream_stats_batch_np,
)
from repro.core.workloads import MiB


def random_trace(n, seed=0, max_offset=1 << 30):
    rng = np.random.default_rng(seed)
    return [
        Request(
            offset=int(rng.integers(0, max_offset)),
            size=int(rng.integers(1, 1 << 20)),
            file_id=int(rng.integers(0, 4)),
            app_id=int(rng.integers(0, 3)),
            time=float(i) * 1e-4,
        )
        for i, n_ in enumerate(range(n))
    ]


class TestTraceBatchRoundTrip:
    def test_requests_round_trip(self):
        trace = random_trace(333)
        batch = TraceBatch.from_requests(trace)
        assert batch.num_requests == 333
        assert batch.total_bytes == sum(r.size for r in trace)
        assert batch.to_requests() == trace

    def test_items_round_trip_with_gaps(self):
        items = [Gap(2.0), Request(0, 10), Request(10, 10), Gap(1.5),
                 Request(100, 10), Gap(3.0)]
        batch = TraceBatch.from_items(items)
        assert batch.num_gaps == 3
        assert batch.gap_seconds_total == pytest.approx(6.5)
        assert batch.to_items() == items

    def test_workload_round_trip(self):
        w = ior("strided", 16, total_bytes=64 * MiB)
        batch = TraceBatch.from_requests(w.trace)
        assert tuple(batch.to_requests()) == w.trace

    def test_select_remaps_gap_positions(self):
        items = [Request(0, 1), Gap(1.0), Request(10, 1), Request(20, 1)]
        batch = TraceBatch.from_items(items)
        sub = batch.select(np.array([0, 2]))
        # gap preceded request 1; locally it precedes selected request 1
        assert sub.to_items() == [Request(0, 1), Gap(1.0), Request(20, 1)]

    def test_shard_partitions_without_loss(self):
        batch = TraceBatch.from_requests(random_trace(1000))
        assignment = np.arange(1000) % 3
        shards = batch.shard(assignment, 3)
        assert sum(s.num_requests for s in shards) == 1000
        assert sum(s.total_bytes for s in shards) == batch.total_bytes


class TestBatchedScoresMatchScalar:
    @pytest.mark.parametrize("stream_len", [32, 128])
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_numpy_backend_is_bit_exact(self, stream_len, seed):
        trace = random_trace(stream_len * 7 + 13, seed=seed)
        scores = compute_stream_scores(trace, stream_len, backend="numpy")
        grouper = StreamGrouper(stream_len)
        streams = list(grouper.push_many(trace))
        tail = grouper.flush()
        if tail is not None:
            streams.append(tail)
        assert len(scores) == len(streams)
        for i, s in enumerate(streams):
            offs = [r.offset for r in s]
            szs = [r.size for r in s]
            assert scores.rf_sum[i] == random_factor_sum(offs, szs)
            assert scores.percentage[i] == stream_percentage(s)  # bit-exact
            assert scores.seek_distance[i] == sorted_seek_distance(s)
            assert scores.nbytes[i] == sum(szs)

    def test_jnp_backend_matches_numpy(self):
        jax = pytest.importorskip("jax")
        del jax
        rng = np.random.default_rng(3)
        offs = rng.integers(0, 1 << 30, size=(37, 128)).astype(np.int64)
        szs = rng.integers(1, 1 << 20, size=(37, 128)).astype(np.int64)
        rf_np, pct_np, dist_np = stream_stats_batch_np(offs, szs)
        rf_j, pct_j, dist_j = stream_stats_batch(offs, szs)
        np.testing.assert_array_equal(rf_np, np.asarray(rf_j))
        np.testing.assert_allclose(pct_np, np.asarray(pct_j), atol=1e-6)
        # distance is float32-accumulated on device (int32 would wrap)
        np.testing.assert_allclose(dist_np, np.asarray(dist_j), rtol=1e-6)

    def test_pallas_backend_matches_numpy(self):
        pytest.importorskip("jax")
        trace = random_trace(128 * 5, seed=4)
        s_np = compute_stream_scores(trace, backend="numpy")
        s_pl = compute_stream_scores(trace, backend="pallas")
        np.testing.assert_array_equal(s_np.rf_sum, s_pl.rf_sum)
        np.testing.assert_allclose(s_np.percentage, s_pl.percentage, atol=1e-6)
        np.testing.assert_allclose(s_np.seek_distance, s_pl.seek_distance,
                                   rtol=1e-6)

    def test_unknown_backend_rejected(self):
        with pytest.raises(ValueError, match="backend"):
            compute_stream_scores(random_trace(10), backend="cuda")

    def test_gaps_do_not_split_streams(self):
        """Gap markers must not flush a partial window (StreamGrouper rule)."""

        trace = random_trace(100)
        gapped = trace[:50] + [Gap(5.0)] + trace[50:]
        a = compute_stream_scores(trace, stream_len=64)
        b = compute_stream_scores(gapped, stream_len=64)
        np.testing.assert_array_equal(a.rf_sum, b.rf_sum)
        np.testing.assert_array_equal(a.seek_distance, b.seek_distance)
