"""Two-region pipeline + redirector tests (paper Sections 2.3/2.4)."""

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # deterministic no-shrink fallback, same API surface
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    AdaptiveThreshold,
    DataRedirector,
    Device,
    Request,
    SingleRegionBuffer,
    TwoRegionPipeline,
)
from repro.core.pipeline import FlushState


def mk_pipeline(cap=1000, traffic_aware=True, pct=1.0):
    holder = {"pct": pct}
    p = TwoRegionPipeline(
        cap, traffic_aware=traffic_aware, flush_gate=0.5,
        percentage_source=lambda: holder["pct"],
    )
    return p, holder


class TestTwoRegionPipeline:
    def test_fill_swap_flush_cycle(self):
        p, _ = mk_pipeline(cap=300)
        for i in range(3):
            out = p.append(0, i * 100, 100)
            assert out.ok and not out.swapped
        # region R0 now full; next append swaps and schedules flush
        out = p.append(0, 300, 100)
        assert out.ok and out.swapped
        assert p.flush_job is not None
        assert p.flush_job.bytes_total == 300
        assert p.flush_state() is FlushState.FLUSHING

    def test_blocks_when_both_full(self):
        p, _ = mk_pipeline(cap=200)
        for i in range(2):
            p.append(0, i * 100, 100)
        p.append(0, 200, 100)  # swap; R0 flushing
        p.append(0, 300, 100)  # R1 full
        out = p.append(0, 400, 100)
        assert out.blocked and not out.ok
        assert p.blocked_events == 1
        # drain R0's flush -> appends work again
        p.force_flush()
        p.flush_progress(10**9)
        out = p.append(0, 400, 100)
        assert out.ok and out.swapped  # swapped back to the freed region

    def test_traffic_aware_pause_and_resume(self):
        """Paper Section 2.4.2: low random percentage => flush paused."""

        p, holder = mk_pipeline(cap=200, pct=0.1)
        p.append(0, 0, 100)
        p.append(0, 100, 100)
        p.append(0, 200, 100)  # swap, flush scheduled
        assert p.flush_state() is FlushState.PAUSED  # pct 0.1 < gate 0.5
        holder["pct"] = 0.9
        assert p.flush_state() is FlushState.FLUSHING
        holder["pct"] = 0.2
        assert p.flush_state() is FlushState.PAUSED
        p.force_flush()  # space pressure overrides the gate
        assert p.flush_state() is FlushState.FLUSHING

    def test_immediate_mode_never_pauses(self):
        p, _ = mk_pipeline(cap=200, traffic_aware=False, pct=0.0)
        p.append(0, 0, 100)
        p.append(0, 100, 100)
        p.append(0, 200, 100)
        assert p.flush_state() is FlushState.FLUSHING  # SSDUP behaviour

    def test_flush_completion_resets_region(self):
        p, _ = mk_pipeline(cap=200)
        p.append(0, 0, 100)
        p.append(0, 100, 100)
        p.append(0, 200, 100)
        region = p.flush_job.region
        used = p.flush_progress(10**9)
        assert used == 200
        assert p.flush_job is None
        assert region.used_bytes == 0
        assert p.flushes_completed == 1

    def test_drain_schedules_everything(self):
        p, _ = mk_pipeline(cap=1000)
        p.append(0, 0, 100)
        p.drain()
        assert p.flush_job is not None and p.flush_job.forced
        p.flush_progress(10**9)
        assert p.buffered_bytes == 0

    def test_oversized_request_rejected(self):
        p, _ = mk_pipeline(cap=100)
        p.append(0, 0, 100)
        with pytest.raises(ValueError):
            p.append(0, 100, 5000)  # larger than a whole region


class TestSingleRegionBuffer:
    def test_blocks_while_flushing(self):
        b = SingleRegionBuffer(200, percentage_source=lambda: 1.0)
        assert b.append(0, 0, 100).ok
        out = b.append(0, 100, 100)  # fills -> eager flush scheduled
        assert out.ok
        assert b.flush_job is not None and b.flush_job.forced
        out = b.append(0, 200, 50)
        assert out.blocked
        b.flush_progress(10**9)
        assert b.append(0, 200, 50).ok


@settings(max_examples=50, deadline=None)
@given(
    st.lists(st.integers(1, 60), min_size=1, max_size=200),
    st.integers(100, 400),
)
def test_property_pipeline_conservation(sizes, cap):
    """No bytes are ever lost: appended == flushed + still buffered, and a
    region never exceeds its capacity."""

    p, _ = mk_pipeline(cap=cap)
    appended = 0
    off = 0
    for s in sizes:
        out = p.append(0, off, s)
        if out.blocked:
            p.force_flush()
            p.flush_progress(10**9)
            out = p.append(0, off, s)
        assert out.ok
        appended += s
        off += s
        for r in p.regions:
            assert r.used_bytes <= r.capacity
    p.drain()
    while p.flush_job is not None:
        p.force_flush()
        p.flush_progress(10**9)
    assert p.total_flushed_bytes == appended
    assert p.buffered_bytes == 0


def make_stream(rf: int, n: int = 17, base: int = 0) -> list[Request]:
    """A stream of n requests whose random percentage is rf/(n-1):
    the first ``rf`` sorted-adjacent gaps jump, the rest are contiguous."""

    assert 0 <= rf <= n - 1
    offs = []
    cur = base
    for i in range(n):
        offs.append(cur)
        cur += 100 + (999_000 if i < rf else 0)
    return [Request(o, 100) for o in offs]


class TestRedirector:
    def test_starts_on_hdd(self):
        r = DataRedirector(AdaptiveThreshold(), stream_len=17)
        routed = r.route_stream(make_stream(rf=16))
        assert routed.device is Device.HDD  # first stream: no history yet
        assert routed.percentage == pytest.approx(1.0)

    def test_switches_to_ssd_on_rising_randomness(self):
        r = DataRedirector(AdaptiveThreshold(), stream_len=17)
        for k, rf in enumerate([2, 11, 14]):  # pct 0.125, ~0.69, 0.875
            r.route_stream(make_stream(rf, base=k * 10**9))
        assert r.current_device is Device.SSD
        routed = r.route_stream(make_stream(15, base=9 * 10**9))
        assert routed.device is Device.SSD

    def test_switches_back_on_sequential(self):
        r = DataRedirector(AdaptiveThreshold(), stream_len=17)
        for k, rf in enumerate([2, 11, 14, 15]):
            r.route_stream(make_stream(rf, base=k * 10**9))
        assert r.current_device is Device.SSD
        # sustained sequential traffic pulls it back
        for k in range(2):
            r.route_stream(make_stream(1, base=(10 + k) * 10**9))
        routed = r.route_stream(make_stream(1, base=20 * 10**9))
        assert routed.device is Device.HDD

    def test_route_generator_and_stats(self):
        r = DataRedirector(AdaptiveThreshold(), stream_len=17)
        reqs = make_stream(1) + make_stream(14, base=10**9)
        routed = list(r.route(iter(reqs)))
        assert len(routed) == 2
        total = r.bytes_to[Device.HDD] + r.bytes_to[Device.SSD]
        assert total == sum(q.size for q in reqs)
        assert 0.0 <= r.ssd_byte_ratio <= 1.0

    def test_finish_flushes_tail(self):
        r = DataRedirector(AdaptiveThreshold(), stream_len=128)
        for q in make_stream(1, n=10):
            list(r.route([q]))
        tail = r.finish()
        assert tail is not None and len(tail.stream) == 10
        assert r.finish() is None
