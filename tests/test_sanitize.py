"""Runtime sanitizer mode: enablement plumbing, bit-exactness of the
golden fixture matrix with every invariant armed, and seeded-bug tests
proving each wired layer actually catches its class of violation."""

import dataclasses

import numpy as np
import pytest

from repro.analysis import SanitizerError, sanitizing
from repro.analysis import sanitize as sanitize_mod
from repro.core import IONodeSimulator, ior, relabel
from repro.core.fleet import FleetSimulator
from repro.core.simulator import _ReplayState
from repro.core.trace import TraceBatch
from repro.core.workloads import MiB
from repro.service import BurstBufferService
from repro.testing import golden


def small_batch(seed: int = 0, total: int = 32 * MiB) -> TraceBatch:
    items = list(
        relabel(ior("segmented-random", 8, total_bytes=total, seed=seed),
                app_id=0, file_id=0).trace
    )
    return TraceBatch.from_items(items)


# -- enablement plumbing -------------------------------------------------


class TestEnablement:
    def test_default_off(self, monkeypatch):
        monkeypatch.delenv(sanitize_mod.ENV_VAR, raising=False)
        assert not sanitize_mod.enabled()

    @pytest.mark.parametrize("value", ["1", "true", "YES", " on "])
    def test_env_var_truthy(self, monkeypatch, value):
        monkeypatch.setenv(sanitize_mod.ENV_VAR, value)
        assert sanitize_mod.enabled()

    def test_env_var_falsy(self, monkeypatch):
        monkeypatch.setenv(sanitize_mod.ENV_VAR, "0")
        assert not sanitize_mod.enabled()

    def test_context_overrides_env(self, monkeypatch):
        monkeypatch.setenv(sanitize_mod.ENV_VAR, "1")
        with sanitizing(False):
            assert not sanitize_mod.enabled()
        assert sanitize_mod.enabled()

    def test_context_nests_and_restores(self):
        with sanitizing():
            assert sanitize_mod.enabled()
            with sanitizing(False):
                assert not sanitize_mod.enabled()
            assert sanitize_mod.enabled()
        assert not sanitize_mod.enabled()

    def test_explicit_arg_beats_override(self):
        with sanitizing():
            assert not IONodeSimulator(sanitize=False).sanitize
        assert IONodeSimulator(sanitize=True).sanitize
        assert not IONodeSimulator().sanitize

    def test_check_raises_with_formatting(self):
        sanitize_mod.check(True, "never raised")
        with pytest.raises(SanitizerError, match="got 3"):
            sanitize_mod.check(False, "got %d", 3)


# -- the smoke test: golden matrix bit-exact with checks armed -----------


class TestGoldenMatrixSanitized:
    @pytest.mark.parametrize(
        "scheme", golden.FIXTURE_SCHEMES, ids=str
    )
    def test_fixture_replay_bit_exact_under_sanitize(self, scheme):
        for workload in golden.FIXTURE_WORKLOADS:
            for policy in golden.FIXTURE_POLICIES:
                path = golden.fixture_path(scheme, workload, policy)
                payload = golden.load_fixture(path)
                with sanitizing():
                    result = golden.replay_fixture(payload)
                diffs = golden.check_fixture(payload, result)
                assert diffs == [], diffs[0]


class TestSanitizeIsPure:
    @pytest.mark.parametrize("scheme", ["orangefs-bb", "ssdup+"])
    def test_results_identical_with_and_without(self, scheme):
        batch = small_batch()
        base = IONodeSimulator(
            scheme=scheme, ssd_capacity=8 * MiB
        ).run(batch)
        san = IONodeSimulator(
            scheme=scheme, ssd_capacity=8 * MiB, sanitize=True
        ).run(batch)
        for f in dataclasses.fields(base):
            assert getattr(base, f.name) == getattr(san, f.name), f.name


# -- seeded bugs: every wired layer must catch its violation class -------


class TestCatchesInjectedBugs:
    def test_backwards_clock_caught(self, monkeypatch):
        sim = IONodeSimulator(scheme="ssdup+", sanitize=True)
        impl = IONodeSimulator._replay_stream_impl

        def warped(self, st, *args, **kwargs):
            before = st.clock
            impl(self, st, *args, **kwargs)
            st.clock = before - 1.0  # simulated accounting bug

        monkeypatch.setattr(
            IONodeSimulator, "_replay_stream_impl", warped
        )
        with pytest.raises(SanitizerError, match="backwards"):
            sim.run(small_batch())

    def test_score_trace_mismatch_caught(self):
        sim = IONodeSimulator(scheme="ssdup+", sanitize=True)
        st = _ReplayState()
        with pytest.raises(SanitizerError, match="disagrees"):
            sim._replay_stream(
                st,
                np.array([0], dtype=np.int64),
                np.array([1024], dtype=np.int64),
                np.array([0], dtype=np.int64),
                nbytes=4096,  # wrong: scores from a different trace
                pct=0.5, seeks=1, dist=0,
            )

    def test_negative_gap_caught(self):
        sim = IONodeSimulator(scheme="ssdup+", sanitize=True)
        sim.begin_session()
        with pytest.raises(SanitizerError, match="non-negative"):
            sim.feed_gap(-1.0)

    def test_invalid_trace_rejected(self):
        batch = small_batch()
        bad = dataclasses.replace(
            batch, sizes=batch.sizes * np.int64(-1)
        )
        sim = IONodeSimulator(scheme="orangefs", sanitize=True)
        with pytest.raises(ValueError, match="negative request size"):
            sim.run(bad)

    def test_fleet_shard_loss_caught(self, monkeypatch):
        fleet = FleetSimulator(
            num_nodes=2, scheme="orangefs", sanitize=True
        )
        def lossy(self, batch):
            assignment = np.arange(batch.num_requests, dtype=np.int64) % 2
            shard0, shard1 = batch.shard(assignment, 2)
            return [shard0, shard1.shard(  # silently drop node 1's work
                np.full(shard1.num_requests, 1, dtype=np.int64), 2)[0]]

        monkeypatch.setattr(FleetSimulator, "shard", lossy)
        with pytest.raises(SanitizerError, match="sharding dropped"):
            fleet.run(small_batch())

    def test_service_ledger_violation_caught(self, monkeypatch):
        original = BurstBufferService._account_session

        def tampered(self, sim, res, outstanding, metrics):
            original(self, sim, res, outstanding, metrics)
            metrics.written_ssd_bytes += 4096  # phantom SSD bytes

        monkeypatch.setattr(
            BurstBufferService, "_account_session", tampered
        )
        svc = BurstBufferService(
            scheme="ssdup+", num_nodes=2, sanitize=True
        )
        with pytest.raises(SanitizerError, match="ledger"):
            svc.run(small_batch())
        # without sanitize the same bug sails through silently (the
        # violation is still *recorded*, proving the ledger math saw it)
        svc2 = BurstBufferService(scheme="ssdup+", num_nodes=2)
        result = svc2.run(small_batch())
        assert result.metrics.conservation_violations()

    def test_ftl_ledger_violation_caught(self, monkeypatch):
        """A page that goes missing from the FTL's conservation ledger
        (valid + invalid + free == total) must trip the end-of-run
        storage check, and sail through silently without sanitize."""

        from repro.core.ftl import FTLModel
        from repro.testing.traces import golden_trace

        batch = golden_trace("mixed-burst")
        trim = FTLModel.trim

        def leaky(self, offset, nbytes):
            trim(self, offset, nbytes)
            self._invalid_pages -= 1  # page leaked out of the ledger

        monkeypatch.setattr(FTLModel, "trim", leaky)
        sim = IONodeSimulator(
            scheme="ssdup+", ssd="ftl", ssd_capacity=4 * MiB, sanitize=True
        )
        with pytest.raises(SanitizerError, match="conservation"):
            sim.run(batch)
        sim2 = IONodeSimulator(
            scheme="ssdup+", ssd="ftl", ssd_capacity=4 * MiB
        )
        res = sim2.run(batch)  # same bug, sanitizer off: no raise
        assert res.bytes_to_ssd > 0  # the buggy trim path actually ran

    def test_device_nan_caught(self):
        from repro.core import engine_device
        from repro.core.trace import compute_stream_scores

        batch = small_batch()
        scores = compute_stream_scores(batch, 128)
        tape = dict(
            engine_device.build_events(batch, scores, stream_len=128)
        )
        tape["net_t"] = tape["net_t"].copy()
        tape["net_t"][0] = np.nan  # NaN smuggled into a valid event
        events = engine_device.stack_events([tape])
        lanes = engine_device._stack_lanes(
            [engine_device.lane_consts("ssdup+", 8 << 30, 0.5)]
        )
        state0 = engine_device._stack_lanes(
            [engine_device.initial_lane_state("ssdup+", 64, None)]
        )
        with pytest.raises(SanitizerError, match="non-finite"):
            with sanitizing():
                engine_device.replay_lanes(events, lanes, state0)
        # unsanitized, the NaN silently poisons the result
        out = engine_device.replay_lanes(events, lanes, state0)
        assert np.isnan(out["io_seconds"][0])
