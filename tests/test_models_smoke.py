"""Per-architecture smoke tests (assignment deliverable f).

For every assigned arch: instantiate the REDUCED same-family config, run one
forward/train step on CPU, assert output shapes + finiteness; then check
decode consistency — prefill + one decode_step must reproduce the full
forward's last-position logits (validates KV-cache/SSM-state semantics).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHITECTURES, get_config, get_smoke_config
from repro.models import get_model

pytestmark = pytest.mark.slow  # multi-second per-arch device runs

B, S = 2, 16


def make_batch(cfg, tokens):
    batch = {"tokens": tokens, "labels": jnp.ones_like(tokens)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = (
            jnp.ones((tokens.shape[0], cfg.n_patches, cfg.d_model), jnp.bfloat16) * 0.01
        )
    if cfg.family == "encdec":
        batch["frames"] = (
            jnp.ones((tokens.shape[0], cfg.n_frames, cfg.d_model), jnp.bfloat16) * 0.01
        )
    return batch


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHITECTURES)
class TestSmokePerArch:
    def test_full_config_loads(self, arch, key):
        cfg = get_config(arch)
        assert cfg.padded_vocab % cfg.vocab_pad_to == 0
        assert cfg.param_count() > 0
        assert cfg.active_param_count() <= cfg.param_count()

    def test_forward_and_loss(self, arch, key):
        cfg = get_smoke_config(arch)
        m = get_model(cfg)
        params = m.init_params(key)
        tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        loss = m.loss_fn(params, make_batch(cfg, tokens))
        assert loss.shape == ()
        assert bool(jnp.isfinite(loss)), f"{arch}: loss not finite"

    def test_train_step_reduces_loss(self, arch, key):
        """One SGD step on a repeated batch must reduce the loss."""

        cfg = get_smoke_config(arch)
        m = get_model(cfg)
        params = m.init_params(key)
        tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        batch = make_batch(cfg, tokens)

        def loss_of(p):
            return m.loss_fn(p, batch)

        # MoE top-k routing is discrete: big steps can flip expert choices,
        # so use a gentler step there.  The VLM's vision tower also
        # overshoots at 0.5 (loss rises on the first step; 0.05-0.2 all
        # descend), so it gets a gentler step too.
        lr = {"moe": 0.02, "vlm": 0.1}.get(cfg.family, 0.5)
        l0, grads = jax.value_and_grad(loss_of)(params)
        params2 = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, grads)
        l1 = loss_of(params2)
        assert bool(jnp.isfinite(l1))
        assert float(l1) < float(l0), f"{arch}: loss did not decrease"

    def test_decode_matches_forward(self, arch, key):
        """prefill(tokens[:-1]) + decode_step(tokens[-1]) == forward(tokens)
        at the last position (KV-cache / SSM-state correctness)."""

        cfg = get_smoke_config(arch)
        m = get_model(cfg)
        params = m.init_params(key)
        tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        batch = make_batch(cfg, tokens)

        # reference: full-sequence logits at the last position
        ref_loss_inputs = {k: v for k, v in batch.items() if k != "labels"}
        full_logits, _ = m.prefill(params, ref_loss_inputs)  # last-pos logits

        # prefill on the prefix, pad caches by one slot, decode the last token
        prefix = dict(ref_loss_inputs)
        prefix["tokens"] = tokens[:, :-1]
        _, cache = m.prefill(params, prefix)

        def pad_seq(x, axes_name):
            # pad the cache sequence axis (attention caches only)
            return jnp.pad(x, [(0, 1) if i == 2 else (0, 0) for i in range(x.ndim)])

        if cfg.family in ("dense", "moe", "vlm"):
            cache = {k: pad_seq(v, k) for k, v in cache.items()}
        elif cfg.family == "encdec":
            cache = {
                k: (pad_seq(v, k) if k in ("k", "v") else v)
                for k, v in cache.items()
            }
        elif cfg.family == "hybrid":
            cache = {
                k: (pad_seq(v, k) if k.startswith("attn_") else v)
                for k, v in cache.items()
            }
        # ssm: state is O(1), nothing to pad

        step_logits, _ = m.decode_step(
            params, cache, tokens[:, -1:], jnp.int32(S - 1))

        a = np.asarray(full_logits.astype(jnp.float32))[:, 0]
        b = np.asarray(step_logits.astype(jnp.float32))[:, 0]
        np.testing.assert_allclose(a, b, rtol=0.08, atol=0.08)
        # ranking agreement at the last position (bf16-tolerant)
        assert (np.argmax(a, -1) == np.argmax(b, -1)).mean() >= 0.5


def test_all_archs_listed():
    assert len(ARCHITECTURES) == 10
