"""Integration tests: the I/O-node simulator reproduces the paper's claims.

These tests assert the paper's *relative* findings under the calibrated
device model (EXPERIMENTS.md §Paper-validation records the full numbers):

* Fig. 6   — throughput falls as random percentage rises (inverse corr.)
* Fig. 8   — SSDUP+ beats plain OrangeFS on random-heavy loads while
             buffering far less than everything
* Fig. 11  — SSDUP+ uses less SSD than SSDUP at high process counts
* Fig. 13  — traffic-aware flushing beats immediate flushing under a
             mixed load with a constrained SSD
* Fig. 14  — longer compute gaps help plain BB; SSDUP+ tolerates short gaps
"""

import numpy as np
import pytest

from repro.core import (
    Gap,
    IONodeSimulator,
    StreamGrouper,
    ior,
    mixed,
    relabel,
    run_schemes,
    stream_percentage,
)
from repro.core.workloads import GiB, MiB

SMALL = GiB // 2  # keep tests fast; trends already visible at this size


def agg(result):
    return 2 * result.throughput_mbs  # paper reports 2-I/O-node aggregates


class TestFig6InverseCorrelation:
    def test_throughput_falls_as_randomness_rises(self):
        tps, rps = [], []
        for n in [8, 32, 128]:
            w = ior("strided", n, total_bytes=SMALL)
            g = StreamGrouper(128)
            rps.append(np.mean([stream_percentage(s) for s in g.push_many(w.trace)]))
            r = IONodeSimulator(scheme="orangefs").run(list(w.trace))
            tps.append(r.throughput_mbs)
        assert rps[0] < rps[1] < rps[2]
        assert tps[0] >= tps[1] > tps[2]

    def test_random_pattern_is_slowest(self):
        results = {}
        for pat in ["segmented-contiguous", "segmented-random"]:
            w = ior(pat, 16, total_bytes=SMALL)
            results[pat] = IONodeSimulator(scheme="orangefs").run(list(w.trace))
        assert (
            results["segmented-random"].throughput_mbs
            < results["segmented-contiguous"].throughput_mbs
        )


class TestFig8SchemeComparison:
    def test_ssdupplus_beats_orangefs_on_random_heavy(self):
        w = ior("strided", 128, total_bytes=SMALL)
        res = run_schemes(w.trace, schemes=("orangefs", "ssdup+"),
                          ssd_capacity=SMALL * 2)
        assert res["ssdup+"].throughput_mbs > 1.2 * res["orangefs"].throughput_mbs

    def test_ssdupplus_buffers_selectively_at_low_contention(self):
        w = ior("strided", 16, total_bytes=SMALL)
        res = run_schemes(w.trace, schemes=("ssdup+",), ssd_capacity=SMALL * 2)
        # low randomness: most data still goes straight to HDD
        assert res["ssdup+"].ssd_byte_ratio < 0.5

    def test_fig11_ssd_capacity_saving_vs_ssdup(self):
        """Paper: at 64 procs SSDUP buffers ~99% but SSDUP+ ~47%."""

        w = ior("strided", 64, total_bytes=SMALL)
        res = run_schemes(w.trace, schemes=("ssdup", "ssdup+"),
                          ssd_capacity=SMALL * 2)
        assert res["ssdup"].ssd_byte_ratio > 0.8
        assert res["ssdup+"].ssd_byte_ratio < 0.75
        # ... at nearly the same throughput (within 15%)
        assert res["ssdup+"].throughput_mbs > 0.85 * res["ssdup"].throughput_mbs


class TestFig13TrafficAwareFlushing:
    # the paper's effect needs the real phase structure: app bursts several
    # streams long relative to the region size — use the paper-scale trace
    # (4 GiB per app, 4 GiB SSD -> 2 GiB regions), same as Fig. 13.
    @pytest.fixture(scope="class")
    def mixed_load(self):
        w1 = relabel(ior("segmented-contiguous", 16, total_bytes=4 * GiB, seed=1),
                     app_id=0, file_id=0)
        w2 = relabel(ior("segmented-random", 16, total_bytes=4 * GiB, seed=2),
                     app_id=1, file_id=1)
        return mixed(w1, w2, burst_requests=512)

    def test_ssdupplus_beats_ssdup_under_constrained_ssd(self, mixed_load):
        cap = 4 * GiB  # SSD holds half the 8 GiB mixed load
        res = run_schemes(mixed_load.trace, schemes=("ssdup", "ssdup+"),
                          ssd_capacity=cap)
        assert res["ssdup+"].throughput_mbs >= res["ssdup"].throughput_mbs
        # the win comes from pausing: SSDUP never pauses, SSDUP+ does
        assert res["ssdup"].flush_paused_seconds == 0.0
        assert res["ssdup+"].flush_paused_seconds > 0.0

    def test_plain_bb_suffers_overflow(self, mixed_load):
        cap = 4 * GiB
        res = run_schemes(mixed_load.trace, schemes=("orangefs-bb", "ssdup+"),
                          ssd_capacity=cap)
        assert res["ssdup+"].throughput_mbs > res["orangefs-bb"].throughput_mbs
        assert res["orangefs-bb"].bytes_to_hdd_direct > 0  # overflowed


class TestFig14ComputeGaps:
    def _two_phase(self, gap_s):
        wa = relabel(ior("segmented-random", 16, total_bytes=SMALL // 2, seed=5),
                     app_id=0, file_id=0)
        wb = relabel(ior("segmented-random", 16, total_bytes=SMALL // 2, seed=6),
                     app_id=1, file_id=1, start_time=1e9)
        return list(wa.trace) + [Gap(float(gap_s))] + list(wb.trace)

    def test_gap_helps_plain_bb(self):
        cap = SMALL // 4  # buffer holds half of each phase
        slow = IONodeSimulator(scheme="orangefs-bb", ssd_capacity=cap).run(
            self._two_phase(0))
        fast = IONodeSimulator(scheme="orangefs-bb", ssd_capacity=cap).run(
            self._two_phase(10))
        assert fast.throughput_mbs > slow.throughput_mbs

    def test_ssdupplus_tolerates_zero_gap(self):
        """SSDUP+'s pipeline means a 0s compute gap costs it far less than
        plain BB (paper: 20% vs 34% below peak)."""

        cap = SMALL // 4
        bb = IONodeSimulator(scheme="orangefs-bb", ssd_capacity=cap).run(
            self._two_phase(0))
        sp = IONodeSimulator(scheme="ssdup+", ssd_capacity=cap).run(
            self._two_phase(0))
        assert sp.throughput_mbs > bb.throughput_mbs


class TestAccounting:
    def test_bytes_conserved(self):
        w = ior("strided", 32, total_bytes=SMALL)
        for s, r in run_schemes(w.trace, ssd_capacity=SMALL).items():
            assert r.total_bytes == w.total_bytes, s
            assert r.bytes_to_ssd + r.bytes_to_hdd_direct == r.total_bytes

    def test_metadata_overhead_is_tiny(self):
        """Paper Table 1 / Section 2.5: AVL metadata is ~0.008% of data."""

        w = ior("segmented-random", 16, total_bytes=SMALL)
        r = IONodeSimulator(scheme="ssdup+", ssd_capacity=SMALL * 2).run(list(w.trace))
        if r.bytes_to_ssd:
            assert r.metadata_bytes <= r.bytes_to_ssd * 1e-3

    def test_gap_excluded_from_io_time(self):
        w = ior("strided", 16, total_bytes=64 * MiB)
        base = IONodeSimulator(scheme="orangefs").run(list(w.trace))
        gapped = IONodeSimulator(scheme="orangefs").run(
            [Gap(5.0)] + list(w.trace))
        assert gapped.io_seconds == pytest.approx(base.io_seconds)
        assert gapped.total_seconds == pytest.approx(base.total_seconds + 5.0)
