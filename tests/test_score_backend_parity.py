"""Scoring-backend parity: ``jnp`` and ``pallas`` vs the numpy oracle.

``compute_stream_scores`` has three backends; the numpy path is the
int64 bit-exact oracle.  The ``jnp`` backend runs under a scoped x64
enable (int64 lanes, float64 division) and must be BIT-EXACT on every
field at any offset magnitude; the ``pallas`` backend keeps the fused
kernel's int32/float32 lanes, so its seek count and percentage are exact
while the seek distance carries float32 accumulation rounding.  Both
backends score the trailing partial stream on device via the
score-neutral padded row (``TraceBatch.padded_stream_matrix``), and
traces whose offsets overflow the kernel's int32 lanes fall back to the
exact host path.

Requires jax: without it the device backends silently fall back to the
host path and parity would be vacuous.
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import TraceBatch, compute_stream_scores, ior, mixed, relabel
from repro.core.workloads import MiB

STREAM_LEN = 128


def _nontrivial_batch(tail: int = 0) -> TraceBatch:
    """Mixed-pattern trace: sequential, random and strided phases
    interleaved, offsets spanning several files.  ``tail`` trims requests
    to leave a ragged final stream."""

    apps = [
        relabel(ior("segmented-contiguous", 8, total_bytes=48 * MiB, seed=11),
                app_id=0, file_id=0),
        relabel(ior("segmented-random", 8, total_bytes=48 * MiB, seed=12),
                app_id=1, file_id=1),
        relabel(ior("strided", 16, total_bytes=48 * MiB, seed=13),
                app_id=2, file_id=2),
    ]
    items = list(mixed(*apps, burst_requests=64).trace)
    if tail:
        items = items[:-tail]
    batch = TraceBatch.from_items(items)
    # keep offsets inside the pallas kernel's int32 lanes so this exercises
    # the kernel itself, not the overflow fallback (tested separately)
    assert int(batch.offsets.max()) < np.iinfo(np.int32).max
    return batch


@pytest.fixture(scope="module")
def batch():
    return _nontrivial_batch()


@pytest.fixture(scope="module")
def ragged_batch():
    return _nontrivial_batch(tail=37)


def _assert_parity(batch, backend):
    oracle = compute_stream_scores(batch, STREAM_LEN, backend="numpy")
    scores = compute_stream_scores(batch, STREAM_LEN, backend=backend)
    assert scores.backend == backend
    assert len(scores) == len(oracle)
    # the random factor is integer counting — bit-exact, no tolerance
    np.testing.assert_array_equal(
        np.asarray(scores.rf_sum, dtype=np.int64),
        np.asarray(oracle.rf_sum, dtype=np.int64),
        err_msg=f"{backend}: rf_sum diverged from numpy oracle")
    # percentage = rf / (true_len - 1), divided host-side in float64 for
    # every backend — bit-exact, including the padded trailing partial
    np.testing.assert_array_equal(
        scores.percentage, oracle.percentage,
        err_msg=f"{backend}: percentage diverged")
    if backend == "jnp":
        # int64 lanes under scoped x64: the distance sum is exact too
        np.testing.assert_array_equal(
            scores.seek_distance, oracle.seek_distance,
            err_msg="jnp: seek_distance diverged (x64 path must be exact)")
    else:
        # the pallas kernel accumulates |sorted residual| in float32
        np.testing.assert_allclose(
            scores.seek_distance, oracle.seek_distance, rtol=1e-5,
            err_msg=f"{backend}: seek_distance diverged")
    # byte sums are exact in every backend
    np.testing.assert_array_equal(scores.nbytes, oracle.nbytes)
    np.testing.assert_array_equal(scores.offset_sum, oracle.offset_sum)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_backend_matches_oracle(batch, backend):
    _assert_parity(batch, backend)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_backend_matches_oracle_ragged_tail(ragged_batch, backend):
    _assert_parity(ragged_batch, backend)


def test_padded_tail_is_score_neutral(ragged_batch):
    """The padded row the device backends score must carry the tail's exact
    statistics: same rf/dist as the unpadded host scoring of the tail."""

    offs_p, szs_p, lens = ragged_batch.padded_stream_matrix(STREAM_LEN)
    assert offs_p.shape == (len(lens), STREAM_LEN)
    assert lens[-1] < STREAM_LEN  # this fixture really has a partial tail
    assert (lens[:-1] == STREAM_LEN).all()
    # pad block sorts strictly after (or tied with) every real request and
    # contributes zero-size contiguous records
    t = int(lens[-1])
    assert (szs_p[-1, t:] == 0).all()
    assert offs_p[-1, t:].min() >= ragged_batch.offsets[-t:].max()
    from repro.core.random_factor import stream_stats_batch_np

    rf_pad, _, dist_pad = stream_stats_batch_np(offs_p[-1:], szs_p[-1:])
    tail_o = ragged_batch.offsets[len(ragged_batch.offsets) - t:]
    tail_s = ragged_batch.sizes[len(ragged_batch.sizes) - t:]
    rf_true, _, dist_true = stream_stats_batch_np(tail_o[None, :], tail_s[None, :])
    assert rf_pad[0] == rf_true[0]
    assert dist_pad[0] == dist_true[0]


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_huge_offsets_stay_exact(backend):
    """Offsets beyond int32: jnp's x64 lanes handle them natively; pallas
    must detect the overflow and fall back to the exact host path rather
    than truncate into wrong seek counts."""

    offs = np.array([2**33, 2**33 + 4096, 2**34, 5, 2**31], dtype=np.int64)
    batch = TraceBatch(
        offsets=offs,
        sizes=np.full(offs.size, 4096, dtype=np.int64),
        file_ids=np.zeros(offs.size, dtype=np.int64),
        app_ids=np.zeros(offs.size, dtype=np.int64),
        times=np.zeros(offs.size, dtype=np.float64),
        gap_positions=np.zeros(0, dtype=np.int64),
        gap_seconds=np.zeros(0, dtype=np.float64),
    )
    oracle = compute_stream_scores(batch, STREAM_LEN, backend="numpy")
    scores = compute_stream_scores(batch, STREAM_LEN, backend=backend)
    np.testing.assert_array_equal(scores.rf_sum, oracle.rf_sum)
    np.testing.assert_array_equal(scores.percentage, oracle.percentage)
    np.testing.assert_array_equal(scores.seek_distance, oracle.seek_distance)


def test_routing_decisions_identical_across_backends(batch):
    """End-to-end: percentages from the device backends must induce the
    same redirector decisions as the oracle (fp noise must stay far from
    any threshold boundary on this trace)."""

    from repro.core import IONodeSimulator

    results = {}
    for backend in ("numpy", "jnp", "pallas"):
        scores = compute_stream_scores(batch, STREAM_LEN, backend=backend)
        sim = IONodeSimulator(scheme="ssdup+",
                              ssd_capacity=batch.total_bytes // 2)
        r = sim.run(batch, scores=scores)
        results[backend] = (r.bytes_to_ssd, r.bytes_to_hdd_direct, r.flushes)
    assert results["jnp"] == results["numpy"]
    assert results["pallas"] == results["numpy"]
