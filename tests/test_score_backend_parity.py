"""Scoring-backend parity: ``jnp`` and ``pallas`` vs the numpy oracle.

``compute_stream_scores`` has three backends; the numpy path is the
int64 bit-exact oracle, the device paths run int32 lanes with float32
distance accumulation.  These tests pin both device backends to the
oracle on non-trivial traces (mixed patterns, ragged tail, multi-MiB
offsets) so the currently 1.0x-speedup kernel cannot silently diverge
before the device-resident replay work lands.

Requires jax: without it the device backends silently fall back to the
host path and parity would be vacuous.
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import TraceBatch, compute_stream_scores, ior, mixed, relabel
from repro.core.workloads import MiB

STREAM_LEN = 128


def _nontrivial_batch(tail: int = 0) -> TraceBatch:
    """Mixed-pattern trace: sequential, random and strided phases
    interleaved, offsets spanning several files.  ``tail`` trims requests
    to leave a ragged final stream."""

    apps = [
        relabel(ior("segmented-contiguous", 8, total_bytes=48 * MiB, seed=11),
                app_id=0, file_id=0),
        relabel(ior("segmented-random", 8, total_bytes=48 * MiB, seed=12),
                app_id=1, file_id=1),
        relabel(ior("strided", 16, total_bytes=48 * MiB, seed=13),
                app_id=2, file_id=2),
    ]
    items = list(mixed(*apps, burst_requests=64).trace)
    if tail:
        items = items[:-tail]
    batch = TraceBatch.from_items(items)
    # parity is only meaningful on the device path: offsets must fit the
    # kernel's int32 lanes or the backend falls back to the host
    assert int(batch.offsets.max()) < np.iinfo(np.int32).max
    return batch


@pytest.fixture(scope="module")
def batch():
    return _nontrivial_batch()


@pytest.fixture(scope="module")
def ragged_batch():
    return _nontrivial_batch(tail=37)


def _assert_parity(batch, backend):
    oracle = compute_stream_scores(batch, STREAM_LEN, backend="numpy")
    scores = compute_stream_scores(batch, STREAM_LEN, backend=backend)
    assert scores.backend == backend
    assert len(scores) == len(oracle)
    # the random factor is integer counting — bit-exact, no tolerance
    np.testing.assert_array_equal(
        np.asarray(scores.rf_sum, dtype=np.int64),
        np.asarray(oracle.rf_sum, dtype=np.int64),
        err_msg=f"{backend}: rf_sum diverged from numpy oracle")
    # percentage = rf / (len-1): float32 division vs float64
    np.testing.assert_allclose(
        scores.percentage, oracle.percentage, rtol=1e-6, atol=1e-7,
        err_msg=f"{backend}: percentage diverged")
    # seek distance accumulates |sorted diffs| in float32 on device
    np.testing.assert_allclose(
        scores.seek_distance, oracle.seek_distance, rtol=1e-5,
        err_msg=f"{backend}: seek_distance diverged")
    # byte sums are exact in every backend
    np.testing.assert_array_equal(scores.nbytes, oracle.nbytes)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_backend_matches_oracle(batch, backend):
    _assert_parity(batch, backend)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_backend_matches_oracle_ragged_tail(ragged_batch, backend):
    _assert_parity(ragged_batch, backend)


def test_routing_decisions_identical_across_backends(batch):
    """End-to-end: percentages from the device backends must induce the
    same redirector decisions as the oracle (fp noise must stay far from
    any threshold boundary on this trace)."""

    from repro.core import IONodeSimulator

    results = {}
    for backend in ("numpy", "jnp"):
        scores = compute_stream_scores(batch, STREAM_LEN, backend=backend)
        sim = IONodeSimulator(scheme="ssdup+",
                              ssd_capacity=batch.total_bytes // 2)
        r = sim.run(batch, scores=scores)
        results[backend] = (r.bytes_to_ssd, r.bytes_to_hdd_direct, r.flushes)
    assert results["jnp"] == results["numpy"]
