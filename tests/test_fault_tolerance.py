"""Fault-tolerance runtime: bounded step windows, revival, idempotent
elastic replanning (the ISSUE-8 satellite fixes)."""

import collections

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fall back to the deterministic stand-in
    from _hypothesis_fallback import given, settings, st

from repro.distributed.fault_tolerance import (
    ElasticPlan,
    FaultToleranceController,
    HeartbeatTable,
    HostState,
    Topology,
)


class TestHostState:
    def test_step_window_is_bounded_deque(self):
        h = HostState(0, 0.0, window=8)
        for i in range(100):
            h.record_step(float(i))
        assert isinstance(h.step_durations, collections.deque)
        assert h.step_durations.maxlen == 8
        assert list(h.step_durations) == [float(i) for i in range(92, 100)]

    def test_list_init_coerced_to_deque(self):
        h = HostState(0, 0.0, window=4, step_durations=[1.0, 2.0, 3.0])
        h.record_step(4.0)
        h.record_step(5.0)
        assert list(h.step_durations) == [2.0, 3.0, 4.0, 5.0]


class TestRevival:
    def test_late_heartbeat_revives_without_reregister(self):
        now = [0.0]
        t = HeartbeatTable(timeout=5.0, clock=lambda: now[0])
        t.register(0)
        t.register(1)
        now[0] = 10.0
        t.heartbeat(1)
        assert t.dead_hosts() == [0]
        assert not t.hosts[0].alive
        # the host comes back: a plain heartbeat is enough
        t.heartbeat(0)
        assert t.dead_hosts() == []
        assert t.hosts[0].alive

    def test_revived_host_keeps_step_history(self):
        now = [0.0]
        t = HeartbeatTable(timeout=1.0, clock=lambda: now[0])
        t.register(0)
        for _ in range(5):
            t.heartbeat(0, 0.25)
        now[0] = 10.0
        assert t.dead_hosts() == [0]
        t.heartbeat(0)
        assert len(t.hosts[0].step_durations) == 5  # not reset by revival


class TestElasticPlanIdempotent:
    def test_same_dead_set_twice_same_topology(self):
        plan = ElasticPlan(Topology(pods=2, data=4, model=2))
        t1 = plan.replan([3])
        t2 = plan.replan([3])
        assert t1 == t2
        assert t1.global_batch_shards() == 7

    def test_dead_set_grows_then_shrinks(self):
        plan = ElasticPlan(Topology(pods=1, data=8, model=1))
        assert plan.replan([0, 1]).data == 6
        assert plan.replan([0]).data == 7  # host 1 revived
        assert plan.replan([]).data == 8

    def test_controller_double_tick_single_shrink(self):
        now = [0.0]
        table = HeartbeatTable(timeout=5.0, clock=lambda: now[0])
        topo = Topology(pods=1, data=8, model=1)
        for h in range(topo.n_hosts):
            table.register(h)
        ctl = FaultToleranceController(table, topo)
        now[0] = 10.0
        for h in range(1, 8):
            table.heartbeat(h)
        a1 = ctl.tick()
        assert [a.kind for a in a1] == ["restart_from_checkpoint"]
        assert ctl.topo.n_hosts == 7
        # second tick with the SAME dead set: no action, no double shrink
        a2 = ctl.tick()
        assert a2 == []
        assert ctl.topo.n_hosts == 7

    def test_controller_rejoin_on_revival(self):
        now = [0.0]
        table = HeartbeatTable(timeout=5.0, clock=lambda: now[0])
        topo = Topology(pods=1, data=4, model=1)
        for h in range(4):
            table.register(h)
        ctl = FaultToleranceController(table, topo)
        now[0] = 10.0
        for h in (0, 1, 2):
            table.heartbeat(h)
        ctl.tick()
        assert ctl.topo.n_hosts == 3
        table.heartbeat(3)  # late heartbeat: host 3 is back
        actions = ctl.tick()
        assert [a.kind for a in actions] == ["rejoin"]
        assert actions[0].detail["hosts"] == [3]
        assert ctl.topo.n_hosts == 4


@settings(max_examples=100, deadline=None)
@given(
    pods=st.integers(1, 4),
    data=st.integers(1, 6),
    model=st.integers(1, 4),
    seed=st.integers(0, 10_000),
)
def test_replan_idempotent_property(pods, data, model, seed):
    """Over random (topology, dead-set) pairs: replan is a pure,
    idempotent function of the complete dead set, anchored at the
    original topology."""

    import numpy as np

    topo = Topology(pods=pods, data=data, model=model)
    plan = ElasticPlan(topo)
    rng = np.random.default_rng(seed)
    n = topo.n_hosts
    k = int(rng.integers(0, n))  # leave at least one replica's worth alive
    dead = sorted(int(h) for h in rng.choice(n, size=k, replace=False))
    # keep at least one replica fully alive or expect the failure mode
    dead_replicas = plan.dead_replicas(dead)
    total_replicas = pods * data
    if len(dead_replicas) >= total_replicas:
        with pytest.raises(RuntimeError):
            plan.replan(dead)
        return
    t1 = plan.replan(dead)
    # 1. idempotent: same dead set, same topology
    assert plan.replan(dead) == t1
    # 2. anchored: an interleaved different dead set does not rebase it
    other = dead[: len(dead) // 2]
    plan.replan(other)
    assert plan.replan(dead) == t1
    # 3. replica accounting: surviving replicas preserved exactly
    assert t1.pods * t1.data == total_replicas - len(dead_replicas)
    # 4. model axis never shrinks (TP groups must stay complete)
    assert t1.model == model
    # 5. empty dead set is the original topology
    assert plan.replan([]) == topo
