"""Minimal stand-in for the hypothesis API surface these tests use.

When the real ``hypothesis`` package is installed (see
``requirements-dev.txt``) the test modules import it directly and get full
shrinking/replay behaviour.  Where it is absent, this fallback keeps the
property tests *running* instead of skipping: ``@given`` draws
``max_examples`` pseudo-random examples from a deterministic per-test seed
(stable across runs, so failures are reproducible) with no shrinking.

Only the strategies the suite uses are provided: ``integers``, ``floats``,
``booleans``, ``sampled_from``, ``tuples``, ``lists``.
"""

from __future__ import annotations

import functools
import zlib

import numpy as np

DEFAULT_MAX_EXAMPLES = 100


class _Strategy:
    """Wraps ``sample(rng) -> value``."""

    def __init__(self, sample):
        self.sample = sample


class _St:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1))
        )

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    @staticmethod
    def sampled_from(seq) -> _Strategy:
        seq = list(seq)
        return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])

    @staticmethod
    def tuples(*strategies: _Strategy) -> _Strategy:
        return _Strategy(lambda rng: tuple(s.sample(rng) for s in strategies))

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        def sample(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elements.sample(rng) for _ in range(n)]

        return _Strategy(sample)


st = _St()


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, **_ignored):
    """Accepts (and mostly ignores) hypothesis settings kwargs."""

    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(*arg_strategies: _Strategy, **kw_strategies: _Strategy):
    """Draw N examples per test from a per-test deterministic seed."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples",
                        DEFAULT_MAX_EXAMPLES)
            rng = np.random.default_rng(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                drawn = [s.sample(rng) for s in arg_strategies]
                drawn_kw = {k: s.sample(rng) for k, s in kw_strategies.items()}
                fn(*args, *drawn, **kwargs, **drawn_kw)

        # pytest follows __wrapped__ to the original signature and would
        # mistake the strategy parameters for fixtures; hide it.
        del wrapper.__wrapped__
        return wrapper

    return deco
