"""Flush-path edge cases beyond tests/test_batched_replay.py: empty-
pipeline drains, zero-I/O traces, degenerate gaps, and an analytic
end-of-trace Eq. 6 residual-seek charge computed independently of the
simulator."""

import numpy as np
import pytest

from repro.core import (
    Gap,
    HDDModel,
    IONodeSimulator,
    TwoRegionPipeline,
    compute_stream_scores,
)
from repro.core.pipeline import SingleRegionBuffer
from repro.core.random_factor import Request
from repro.core.workloads import KiB, MiB

STREAM_LEN = 16
REQ = 64 * KiB
SCHEMES = ("orangefs", "orangefs-bb", "ssdup", "ssdup+")


def random_stream(base: int, n: int = STREAM_LEN, file_id: int = 0,
                  seed: int = 0) -> list[Request]:
    """n requests at non-contiguous offsets (every request seeks)."""

    order = np.random.default_rng(seed).permutation(n)
    return [Request(offset=base + int(i) * 4 * REQ, size=REQ,
                    file_id=file_id) for i in order]


def seq_stream(base: int, n: int = STREAM_LEN,
               file_id: int = 0) -> list[Request]:
    return [Request(offset=base + i * REQ, size=REQ, file_id=file_id)
            for i in range(n)]


def run_both_engines(trace, scheme, **kwargs):
    out = []
    for engine in ("batched", "per-request"):
        sim = IONodeSimulator(scheme=scheme, stream_len=STREAM_LEN,
                              engine=engine, **kwargs)
        out.append(sim.run(trace))
    return out


def assert_equal_results(a, b):
    import dataclasses

    for f in dataclasses.fields(a):
        assert getattr(a, f.name) == getattr(b, f.name), f.name


class TestEmptyPipelineDrain:
    def test_two_region_drain_empty(self):
        pipe = TwoRegionPipeline(8 * MiB)
        assert pipe.drain() == []

    def test_single_region_drain_empty(self):
        buf = SingleRegionBuffer(8 * MiB)
        assert buf.drain() == []

    def test_drain_forces_backlog_and_conserves_bytes(self):
        pipe = TwoRegionPipeline(4 * REQ)  # each region holds 4 requests
        appended = 0
        for r in random_stream(0, n=8):
            assert pipe.append(r.file_id, r.offset, r.size).ok
            appended += r.size
        jobs = pipe.drain()
        assert jobs, "swaps must have queued backlog jobs"
        assert all(j.forced for j in jobs)
        assert sum(j.bytes_left for j in jobs) == appended
        # drain is idempotent: a second call re-reports the outstanding
        # jobs without scheduling duplicates
        again = pipe.drain()
        assert len(again) == len(jobs)
        assert sum(j.bytes_left for j in again) == appended


class TestZeroIOTraces:
    @pytest.mark.parametrize("scheme", SCHEMES)
    def test_empty_trace(self, scheme):
        a, b = run_both_engines([], scheme)
        assert_equal_results(a, b)
        assert a.total_bytes == a.bytes_to_ssd == a.bytes_to_hdd_direct == 0
        assert a.io_seconds == 0.0
        assert a.total_seconds == 0.0
        assert a.flushes == 0
        assert a.throughput_mbs == 0.0

    @pytest.mark.parametrize("scheme", SCHEMES)
    def test_gap_only_trace(self, scheme):
        a, b = run_both_engines([Gap(3.0)], scheme)
        assert_equal_results(a, b)
        assert a.total_bytes == 0
        assert a.io_seconds == 0.0
        assert a.total_seconds == pytest.approx(3.0)


class TestDegenerateGaps:
    """Zero-length, adjacent, leading and trailing gaps — every position
    that stresses the gap/drain/finalize ordering."""

    def _trace(self):
        # stream0 random -> HDD (observes high pct), stream1+2 random ->
        # SSD with a region small enough to swap mid-stream: a flush
        # backlog exists whenever the gap fires
        return (random_stream(0, seed=1)
                + random_stream(64 * MiB, seed=2)
                + random_stream(128 * MiB, seed=3))

    def _run(self, items, scheme="ssdup+"):
        a, b = run_both_engines(items, scheme, ssd_capacity=20 * REQ)
        assert_equal_results(a, b)
        return a

    @pytest.mark.parametrize("scheme", SCHEMES)
    def test_zero_length_gap_adds_no_time(self, scheme):
        items = self._trace()
        with_gap = items[:32] + [Gap(0.0)] + items[32:]
        a = self._run(items, scheme)
        g = self._run(with_gap, scheme)
        assert g.total_bytes == a.total_bytes
        assert g.total_seconds == pytest.approx(a.total_seconds, rel=1e-12)

    def test_adjacent_gaps_equal_one_merged_gap(self):
        items = self._trace()
        split = items[:32] + [Gap(1.0), Gap(2.0)] + items[32:]
        merged = items[:32] + [Gap(3.0)] + items[32:]
        a, b = self._run(split), self._run(merged)
        assert a.total_seconds == pytest.approx(b.total_seconds, rel=1e-12)
        assert a.io_seconds == pytest.approx(b.io_seconds, rel=1e-12)

    def test_leading_gap_with_empty_pipeline(self):
        items = [Gap(2.0)] + self._trace()
        a = self._run(items)
        assert a.total_seconds - a.io_seconds >= 2.0

    def test_trailing_gap_then_finalize(self):
        """A trailing gap drains the flush *backlog*; the end-of-trace
        drain then pays only for the still-active region — the bytes the
        gap already absorbed must not be charged twice."""

        base = self._run(self._trace())
        trailing = self._run(self._trace() + [Gap(30.0)])
        assert trailing.io_seconds == pytest.approx(base.io_seconds,
                                                    rel=1e-12)
        # base pays the full drain (backlog + active region) after io;
        # with the 30 s gap the backlog part lands inside the gap, so the
        # post-gap finalize is strictly cheaper than base's full drain
        base_drain = base.total_seconds - base.io_seconds
        post_gap_drain = trailing.total_seconds - trailing.io_seconds - 30.0
        assert base_drain > 0.0
        assert 0.0 <= post_gap_drain < base_drain
        assert trailing.flushes == base.flushes


class TestEndOfTraceResidualSeeks:
    def test_eq6_drain_charge_matches_analytic_cost(self):
        """The final drain must cost exactly seeks x seek_time +
        bytes / seq_bw (Eq. 6), with the residual seek count derived
        here from first principles (sorted live extents, contiguity)."""

        hdd = HDDModel()
        # stream0 -> HDD (high pct observed); stream1 -> SSD (one-stream
        # lag), fits the region, never flushed before the trace ends
        s0 = random_stream(0, seed=5)
        s1 = random_stream(64 * MiB, seed=6, file_id=0)
        trace = s0 + s1
        sim = IONodeSimulator(scheme="ssdup+", stream_len=STREAM_LEN,
                              ssd_capacity=8 * MiB)
        scores = compute_stream_scores(trace, STREAM_LEN)
        res = sim.run(trace, scores=scores)
        assert res.bytes_to_ssd == sum(r.size for r in s1)
        assert res.flushes == 1  # exactly the end-of-trace drain

        offs = np.sort(np.array([r.offset for r in s1]))
        sizes = np.full_like(offs, REQ)
        seeks = 1 + int(np.count_nonzero(offs[1:] != offs[:-1] + sizes[:-1]))
        expected = seeks * hdd.seek_time + res.bytes_to_ssd / hdd.seq_bw
        assert res.total_seconds - res.io_seconds == pytest.approx(
            expected, rel=1e-12)

    def test_blocked_writer_pays_residual_seeks(self):
        """Region far smaller than one stream: the writer blocks on the
        forced flush, whose rate already amortizes Eq. 6 seeks — engines
        must agree bit-for-bit on the blocked time."""

        s0 = random_stream(0, seed=7)
        s1 = random_stream(64 * MiB, seed=8)
        a, b = run_both_engines(s0 + s1, "ssdup+", ssd_capacity=8 * REQ)
        assert_equal_results(a, b)
        assert a.blocked_seconds > 0.0
        assert a.flushes >= 2  # forced mid-stream + end-of-trace


@pytest.mark.parametrize("scheme", ["ssdup", "ssdup+", "orangefs-bb"])
@pytest.mark.parametrize("gap_s", [0.001, 0.05, 0.4, 2.0])
def test_engines_agree_across_gap_budgets(scheme, gap_s):
    """Sweep the gap budget through the partially-drained-backlog regime
    (budget below, near, and above the drain need): both engines must
    stay bit-identical at every boundary."""

    items = (random_stream(0, seed=11) + random_stream(64 * MiB, seed=12)
             + [Gap(gap_s)] + random_stream(128 * MiB, seed=13))
    a, b = run_both_engines(items, scheme, ssd_capacity=20 * REQ)
    assert_equal_results(a, b)
    assert a.total_bytes == 3 * STREAM_LEN * REQ


class TestOversizedRequestPlainBB:
    """Regression: an oversized request hitting an EMPTY single-region
    buffer used to schedule a zero-byte FlushJob that could never
    complete (``flush_progress`` ignores ``nbytes <= 0``), wedging the
    end-of-trace drain loop forever.  The buffer must instead reject the
    request with no phantom job, and the simulator routes it to HDD."""

    def test_empty_buffer_rejects_without_phantom_job(self):
        buf = SingleRegionBuffer(MiB)
        out = buf.append(file_id=0, offset=0, size=2 * MiB)
        assert out.blocked and not out.ok
        assert buf.flush_job is None  # no zero-byte job scheduled
        assert buf.blocked_events == 1
        assert buf.flushes_completed == 0
        assert buf.drain() == []  # nothing to drain; finalize terminates

    def test_oversized_requests_complete_and_land_on_hdd(self):
        # every request exceeds the SSD: plain BB overflows all of them
        trace = [Request(offset=i * 4 * MiB, size=2 * MiB, file_id=0)
                 for i in range(4)]
        a, b = run_both_engines(trace, "orangefs-bb", ssd_capacity=MiB)
        assert_equal_results(a, b)
        assert a.bytes_to_ssd == 0
        assert a.bytes_to_hdd_direct == 4 * 2 * MiB
        assert a.flushes == 0

    def test_oversized_after_buffered_data_still_flushes(self):
        # a real job exists for the buffered prefix; the oversized
        # request overflows but must not disturb that job's accounting
        trace = ([Request(offset=i * REQ, size=REQ, file_id=0)
                  for i in range(4)]
                 + [Request(offset=64 * MiB, size=2 * MiB, file_id=1)])
        a, b = run_both_engines(trace, "orangefs-bb", ssd_capacity=MiB)
        assert_equal_results(a, b)
        assert a.bytes_to_ssd == 4 * REQ
        assert a.bytes_to_hdd_direct == 2 * MiB
        assert a.flushes >= 1

    def test_two_region_oversized_still_raises(self):
        # the two-region pipeline's contract is unchanged: a request
        # larger than a region is a configuration error
        pipe = TwoRegionPipeline(MiB)
        with pytest.raises(ValueError, match="exceeds region capacity"):
            for i in range(64):
                pipe.append(file_id=0, offset=i * 4 * MiB, size=2 * MiB)
