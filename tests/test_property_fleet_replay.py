"""Property test: engine and index-backend equivalence under *sharded*
fleet replay with adaptive thresholds — the configuration where the
8-16 node anomaly lives and where the single-node equivalence tests
don't reach (per-shard threshold state, ragged shard tails, per-shard
flush backlogs, gap replication).

Every drawn fleet must produce bit-identical per-node SimResults under:

    engine="batched"      vs  engine="per-request"
    index_backend="numpy" vs  index_backend="avl"
"""

import dataclasses

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in CI without hypothesis
    from _hypothesis_fallback import given, settings, st

    HAVE_HYPOTHESIS = False

from repro.core import FleetSimulator, Gap, ior, mixed, relabel
from repro.core.workloads import KiB

STREAM_LEN = 16
REQUEST = 64 * KiB
PATTERNS = ("segmented-contiguous", "segmented-random", "strided")


def build_fleet_trace(app_specs, burst, with_gap):
    apps = []
    for i, (pattern, nreq, seed) in enumerate(app_specs):
        apps.append(relabel(
            ior(pattern, 4, total_bytes=nreq * REQUEST,
                request_size=REQUEST, seed=seed),
            app_id=i, file_id=i))
    items = list(mixed(*apps, burst_requests=burst).trace)
    if with_gap:
        items.insert(len(items) // 2, Gap(0.5))
    return items


def assert_nodes_identical(a, b, label):
    assert a.num_nodes == b.num_nodes
    for i, (ra, rb) in enumerate(zip(a.node_results, b.node_results)):
        for f in dataclasses.fields(ra):
            va, vb = getattr(ra, f.name), getattr(rb, f.name)
            assert va == vb, (
                f"{label}: node[{i}].{f.name} diverged: {va!r} != {vb!r}"
            )


app_spec = st.tuples(
    st.sampled_from(PATTERNS),
    st.integers(min_value=24, max_value=80),   # requests per app
    st.integers(min_value=0, max_value=10_000),
)


@settings(max_examples=30, deadline=None)
@given(
    specs=st.lists(app_spec, min_size=1, max_size=3),
    burst=st.sampled_from([None, 8, 32]),
    with_gap=st.booleans(),
    num_nodes=st.sampled_from([2, 3, 5]),
    policy=st.sampled_from(["round-robin-app", "hash-file", "range-offset"]),
    scheme=st.sampled_from(["ssdup+", "ssdup", "orangefs-bb"]),
    cap_divisor=st.sampled_from([2, 4, 8]),
)
def test_engines_and_index_backends_agree_under_sharding(
        specs, burst, with_gap, num_nodes, policy, scheme, cap_divisor):
    items = build_fleet_trace(specs, burst, with_gap)
    total = sum(i.size for i in items if not isinstance(i, Gap))
    # small per-node capacity forces region swaps / blocked writers /
    # forced flushes on most draws; region = capacity/2 must hold a request
    capacity = max(total // cap_divisor, 4 * REQUEST)

    def run(**node_kwargs):
        return FleetSimulator(
            num_nodes=num_nodes, scheme=scheme, policy=policy,
            stream_len=STREAM_LEN, ssd_capacity=capacity, **node_kwargs,
        ).run(items)

    reference = run(engine="batched", index_backend="numpy")
    oracle = run(engine="per-request", index_backend="numpy")
    assert_nodes_identical(reference, oracle, "batched vs per-request")

    avl = run(engine="batched", index_backend="avl")
    assert_nodes_identical(reference, avl, "numpy vs avl index")

    both = run(engine="per-request", index_backend="avl")
    assert_nodes_identical(reference, both, "batched/numpy vs per-request/avl")


@settings(max_examples=10, deadline=None)
@given(
    specs=st.lists(app_spec, min_size=1, max_size=2),
    num_nodes=st.sampled_from([2, 4]),
)
def test_fleet_scope_warmup_keeps_engines_identical(specs, num_nodes):
    """threshold_scope='fleet' (warm global PercentList) must not break
    engine equivalence — warmup only changes the starting threshold."""

    items = build_fleet_trace(specs, burst=16, with_gap=False)
    total = sum(i.size for i in items if not isinstance(i, Gap))

    def run(engine):
        return FleetSimulator(
            num_nodes=num_nodes, scheme="ssdup+", policy="range-offset",
            stream_len=STREAM_LEN, ssd_capacity=max(total // 4, 4 * REQUEST),
            threshold_scope="fleet", engine=engine,
        ).run(items)

    assert_nodes_identical(run("batched"), run("per-request"),
                           "fleet-scope warmup")
