"""The 8-16 node SSDUP+ shortfall, pinned to a minimal committed fixture.

``tests/golden/anomaly_16n_straggler.json`` holds the literal straggler
shard (node 7 of 16, range-offset) of the fleet benchmark's 2 GiB mix.
The mechanism (experiments/ANOMALY.md): the last stream's percentage
(0.512) sits just above the default traffic-aware flush gate (0.5), so
the flusher runs concurrently for the stream's whole wall — but that
stream is itself routed to the *HDD* (one-stream-lag threshold 0.425),
so the "high percentage => slow tier idle" premise is violated and the
entire foreground device time is inflated 4x (Eq. 7, phi=2).  Raising
the gate to 0.75 defers the flush and removes the inflation without
changing a single routing decision.
"""

import json
import pathlib

import pytest

from repro.core import IONodeSimulator, TraceBatch, compute_stream_scores
from repro.core.random_factor import Request
from repro.testing.golden import GOLDEN_DIR, diff_sim, sim_result_to_dict

FIXTURE = GOLDEN_DIR / "anomaly_16n_straggler.json"


@pytest.fixture(scope="module")
def payload():
    with open(FIXTURE) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def shard(payload):
    t = payload["trace"]
    return TraceBatch.from_requests([
        Request(offset=o, size=s, file_id=f, app_id=a)
        for o, s, f, a in zip(t["offsets"], t["sizes"],
                              t["file_ids"], t["app_ids"])
    ])


def _replay(payload, shard, scheme, **kwargs):
    node = IONodeSimulator(scheme=scheme,
                           ssd_capacity=payload["ssd_capacity"], **kwargs)
    scores = compute_stream_scores(shard) if scheme != "orangefs" else None
    result = node.run(shard, scores=scores)
    decisions = None
    if node.redirector is not None:
        decisions = [[float(p), float(t), d.name.lower()]
                     for p, t, d in node.redirector.decisions]
    return result, decisions


@pytest.mark.parametrize("key,scheme,kwargs", [
    ("orangefs", "orangefs", {}),
    ("ssdup+_gate0.5", "ssdup+", {}),
    ("ssdup+_gate0.75", "ssdup+", {"flush_gate": 0.75}),
    ("ssdup+_gate-device", "ssdup+", {"flush_gate": "device"}),
])
def test_replay_matches_fixture(payload, shard, key, scheme, kwargs):
    result, decisions = _replay(payload, shard, scheme, **kwargs)
    expected = payload["expected"][key]
    diffs = diff_sim(expected["result"], sim_result_to_dict(result))
    assert diffs == [], "\n".join(diffs)
    if expected.get("decisions") is not None:
        assert decisions == expected["decisions"]


def test_shortfall_reproduces(payload, shard):
    """SSDUP+ at the default gate loses to no-buffer OrangeFS here."""

    plus, _ = _replay(payload, shard, "ssdup+")
    base, _ = _replay(payload, shard, "orangefs")
    assert plus.io_seconds > base.io_seconds * 1.5


def test_gate_raise_removes_inflation_without_rerouting(payload, shard):
    """flush_gate=0.75 fixes the shard with identical routing decisions —
    the shortfall is pure flush-gate self-interference, not a threshold
    or routing defect."""

    slow, slow_dec = _replay(payload, shard, "ssdup+")
    fast, fast_dec = _replay(payload, shard, "ssdup+", flush_gate=0.75)
    base, _ = _replay(payload, shard, "orangefs")
    assert slow_dec == fast_dec
    assert fast.bytes_to_ssd == slow.bytes_to_ssd
    assert fast.io_seconds < base.io_seconds < slow.io_seconds


def test_device_gate_fixes_shard_without_tuning(payload, shard):
    """Flush-gate v2 (``flush_gate="device"``): pausing the flusher
    whenever the foreground stream writes the HDD removes the anomaly's
    self-interference *without a tuned percentage cutoff* — the device
    gate matches the hand-tuned gate=0.75 result exactly here, because
    both defer the flush past the HDD-bound final stream.  Routing is
    untouched (the gate only times the flusher)."""

    slow, slow_dec = _replay(payload, shard, "ssdup+")
    dev, dev_dec = _replay(payload, shard, "ssdup+", flush_gate="device")
    tuned, _ = _replay(payload, shard, "ssdup+", flush_gate=0.75)
    base, _ = _replay(payload, shard, "orangefs")
    assert dev_dec == slow_dec
    assert dev.bytes_to_ssd == slow.bytes_to_ssd
    assert dev.io_seconds < base.io_seconds < slow.io_seconds
    assert dev.io_seconds == tuned.io_seconds


def test_offending_stream_sits_between_gate_and_threshold(payload):
    """The mechanism's signature: the last stream's percentage opens the
    0.5 flush gate, yet the stream itself is on the HDD — the one-stream
    routing lag sent it there even though its pct exceeds the threshold
    in effect (the *next* stream would have gone to SSD)."""

    decisions = payload["expected"]["ssdup+_gate0.5"]["decisions"]
    pct, thr, device = decisions[-1]
    assert device == "hdd"
    assert pct >= 0.5            # opens the traffic-aware flush gate
    assert pct > thr             # would have routed to SSD without lag
