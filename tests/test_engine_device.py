"""Device-engine parity: ``engine="device"`` vs the numpy oracle matrix.

The device engine (:mod:`repro.core.engine_device`) is stream-granular
where the numpy engines are request-granular, so it carries a documented
per-field accuracy contract instead of bit-exactness.  Every golden
fixture embeds the tolerance table it was verified against
(``device_tolerance``, written by ``repro.testing.golden --write``);
these tests replay the FULL committed matrix — 4 schemes x 2 workloads
x 2 policies x 4 nodes — plus the ``anomaly_16n_straggler`` shard under
``engine="device"`` and assert against the *embedded* contract, so a
tolerance loosening must show up as a reviewable fixture diff, never as
a silent test-side constant bump.

``FleetProgram`` (one jitted sweep over the whole scheme x node lane
matrix) must agree with the per-node ``engine="device"`` dispatch it
batches, and with the stored snapshots under the same tolerances.

Requires jax — the device engine has no host fallback by design (the
numpy engines ARE the fallback, behind the same ``engine=`` switch).
"""

import json

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import FleetProgram, IONodeSimulator, TraceBatch, compute_stream_scores
from repro.core.engine_device import DEVICE_TOLERANCES
from repro.core.random_factor import Request
from repro.testing import golden
from repro.testing.golden import (
    GOLDEN_DIR,
    check_fixture,
    diff_sim,
    fleet_result_to_dict,
    load_fixture,
    replay_fixture,
    sim_result_to_dict,
)
from repro.testing.traces import golden_trace

FIXTURE_FILES = sorted(GOLDEN_DIR.glob("*__*.json"))


@pytest.fixture(scope="module")
def payloads():
    return {p.name: load_fixture(p) for p in FIXTURE_FILES}


def test_every_fixture_embeds_the_tolerance_contract(payloads):
    """Fixtures must carry the table the device replay is judged by."""

    for name, payload in payloads.items():
        tol = payload.get("device_tolerance")
        assert tol, f"{name}: missing device_tolerance metadata"
        assert set(tol) == set(DEVICE_TOLERANCES), name
        for field, (rtol, atol) in DEVICE_TOLERANCES.items():
            assert tuple(tol[field]) == (rtol, atol), (
                f"{name}: embedded tolerance for {field} drifted from "
                "DEVICE_TOLERANCES — regenerate fixtures with --write")


@pytest.mark.parametrize("path", FIXTURE_FILES, ids=lambda p: p.stem)
def test_device_replay_matches_fixture(path, payloads):
    """The whole committed matrix, replayed on device, within contract."""

    payload = payloads[path.name]
    fr = replay_fixture(payload, engine="device")
    diffs = check_fixture(payload, fr,
                          tolerances=payload["device_tolerance"])
    assert diffs == [], f"{path.name} (device):\n" + "\n".join(diffs)


@pytest.mark.parametrize("path", FIXTURE_FILES, ids=lambda p: p.stem)
def test_device_routing_fields_are_exact(path, payloads):
    """Routing and byte accounting for the non-BB schemes is documented
    as timing-independent and EXACT (approximation #5); holding the
    device engine to that stronger claim catches regressions the
    tolerance tiers would mask."""

    payload = payloads[path.name]
    if payload["key"]["scheme"] == "orangefs-bb":
        pytest.skip("plain-BB byte splits are timing-coupled by contract")
    fr = replay_fixture(payload, engine="device")
    actual = fleet_result_to_dict(fr)
    for i, (e, a) in enumerate(zip(payload["result"]["nodes"],
                                   actual["nodes"])):
        for field in ("total_bytes", "bytes_to_ssd", "bytes_to_hdd_direct",
                      "flushes", "peak_ssd_occupancy"):
            assert e[field] == a[field], (
                f"node[{i}].{field}: expected {e[field]}, got {a[field]}")


# -- anomaly fixture ---------------------------------------------------

ANOMALY = GOLDEN_DIR / "anomaly_16n_straggler.json"


@pytest.fixture(scope="module")
def anomaly_payload():
    with open(ANOMALY) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def anomaly_shard(anomaly_payload):
    t = anomaly_payload["trace"]
    return TraceBatch.from_requests([
        Request(offset=o, size=s, file_id=f, app_id=a)
        for o, s, f, a in zip(t["offsets"], t["sizes"],
                              t["file_ids"], t["app_ids"])
    ])


@pytest.mark.parametrize("key,scheme,kwargs", [
    ("orangefs", "orangefs", {}),
    ("ssdup+_gate0.5", "ssdup+", {}),
    ("ssdup+_gate0.75", "ssdup+", {"flush_gate": 0.75}),
])
def test_device_replays_anomaly_fixture(anomaly_payload, anomaly_shard,
                                        key, scheme, kwargs):
    """The straggler shard — the repo's root-caused 8-16 node shortfall —
    must reproduce on device, including the flush-gate sensitivity."""

    node = IONodeSimulator(scheme=scheme, engine="device",
                           ssd_capacity=anomaly_payload["ssd_capacity"],
                           **kwargs)
    scores = (compute_stream_scores(anomaly_shard)
              if scheme != "orangefs" else None)
    result = node.run(anomaly_shard, scores=scores)
    expected = anomaly_payload["expected"][key]["result"]
    diffs = diff_sim(expected, sim_result_to_dict(result),
                     tolerances=anomaly_payload["device_tolerance"])
    assert diffs == [], f"{key} (device):\n" + "\n".join(diffs)


def test_device_reproduces_gate_shortfall(anomaly_payload, anomaly_shard):
    """The device clocks must preserve the anomaly's ORDERING, not just
    its field values: ssdup+ at gate 0.5 loses to plain OrangeFS, and
    raising the gate to 0.75 recovers it."""

    def run(scheme, **kw):
        node = IONodeSimulator(scheme=scheme, engine="device",
                               ssd_capacity=anomaly_payload["ssd_capacity"],
                               **kw)
        scores = (compute_stream_scores(anomaly_shard)
                  if scheme != "orangefs" else None)
        return node.run(anomaly_shard, scores=scores)

    base = run("orangefs")
    plus = run("ssdup+")
    fixed = run("ssdup+", flush_gate=0.75)
    assert plus.io_seconds > base.io_seconds * 1.5
    assert fixed.io_seconds < base.io_seconds


# -- FleetProgram ------------------------------------------------------


def test_fleet_program_matches_fixture_matrix(payloads):
    """One jitted sweep (4 schemes x 4 nodes = 16 lanes) must land every
    scheme's FleetResult inside the same embedded contract the per-node
    device replays satisfy."""

    workload, policy = "mixed-burst", "range-offset"
    batch = golden_trace(workload)
    cap = golden._node_capacity(batch.total_bytes)
    prog = FleetProgram(num_nodes=golden.FIXTURE_NODES,
                        schemes=golden.FIXTURE_SCHEMES,
                        policy=policy, ssd_capacity=cap)
    results = prog.run(batch)
    assert set(results) == set(golden.FIXTURE_SCHEMES)
    for scheme, fr in results.items():
        payload = payloads[golden.fixture_name(scheme, workload, policy)]
        diffs = check_fixture(payload, fr,
                              tolerances=payload["device_tolerance"])
        assert diffs == [], f"FleetProgram {scheme}:\n" + "\n".join(diffs)


def test_fleet_program_equals_per_lane_device_dispatch():
    """Batching lanes must not change a single number: the fused sweep
    and N independent ``engine="device"`` runs share one code path, so
    they agree to f64 bit-level on every field."""

    from repro.core import FleetSimulator

    batch = golden_trace("strided-gaps")
    cap = golden._node_capacity(batch.total_bytes)
    prog = FleetProgram(num_nodes=golden.FIXTURE_NODES,
                        schemes=("ssdup", "ssdup+"),
                        policy="round-robin-app", ssd_capacity=cap)
    swept = prog.run(batch)
    for scheme in ("ssdup", "ssdup+"):
        loop = FleetSimulator(num_nodes=golden.FIXTURE_NODES, scheme=scheme,
                              policy="round-robin-app", ssd_capacity=cap,
                              engine="device").run(batch)
        a = fleet_result_to_dict(swept[scheme])
        b = fleet_result_to_dict(loop)
        assert a == b, f"{scheme}: fused sweep != per-lane device replay"


def test_plain_bb_cross_stream_merge_routing():
    """Tiled workloads (IOR strided) interleave streams into contiguous
    extents, so a flushed region's sorted union has far fewer seeks than
    the per-stream sum — without the tape's cross-merge correction the
    device underestimates the flush rate ~2x and plain-BB overflow
    routing diverges by whole streams.  Routing must match the oracle
    exactly here, and the clocks must stay inside the contract."""

    from repro.core import ior

    w = ior("strided", 64, total_bytes=1 << 28)
    batch = TraceBatch.from_items(w.trace)
    cap = batch.total_bytes // 2
    oracle = IONodeSimulator(scheme="orangefs-bb", ssd_capacity=cap,
                             engine="batched").run(batch)
    dev = IONodeSimulator(scheme="orangefs-bb", ssd_capacity=cap,
                          engine="device").run(batch)
    assert dev.bytes_to_ssd == oracle.bytes_to_ssd
    assert dev.bytes_to_hdd_direct == oracle.bytes_to_hdd_direct
    assert dev.flushes == oracle.flushes
    assert dev.peak_ssd_occupancy == oracle.peak_ssd_occupancy
    rtol, _ = DEVICE_TOLERANCES["io_seconds"]
    assert abs(dev.io_seconds - oracle.io_seconds) <= rtol * oracle.io_seconds


# -- tolerance-tier mechanics ------------------------------------------


def test_tolerance_tiers_gate_comparison(payloads):
    """The tiered differ: within-tier drift passes, beyond-tier fails,
    and a (0, 0) tier stays bit-exact."""

    import copy

    payload = next(iter(payloads.values()))
    tol = payload["device_tolerance"]
    base = payload["result"]

    drifted = copy.deepcopy(base)
    drifted["nodes"][0]["io_seconds"] *= 1.02  # inside the 5% tier
    assert golden.diff_fleet(base, drifted, tolerances=tol) == []

    broken = copy.deepcopy(base)
    broken["nodes"][0]["io_seconds"] *= 1.2    # far outside
    assert golden.diff_fleet(base, broken, tolerances=tol)

    exact = copy.deepcopy(base)
    exact["nodes"][0]["total_bytes"] += 1      # (0, 0) tier: any drift trips
    assert golden.diff_fleet(base, exact, tolerances=tol)

    # without tolerances the drifted copy is still a divergence
    assert golden.diff_fleet(base, drifted)
