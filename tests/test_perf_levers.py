"""Perf levers must preserve semantics (baseline equivalence tests).

Every hillclimb lever (DESIGN.md §6b) is either bit-exact or boundedly
lossy; these tests pin that down so optimized configs are safe to deploy.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.launch.steps import make_train_step

pytestmark = pytest.mark.slow  # multi-second per-arch device runs
from repro.models import get_model
from repro.optim import AdamWConfig, init_state

B, S = 2, 16


def batch_for(cfg, key):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    b = {"tokens": toks, "labels": toks}
    if cfg.family == "vlm":
        b["patch_embeds"] = jnp.ones((B, cfg.n_patches, cfg.d_model),
                                     jnp.bfloat16) * 0.01
    return b


def loss_of(cfg, params, batch):
    return float(get_model(cfg).loss_fn(params, batch))


class TestExactLevers:
    """Levers that must be bit-exact (pure scheduling/layout changes)."""

    @pytest.mark.parametrize("arch,overrides", [
        ("falcon-mamba-7b", dict(mamba_fused_proj=True)),
        ("falcon-mamba-7b", dict(scan_chunk=4)),
        ("falcon-mamba-7b", dict(scan_chunk=64)),
        ("falcon-mamba-7b", dict(ssm_impl="pallas")),
        ("zamba2-2.7b", dict(scan_chunk=4)),
        ("grok-1-314b", dict(moe_group_size=8)),
    ])
    def test_bit_exact(self, arch, overrides):
        cfg0 = get_smoke_config(arch)
        cfg1 = dataclasses.replace(cfg0, **overrides)
        key = jax.random.PRNGKey(0)
        params = get_model(cfg0).init_params(key)
        batch = batch_for(cfg0, jax.random.PRNGKey(1))
        l0 = loss_of(cfg0, params, batch)
        l1 = loss_of(cfg1, params, batch)
        assert l0 == pytest.approx(l1, abs=2e-3), (arch, overrides)

    def test_microbatch_grad_equivalence(self):
        cfg0 = get_smoke_config("qwen3-1.7b")
        cfg1 = dataclasses.replace(cfg0, microbatch=1)
        key = jax.random.PRNGKey(0)
        outs = []
        for cfg in (cfg0, cfg1):
            m = get_model(cfg)
            params = m.init_params(key)
            st = init_state(params)
            step = make_train_step(m, AdamWConfig(lr=1e-3))
            _, _, metrics = step(params, st, batch_for(cfg, jax.random.PRNGKey(1)))
            outs.append((float(metrics["loss"]), float(metrics["grad_norm"])))
        assert outs[0][0] == pytest.approx(outs[1][0], abs=1e-4)
        assert outs[0][1] == pytest.approx(outs[1][1], rel=3e-3)


class TestLossyLevers:
    """Quantization levers: bounded deviation, finite outputs."""

    def test_bf16_softmax_close(self):
        cfg0 = get_smoke_config("qwen3-1.7b")
        cfg1 = dataclasses.replace(cfg0, softmax_dtype="bfloat16")
        params = get_model(cfg0).init_params(jax.random.PRNGKey(0))
        batch = batch_for(cfg0, jax.random.PRNGKey(1))
        l0, l1 = loss_of(cfg0, params, batch), loss_of(cfg1, params, batch)
        assert abs(l0 - l1) < 0.05

    def test_bf16_moe_dispatch_close(self):
        cfg0 = get_smoke_config("moonshot-v1-16b-a3b")
        cfg1 = dataclasses.replace(cfg0, moe_dispatch_dtype="bfloat16")
        params = get_model(cfg0).init_params(jax.random.PRNGKey(0))
        batch = batch_for(cfg0, jax.random.PRNGKey(1))
        assert abs(loss_of(cfg0, params, batch)
                   - loss_of(cfg1, params, batch)) < 0.05

    def test_fp8_param_storage_finite_and_sane(self):
        cfg0 = get_smoke_config("grok-1-314b")
        cfg1 = dataclasses.replace(cfg0, param_dtype="float8_e4m3fn",
                                   matmul_weight_dtype="bfloat16")
        m1 = get_model(cfg1)
        params = m1.init_params(jax.random.PRNGKey(0))
        # init respects the storage dtype
        leaf = jax.tree.leaves(params)[0]
        batch = batch_for(cfg1, jax.random.PRNGKey(1))
        l1 = m1.loss_fn(params, batch)
        assert bool(jnp.isfinite(l1))

    def test_embed_onehot_exact(self):
        cfg0 = get_smoke_config("qwen3-1.7b")
        cfg1 = dataclasses.replace(cfg0, embed_onehot=True)
        params = get_model(cfg0).init_params(jax.random.PRNGKey(0))
        batch = batch_for(cfg0, jax.random.PRNGKey(1))
        assert loss_of(cfg0, params, batch) == pytest.approx(
            loss_of(cfg1, params, batch), abs=1e-3)
