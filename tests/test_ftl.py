"""Columnar FTL storage backend: geometry, GC, write amplification.

Three layers of guarantees:

* model-level — page/ledger conservation, trim semantics, GC-epoch
  batch-size independence (charging the same traffic in any batch split
  is bit-identical), and the paper's §2.5 claim as *properties*:
  log-structured traffic never amplifies worse than in-place traffic,
  and GC never reclaims a page holding the latest version of an extent;
* engine-level — ``ssd="ftl"`` replays bit-identically between the
  per-request oracle and the batched engine, and within the documented
  tolerance on the device engine;
* plumbing — ``ssd=`` spec resolution, per-scheme/per-node cloning,
  degraded-mode rescaling, and config fingerprints.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # deterministic no-shrink fallback, same API surface
    from _hypothesis_fallback import given, settings, st

from repro.analysis import SanitizerError, sanitizing
from repro.core import (
    IONodeSimulator,
    FTLModel,
    SSDModel,
    StorageModel,
    clone_storage,
    make_storage_model,
    run_schemes,
)
from repro.testing.traces import golden_trace

KiB = 1024
MiB = 1 << 20

# Small geometry so a few hundred requests exercise wraparound and GC:
# 1 MiB logical = 4 blocks of 64 pages; watermarks low enough that the
# overprovision pool (10 blocks) actually cycles.
SMALL = dict(
    logical_bytes=1 * MiB,
    page_size=4 * KiB,
    pages_per_block=64,
    n_channels=4,
    gc_low_blocks=2,
    gc_high_blocks=4,
)


def small_ftl(**over) -> FTLModel:
    return FTLModel(**{**SMALL, **over})


# -- construction and spec resolution ----------------------------------


class TestConstruction:
    def test_default_nominal_bandwidth_matches_constant_model(self):
        """t_prog defaults so the GC-free striped bandwidth equals the
        constant model's 380 MB/s — same workload, same nominal rate."""

        ftl = FTLModel(logical_bytes=1 * MiB)
        assert ftl.write_bw == pytest.approx(SSDModel().write_bw)

    @pytest.mark.parametrize("bad", [
        dict(logical_bytes=0),
        dict(page_size=0),
        dict(pages_per_block=0),
        dict(n_channels=0),
        dict(overprovision=-0.1),
        dict(gc_low_blocks=1),                      # < 2
        dict(gc_low_blocks=4, gc_high_blocks=4),    # low >= high
    ])
    def test_bad_geometry_rejected(self, bad):
        with pytest.raises(ValueError):
            small_ftl(**bad)

    def test_make_storage_model_resolves_specs(self):
        assert isinstance(make_storage_model(None), SSDModel)
        assert isinstance(make_storage_model("constant"), SSDModel)
        ftl = make_storage_model("ftl", logical_bytes=1 * MiB)
        assert isinstance(ftl, FTLModel)
        assert make_storage_model(ftl) is ftl
        with pytest.raises(ValueError):
            make_storage_model("ftl")  # no capacity to size the space
        with pytest.raises(ValueError):
            make_storage_model("nvme-zns")
        with pytest.raises(TypeError):
            make_storage_model(42)

    def test_both_backends_satisfy_protocol(self):
        assert isinstance(SSDModel(), StorageModel)
        assert isinstance(small_ftl(), StorageModel)

    def test_clone_storage_isolates_stateful_state(self):
        ftl = small_ftl()
        ftl.charge_write(np.array([0]), np.array([8 * KiB]))
        twin = clone_storage(ftl)
        assert twin is not ftl
        assert twin.host_bytes == 0  # fresh state, same geometry
        assert twin.config_fingerprint() == ftl.config_fingerprint()
        const = SSDModel()
        assert clone_storage(const) is const  # immutable: shared
        assert clone_storage("ftl") == "ftl"
        assert clone_storage(None) is None


# -- charge_write contract ---------------------------------------------


class TestChargeWrite:
    def test_requires_offsets(self):
        with pytest.raises(ValueError, match="offsets"):
            small_ftl().charge_write(None, np.array([4 * KiB]))

    def test_rejects_out_of_range_lba(self):
        ftl = small_ftl()
        with pytest.raises(ValueError):
            ftl.charge_write(np.array([1 * MiB]), np.array([4 * KiB]))
        with pytest.raises(ValueError):
            ftl.charge_write(np.array([-4096]), np.array([4 * KiB]))

    def test_rejects_length_mismatch(self):
        with pytest.raises(ValueError):
            small_ftl().charge_write(np.array([0, 4096]), np.array([4096]))

    def test_gc_free_write_costs_pages_times_t_page(self):
        ftl = small_ftl()
        times = ftl.charge_write(
            np.array([0, 16 * KiB]), np.array([16 * KiB, 6 * KiB])
        )
        assert times[0] == pytest.approx(4 * ftl.t_page)
        assert times[1] == pytest.approx(2 * ftl.t_page)  # ceil(6/4)

    def test_zero_size_costs_nothing(self):
        ftl = small_ftl()
        times = ftl.charge_write(np.array([0]), np.array([0]))
        assert times[0] == 0.0
        assert ftl.host_pages == 0

    def test_batch_split_independence(self):
        """Charging one arrival sequence in any batch split is
        bit-identical — times, mapping table, and GC history."""

        rng = np.random.default_rng(7)
        n = 500
        pages = 1 * MiB // (4 * KiB)
        offsets = rng.integers(0, pages, n) * 4 * KiB
        sizes = rng.integers(1, 5, n) * 4 * KiB
        offsets = np.minimum(offsets, 1 * MiB - sizes).astype(np.int64)
        sizes = sizes.astype(np.int64)

        whole = small_ftl()
        t_whole = whole.charge_write(offsets, sizes)
        split = small_ftl()
        cuts = sorted(set(rng.integers(1, n, 9).tolist()) | {0, n})
        t_split = np.concatenate([
            split.charge_write(offsets[a:b], sizes[a:b])
            for a, b in zip(cuts[:-1], cuts[1:])
        ])
        np.testing.assert_array_equal(t_whole, t_split)
        np.testing.assert_array_equal(whole._l2p, split._l2p)
        assert whole.stats() == split.stats()


# -- trim, GC, and write amplification ---------------------------------


class TestGarbageCollection:
    def test_sequential_log_with_trim_stays_wa_one(self):
        """The log-store pattern (§2.5): append sequentially, trim the
        whole region when it dies.  GC never has to move a byte."""

        ftl = small_ftl()
        for _round in range(6):  # 6 MiB through a 1 MiB space
            head = 0
            while head < 1 * MiB:
                ftl.charge_write(np.array([head]), np.array([16 * KiB]))
                head += 16 * KiB
            ftl.trim(0, 1 * MiB)
        assert ftl.wa == 1.0
        assert ftl.reloc_pages == 0

    def test_random_overwrite_amplifies(self):
        """In-place random overwrites at high occupancy force GC to
        relocate still-valid pages: WA > 1 and erases happen."""

        ftl = small_ftl()
        rng = np.random.default_rng(3)
        pages = 1 * MiB // (4 * KiB)
        for _ in range(8):
            offs = rng.permutation(pages).astype(np.int64) * 4 * KiB
            ftl.charge_write(offs, np.full(pages, 4 * KiB, dtype=np.int64))
        assert ftl.wa > 1.0
        assert ftl.gc_runs > 0
        assert ftl.erases > 0

    def test_gc_time_charged_to_triggering_request(self):
        """A request that trips the watermark pays the reclaim time —
        total charged seconds exceed the GC-free cost."""

        ftl = small_ftl()
        rng = np.random.default_rng(5)
        pages = 1 * MiB // (4 * KiB)
        total = 0.0
        for _ in range(8):
            offs = rng.permutation(pages).astype(np.int64) * 4 * KiB
            total += float(ftl.charge_write(
                offs, np.full(pages, 4 * KiB, dtype=np.int64)
            ).sum())
        gc_free = 8 * pages * ftl.t_page
        assert total > gc_free

    def test_trim_only_drops_fully_covered_pages(self):
        ftl = small_ftl()
        ftl.charge_write(np.array([0]), np.array([8 * KiB]))  # pages 0,1
        ftl.trim(2 * KiB, 4 * KiB)  # straddles, covers no whole page
        assert ftl.live_pages == 2
        ftl.trim(0, 8 * KiB)
        assert ftl.live_pages == 0

    def test_degraded_slows_in_place(self):
        ftl = small_ftl()
        t0 = ftl.t_page
        assert ftl.degraded(0.5) is ftl  # identity preserved
        assert ftl.t_page == pytest.approx(2 * t0)
        with pytest.raises(ValueError):
            ftl.degraded(0.0)

    def test_sanitize_check_passes_after_heavy_churn(self):
        ftl = small_ftl()
        rng = np.random.default_rng(11)
        pages = 1 * MiB // (4 * KiB)
        for _ in range(4):
            offs = rng.permutation(pages).astype(np.int64) * 4 * KiB
            ftl.charge_write(offs, np.full(pages, 4 * KiB, dtype=np.int64))
            ftl.trim(0, 256 * KiB)
        with sanitizing():
            ftl.sanitize_check()

    def test_sanitize_check_catches_seeded_ledger_bug(self):
        ftl = small_ftl()
        ftl.charge_write(np.array([0]), np.array([64 * KiB]))
        ftl._valid_total += 1  # seeded corruption
        with sanitizing(), pytest.raises(SanitizerError):
            ftl.sanitize_check()


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_property_log_structured_wa_never_worse_than_inplace(seed):
    """Paper §2.5: for identical host traffic, writing it as a
    sequential log (with whole-region trims on wrap) never amplifies
    worse than writing it in place."""

    rng = np.random.default_rng(seed)
    n = 300
    pages = 1 * MiB // (4 * KiB)
    sizes = (rng.integers(1, 5, n) * 4 * KiB).astype(np.int64)
    offsets = (rng.integers(0, pages, n) * 4 * KiB).astype(np.int64)
    offsets = np.minimum(offsets, 1 * MiB - sizes)

    inplace = small_ftl()
    inplace.charge_write(offsets, sizes)

    log = small_ftl()
    head = 0
    for s in sizes:
        if head + int(s) > 1 * MiB:
            log.trim(0, 1 * MiB)
            head = 0
        log.charge_write(
            np.array([head], dtype=np.int64), np.array([s], dtype=np.int64)
        )
        head += int(s)
    assert log.wa <= inplace.wa + 1e-12
    assert log.wa == 1.0  # appends + whole-region trims never relocate


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_property_gc_never_reclaims_latest_version(seed):
    """Every logical page ever written still round-trips through the
    mapping tables after arbitrary churn: GC may move the latest
    version, never lose it."""

    rng = np.random.default_rng(seed)
    ftl = small_ftl()
    pages = 1 * MiB // (4 * KiB)
    written = set()
    for _ in range(6):
        k = int(rng.integers(50, 200))
        offs = (rng.integers(0, pages, k) * 4 * KiB).astype(np.int64)
        ftl.charge_write(offs, np.full(k, 4 * KiB, dtype=np.int64))
        written.update((offs // (4 * KiB)).tolist())
    lpns = np.array(sorted(written), dtype=np.int64)
    phys = ftl._l2p[lpns]
    assert (phys >= 0).all()  # still mapped
    np.testing.assert_array_equal(ftl._p2l[phys], lpns)  # and consistent
    with sanitizing():
        ftl.sanitize_check()


# -- engine threading --------------------------------------------------


SCHEMES = ("orangefs", "orangefs-bb", "ssdup", "ssdup+")


class TestEngineParity:
    @pytest.mark.parametrize("workload", ("mixed-burst", "strided-gaps"))
    def test_per_request_matches_batched_bit_exact(self, workload):
        trace = golden_trace(workload)
        for scheme in SCHEMES:
            kw = dict(scheme=scheme, ssd_capacity=4 * MiB, ssd="ftl")
            a = IONodeSimulator(engine="per-request", **kw).run(trace)
            b = IONodeSimulator(engine="batched", **kw).run(trace)
            assert a == b, scheme

    def test_constant_spec_matches_default_bit_exact(self):
        trace = golden_trace("mixed-burst")
        for scheme in SCHEMES:
            a = IONodeSimulator(
                scheme=scheme, ssd_capacity=4 * MiB, ssd="constant"
            ).run(trace)
            b = IONodeSimulator(scheme=scheme, ssd_capacity=4 * MiB).run(trace)
            assert a == b, scheme

    def test_device_engine_within_tolerance(self):
        pytest.importorskip("jax")
        trace = golden_trace("mixed-burst")
        for scheme in ("ssdup", "ssdup+"):
            kw = dict(scheme=scheme, ssd_capacity=32 * MiB, ssd="ftl")
            ref = IONodeSimulator(engine="batched", **kw).run(trace)
            dev = IONodeSimulator(engine="device", **kw).run(trace)
            assert dev.io_seconds == pytest.approx(
                ref.io_seconds, rel=0.05
            ), scheme

    def test_run_schemes_keeps_models_independent(self):
        """A shared ``ssd="ftl"`` spec across a scheme sweep must not
        leak one scheme's mapping state into the next."""

        trace = golden_trace("mixed-burst")
        together = run_schemes(trace, ssd_capacity=4 * MiB, ssd="ftl")
        for scheme, res in together.items():
            alone = IONodeSimulator(
                scheme=scheme, ssd_capacity=4 * MiB, ssd="ftl"
            ).run(trace)
            assert res == alone, scheme


class TestFlushGateDevice:
    def test_invalid_gate_string_rejected(self):
        with pytest.raises(ValueError, match="flush_gate"):
            IONodeSimulator(scheme="ssdup+", flush_gate="adaptive")

    @pytest.mark.parametrize("workload", ("mixed-burst", "strided-gaps"))
    def test_per_request_matches_batched(self, workload):
        trace = golden_trace(workload)
        kw = dict(scheme="ssdup+", ssd_capacity=4 * MiB, flush_gate="device")
        a = IONodeSimulator(engine="per-request", **kw).run(trace)
        b = IONodeSimulator(engine="batched", **kw).run(trace)
        assert a == b

    def test_routing_untouched_by_gate_scheme(self):
        """The device gate only retimes the flusher: byte routing is
        identical to the percentage gate."""

        trace = golden_trace("mixed-burst")
        pct = IONodeSimulator(
            scheme="ssdup+", ssd_capacity=4 * MiB, flush_gate=0.5
        ).run(trace)
        dev = IONodeSimulator(
            scheme="ssdup+", ssd_capacity=4 * MiB, flush_gate="device"
        ).run(trace)
        assert dev.bytes_to_ssd == pct.bytes_to_ssd
        assert dev.bytes_to_hdd_direct == pct.bytes_to_hdd_direct
