"""Unit + property tests for the random-factor detector (paper Section 2.2)."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # deterministic no-shrink fallback, same API surface
    from _hypothesis_fallback import given, settings, st

from repro.core import (
    Request,
    StreamGrouper,
    random_factor_batch,
    random_factor_sum,
    random_percentage,
    random_percentage_batch,
    stream_percentage,
)

REQ = 256 * 1024


class TestRandomFactorScalar:
    def test_fully_sequential_is_zero(self):
        offs = np.arange(128) * REQ
        assert random_factor_sum(offs, REQ) == 0
        assert random_percentage(offs, REQ) == 0.0

    def test_sorted_out_of_order_arrivals_still_sequential(self):
        # paper Fig. 4: arrival order is irrelevant, only sorted gaps count
        rng = np.random.default_rng(0)
        offs = rng.permutation(np.arange(128)) * REQ
        assert random_factor_sum(offs, REQ) == 0

    def test_fully_random_is_max(self):
        # huge strides: every sorted-adjacent pair leaves a gap
        offs = np.arange(128) * (10 * REQ)
        assert random_factor_sum(offs, REQ) == 127
        assert random_percentage(offs, REQ) == pytest.approx(1.0)

    def test_paper_fig4_example(self):
        # items #2,#3 contiguous after sorting (RF 0); #4 -> #7 gap (RF 1)
        offs = np.array([2, 3, 4, 7]) * REQ
        # pairs after sort: (2,3)=0, (3,4)=0, (4,7)=1
        assert random_factor_sum(offs, REQ) == 1

    def test_strided_half(self):
        # every second request present: all gaps = 2*REQ -> all random
        offs = np.arange(0, 256, 2) * REQ
        assert random_percentage(offs, REQ) == pytest.approx(1.0)

    def test_variable_sizes(self):
        # contiguity must use each request's own size
        offs = [0, 100, 300]
        sizes = [100, 200, 50]
        assert random_factor_sum(offs, sizes) == 0
        sizes = [100, 100, 50]
        assert random_factor_sum(offs, sizes) == 1

    def test_single_and_empty(self):
        assert random_factor_sum([], REQ) == 0
        assert random_factor_sum([42], REQ) == 0
        assert random_percentage([42], REQ) == 0.0


class TestBatchOracleAgreement:
    """The jnp batch path must agree with the scalar path (it is also the
    oracle for the stream_rf Pallas kernel)."""

    @pytest.mark.parametrize("n", [2, 16, 128, 256])
    def test_agreement_random(self, n):
        rng = np.random.default_rng(n)
        offs = rng.integers(0, 1 << 20, size=(8, n)).astype(np.int32)
        sizes = np.full((8, n), 7, np.int32)
        batch = np.asarray(random_factor_batch(offs, sizes))
        for i in range(8):
            assert batch[i] == random_factor_sum(offs[i], sizes[i])

    def test_percentage_batch(self):
        offs = np.arange(128, dtype=np.int32)[None, :] * 7
        out = np.asarray(random_percentage_batch(offs, 7))
        assert out.shape == (1,)
        assert out[0] == pytest.approx(0.0)


@settings(max_examples=200, deadline=None)
@given(
    offsets=st.lists(st.integers(0, 1 << 30), min_size=2, max_size=128),
    size=st.integers(1, 1 << 20),
)
def test_property_rf_bounds_and_sort_invariance(offsets, size):
    """0 <= S <= N-1; permuting arrivals never changes S (sorting first)."""

    offs = np.asarray(offsets, dtype=np.int64)
    s = random_factor_sum(offs, size)
    assert 0 <= s <= len(offs) - 1
    rng = np.random.default_rng(1)
    assert random_factor_sum(rng.permutation(offs), size) == s
    p = random_percentage(offs, size)
    assert 0.0 <= p <= 1.0


@settings(max_examples=100, deadline=None)
@given(st.integers(2, 64), st.integers(1, 100))
def test_property_contiguous_run_is_zero(n, size):
    offs = np.arange(n, dtype=np.int64) * size
    assert random_factor_sum(offs, size) == 0


class TestStreamGrouper:
    def test_groups_of_stream_len(self):
        g = StreamGrouper(4)
        out = list(g.push_many(Request(i, 1) for i in range(10)))
        assert [len(s) for s in out] == [4, 4]
        assert g.pending == 2
        tail = g.flush()
        assert len(tail) == 2
        assert g.flush() is None
        assert g.streams_emitted == 3

    def test_rejects_tiny_stream_len(self):
        with pytest.raises(ValueError):
            StreamGrouper(1)

    def test_stream_percentage_of_requests(self):
        stream = [Request(i * 10, 10) for i in range(16)]
        assert stream_percentage(stream) == 0.0
        stream = [Request(i * 30, 10) for i in range(16)]
        assert stream_percentage(stream) == 1.0
