"""ssm_scan Pallas kernel sweep vs. the jnp oracle (interpret mode)."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels.ssm_scan.ops import ssm_scan_op
from repro.kernels.ssm_scan.ref import ssm_scan_ref

pytestmark = pytest.mark.slow  # interpret-mode Pallas runs, seconds per case


def make(b, s, di, n, xdtype, seed=0):
    rng = np.random.default_rng(seed)
    delta = np.abs(rng.normal(0, 0.1, (b, s, di))).astype(np.float32)
    B = rng.normal(size=(b, s, n)).astype(np.float32)
    C = rng.normal(size=(b, s, n)).astype(np.float32)
    x = rng.normal(size=(b, s, di)).astype(xdtype)
    A = -np.abs(rng.normal(1, 0.3, (di, n))).astype(np.float32)
    return delta, B, C, x, A


@pytest.mark.parametrize("xdtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,di,n,bd,ck",
    [
        (1, 32, 16, 4, 16, 16),
        (2, 64, 32, 8, 16, 16),   # multiple d-blocks AND chunks
        (1, 128, 64, 16, 64, 32),  # falcon-mamba-like ratios, scaled
        (3, 96, 48, 8, 16, 32),   # odd batch, 3 chunks, 3 d-blocks
    ],
)
def test_vs_ref(b, s, di, n, bd, ck, xdtype):
    delta, B, C, x, A = make(b, s, di, n, np.float32)
    x = jnp.asarray(x, xdtype)
    y, h = ssm_scan_op(delta, B, C, x, A, block_d=bd, chunk=ck)
    yr, hr = ssm_scan_ref(jnp.asarray(delta), jnp.asarray(B), jnp.asarray(C),
                          x, jnp.asarray(A))
    tol = 3e-2 if xdtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               atol=1e-3, rtol=1e-3)


def test_state_carries_across_chunks():
    """The VMEM state must persist across sequence-chunk grid steps:
    splitting the same sequence into more chunks may not change the result."""

    delta, B, C, x, A = make(1, 64, 16, 4, np.float32, seed=3)
    y1, h1 = ssm_scan_op(delta, B, C, x, A, block_d=16, chunk=64)
    y2, h2 = ssm_scan_op(delta, B, C, x, A, block_d=16, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-5)


def test_matches_model_scan_path():
    """Kernel semantics == the model trunk's chunked scan (mamba1 path)."""

    from repro.models.layers import _ssm_scan

    delta, B, C, x, A = make(2, 64, 32, 8, np.float32, seed=5)
    h0 = jnp.zeros((2, 32, 8), jnp.float32)
    y_model, h_model = _ssm_scan(
        jnp.asarray(delta), jnp.asarray(B), jnp.asarray(C), jnp.asarray(x),
        h0, chunk=16, A_full=jnp.asarray(A))
    y_k, h_k = ssm_scan_op(delta, B, C, x, A, block_d=16, chunk=16)
    np.testing.assert_allclose(np.asarray(y_model), np.asarray(y_k),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h_model), np.asarray(h_k),
                               atol=1e-4, rtol=1e-4)
