"""HLO collective parser + roofline-term unit tests."""

import pytest

from repro.launch.roofline import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    RooflineTerms,
    derive_terms,
    model_flops_per_step,
    parse_collectives,
)
from repro.configs import SHAPE_CELLS, get_config

HLO_SAMPLE = """
ENTRY %main {
  %x = bf16[16,1024]{1,0} parameter(0)
  %ar = bf16[16,1024]{1,0} all-reduce(%x), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  %ag = f32[64,64]{1,0} all-gather(%x), replica_groups=[16,16]<=[256], dimensions={0}
  %rs = bf16[8,128]{1,0} reduce-scatter(%x), replica_groups={{0,1}}, to_apply=%add
  %cp = f32[4,4]{1,0} collective-permute(%x), source_target_pairs={{0,1},{1,0}}
  %a2a = (bf16[2,2]{1,0}, bf16[2,2]{1,0}) all-to-all(%x, %y), replica_groups={{0,1,2,3}}
  %ags = bf16[32]{0} all-gather-start(%x), replica_groups={{0,1,2,3}}
  %agd = bf16[32]{0} all-gather-done(%ags)
  %dot = f32[4,4]{1,0} dot(%cp, %cp)
}
"""


class TestParser:
    def test_counts_and_kinds(self):
        st = parse_collectives(HLO_SAMPLE)
        assert st.counts["all-reduce"] == 1
        assert st.counts["all-gather"] == 2  # plain + -start, -done skipped
        assert st.counts["reduce-scatter"] == 1
        assert st.counts["collective-permute"] == 1
        assert st.counts["all-to-all"] == 1

    def test_byte_accounting(self):
        st = parse_collectives(HLO_SAMPLE)
        assert st.bytes_by_kind["all-reduce"] == 16 * 1024 * 2
        # tuple output: two bf16[2,2]
        assert st.bytes_by_kind["all-to-all"] == 2 * (2 * 2 * 2)

    def test_ring_factors(self):
        # one all-reduce of N bytes in a group of 4 -> 2*(3/4)*N link bytes
        text = ("%ar = f32[10]{0} all-reduce(%x), "
                "replica_groups={{0,1,2,3}}, to_apply=%a")
        st = parse_collectives(text)
        assert st.link_bytes == pytest.approx(2 * 0.75 * 40)

    def test_iota_replica_groups(self):
        text = ("%ag = f32[16]{0} all-gather(%x), "
                "replica_groups=[16,16]<=[256], dimensions={0}")
        st = parse_collectives(text)
        # group size 16 -> factor 15/16
        assert st.link_bytes == pytest.approx((15 / 16) * 64)

    def test_ignores_non_collectives(self):
        st = parse_collectives("%dot = f32[4,4]{1,0} dot(%a, %b)")
        assert st.link_bytes == 0.0 and not st.counts


class TestTerms:
    def test_derive_and_dominance(self):
        st = parse_collectives(HLO_SAMPLE)
        t = derive_terms({"flops": 1e15, "bytes accessed": 1e9}, st)
        assert t.compute_s == pytest.approx(1e15 / PEAK_FLOPS)
        assert t.memory_s == pytest.approx(1e9 / HBM_BW)
        assert t.dominant == "compute"
        assert t.step_time_s == max(t.compute_s, t.memory_s, t.collective_s)

    def test_model_flops_train_vs_decode(self):
        cfg = get_config("qwen3-1.7b")
        train = model_flops_per_step(cfg, SHAPE_CELLS["train_4k"])
        dec = model_flops_per_step(cfg, SHAPE_CELLS["decode_32k"])
        # train: 6*N*B*S; decode: 2*N*B — many orders of magnitude apart
        assert train / dec == pytest.approx(
            3 * 256 * 4096 / 128, rel=1e-6)

    def test_moe_uses_active_params(self):
        cfg = get_config("grok-1-314b")
        assert cfg.active_param_count() < 0.45 * cfg.param_count()
