"""End-to-end tests of the real-byte burst buffer (checkpoint substrate)."""

import os

import numpy as np
import pytest

from repro.core import BurstBufferWriter


@pytest.fixture()
def dirs(tmp_path):
    return str(tmp_path / "fast"), str(tmp_path / "slow")


def test_write_drain_readback_sequential(dirs):
    fast, slow = dirs
    bb = BurstBufferWriter(fast, slow, region_bytes=1 << 16, stream_len=8)
    rng = np.random.default_rng(0)
    blobs = {}
    off = 0
    for i in range(64):
        data = rng.bytes(512)
        blobs[off] = data
        bb.write(file_id=0, offset=off, data=data)
        off += 512
    bb.drain()
    # everything must land in the slow tier, byte-exact
    path = os.path.join(slow, "file_0.bin")
    with open(path, "rb") as f:
        content = f.read()
    for o, d in blobs.items():
        assert content[o:o + 512] == d
    bb.close()


def test_random_offsets_round_trip(dirs):
    """Random writes exercise the fast-tier log + AVL path; after drain the
    slow tier must hold every extent at its ORIGINAL offset."""

    fast, slow = dirs
    bb = BurstBufferWriter(fast, slow, region_bytes=1 << 15, stream_len=8)
    rng = np.random.default_rng(1)
    # shuffled offsets look random to the detector -> fast tier
    offsets = rng.permutation(256) * 256
    blobs = {}
    for o in offsets:
        data = rng.bytes(256)
        blobs[int(o)] = data
        bb.write(file_id=3, offset=int(o), data=data)
    bb.drain()
    stats = bb.stats()
    with open(os.path.join(slow, "file_3.bin"), "rb") as f:
        content = f.read()
    for o, d in blobs.items():
        assert content[o:o + 256] == d, f"extent at {o} corrupted"
    bb.close()
    assert stats["bytes_fast"] + stats["bytes_slow_direct"] == 256 * 256


def test_read_your_writes_before_drain(dirs):
    fast, slow = dirs
    bb = BurstBufferWriter(fast, slow, region_bytes=1 << 15, stream_len=4)
    rng = np.random.default_rng(2)
    # random-looking offsets so the stream is redirected to the fast tier
    offs = [0, 999_000, 5_000_000, 2_500_000, 7_777_000, 1_234_000,
            9_000_000, 4_321_000]
    blobs = {}
    for o in offs:
        d = rng.bytes(128)
        blobs[o] = d
        bb.write(file_id=7, offset=o, data=d)
    # streams of 4 -> both streams dispatched; data may be in fast tier
    for o, d in blobs.items():
        assert bb.read(7, o, 128) == d
    bb.close()


def test_multiple_files(dirs):
    fast, slow = dirs
    bb = BurstBufferWriter(fast, slow, region_bytes=1 << 14, stream_len=4)
    rng = np.random.default_rng(3)
    blobs = {}
    for i in range(48):
        fid = i % 3
        off = (i // 3) * 128
        d = rng.bytes(128)
        blobs[(fid, off)] = d
        bb.write(fid, off, d)
    bb.drain()
    for (fid, off), d in blobs.items():
        with open(os.path.join(slow, f"file_{fid}.bin"), "rb") as f:
            f.seek(off)
            assert f.read(128) == d
    bb.close()


def test_region_cycling_under_pressure(dirs):
    """Writing far more than the fast tier forces multiple flush cycles."""

    fast, slow = dirs
    bb = BurstBufferWriter(fast, slow, region_bytes=4096, stream_len=4,
                           traffic_aware=False)
    rng = np.random.default_rng(4)
    blobs = {}
    offs = rng.permutation(128) * 1024  # random -> fast tier
    for o in offs:
        d = rng.bytes(1024)
        blobs[int(o)] = d
        bb.write(0, int(o), d)
    bb.drain()
    stats = bb.stats()
    with open(os.path.join(slow, "file_0.bin"), "rb") as f:
        content = f.read()
    for o, d in blobs.items():
        assert content[o:o + 1024] == d
    bb.close()
    if stats["bytes_fast"] > 0:
        assert stats["flushes_completed"] >= 1


def test_stats_shape(dirs):
    fast, slow = dirs
    bb = BurstBufferWriter(fast, slow)
    bb.write(0, 0, b"x" * 64)
    s = bb.stats()
    for key in ("bytes_fast", "bytes_slow_direct", "fast_byte_ratio",
                "flushes_completed", "flush_stalls", "metadata_bytes",
                "threshold"):
        assert key in s
    bb.close()
