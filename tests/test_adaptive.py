"""Tests for the adaptive threshold (paper Eq. 2/3, Section 2.3.2)."""

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # deterministic no-shrink fallback, same API surface
    from _hypothesis_fallback import given, settings, st

from repro.core import AdaptiveThreshold, StaticWatermarkThreshold

PAPER_PCTS = [0.3937, 0.5433, 0.5905, 0.6299, 0.6062,
              0.5826, 0.622, 0.622, 0.622, 0.6771]
PAPER_THRESHOLDS = [0.5, 0.5433, 0.5433, 0.5433, 0.5905,
                    0.5826, 0.5826, 0.5905, 0.5905, 0.6062]


class TestPaperCaseStudy:
    def test_reproduces_paper_sequence(self):
        """Section 2.3.2 case study: our indexing convention reproduces 9/10
        of the paper's printed thresholds exactly (the 7th differs by one
        sorted index — consistent with their 4-decimal rounding)."""

        at = AdaptiveThreshold()
        out = at.observe_many(PAPER_PCTS)
        exact = sum(abs(a - b) < 1e-9 for a, b in zip(out, PAPER_THRESHOLDS))
        assert exact >= 9
        # ... and the one mismatch is a neighbour element of PercentList
        for a, b in zip(out, PAPER_THRESHOLDS):
            assert abs(a - b) <= 0.012

    def test_redirection_set_matches_paper(self):
        """The paper lists the streams directed to SSD: those with pct
        0.6299, 0.6062, 0.5826(x0)... — verify the >threshold predicate picks
        the same high-percentage members."""

        at = AdaptiveThreshold()
        sent = []
        for p in PAPER_PCTS:
            thr_before = at.threshold
            at.observe(p)
            if p > thr_before:
                sent.append(p)
        # all of the paper's listed redirected percentages appear
        for expected in (0.6299, 0.6062, 0.622, 0.6771):
            assert expected in sent


class TestAdaptiveBehaviour:
    def test_default_before_history(self):
        at = AdaptiveThreshold(default=0.5)
        assert at.threshold == 0.5

    def test_low_randomness_strict_threshold(self):
        """Mostly-sequential history => threshold near the top of the list
        (few streams redirected)."""

        at = AdaptiveThreshold()
        at.observe_many([0.05, 0.08, 0.1, 0.12, 0.06, 0.9])
        assert at.threshold >= 0.5  # picks high-index element

    def test_high_randomness_loose_threshold(self):
        at = AdaptiveThreshold()
        at.observe_many([0.9, 0.95, 0.85, 0.92, 0.88])
        # avgper ~0.9 -> index ~0.1*N -> near the list's bottom
        assert at.threshold <= 0.9

    def test_threshold_always_member_of_percentlist(self):
        at = AdaptiveThreshold(window=8)
        import random
        rnd = random.Random(0)
        at.observe(rnd.random())  # first observation keeps the default
        for _ in range(200):
            at.observe(rnd.random())
            assert at.threshold in at.percent_list

    def test_window_eviction(self):
        at = AdaptiveThreshold(window=3)
        at.observe_many([0.1, 0.2, 0.3, 0.4])
        assert len(at.percent_list) == 3
        assert 0.1 not in at.percent_list

    def test_reset(self):
        at = AdaptiveThreshold()
        at.observe_many([0.5, 0.6])
        at.reset()
        assert at.threshold == at.default
        assert at.percent_list == ()

    def test_rejects_out_of_range(self):
        at = AdaptiveThreshold()
        with pytest.raises(ValueError):
            at.observe(1.5)
        with pytest.raises(ValueError):
            at.observe(-0.1)


@settings(max_examples=200, deadline=None)
@given(st.lists(st.floats(0.0, 1.0), min_size=2, max_size=64))
def test_property_threshold_bounded_by_history(pcts):
    """From the second observation on, threshold is always an element of
    PercentList => within [min, max] of the observed history."""

    at = AdaptiveThreshold(window=16)
    for p in pcts:
        at.observe(p)
    lst = at.percent_list
    assert lst[0] <= at.threshold <= lst[-1]
    assert list(lst) == sorted(lst)
    # avgper consistent
    assert at.avgper == pytest.approx(sum(lst) / len(lst))


class TestStaticWatermarks:
    def test_hysteresis(self):
        sw = StaticWatermarkThreshold(high=0.45, low=0.30)
        assert not sw.is_random(0.40)  # below high, initial state seq
        sw.observe(0.5)
        assert sw.is_random(0.40)  # in band, sticky random
        sw.observe(0.2)
        assert not sw.is_random(0.40)  # dropped below low

    def test_validation(self):
        with pytest.raises(ValueError):
            StaticWatermarkThreshold(high=0.2, low=0.5)
