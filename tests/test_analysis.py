"""simlint unit tests: one known-bad and one known-good snippet per
rule, inline suppression, baseline round-trip/diff, the CLI, and the
repo-clean gate (``src/repro`` must scan clean at HEAD)."""

import pathlib
import textwrap

import pytest

from repro.analysis import check_paths, check_source
from repro.analysis.baseline import (
    diff_baseline,
    load_baseline,
    write_baseline,
)
from repro.analysis.cli import main as cli_main
from repro.analysis.engine import iter_py_files
from repro.analysis.rules import all_rules, rules_by_id

SRC = pathlib.Path(__file__).resolve().parents[1] / "src" / "repro"


def findings_for(rule_id: str, source: str, rel: str = "core/snippet.py"):
    rules = rules_by_id([rule_id])
    return check_source(textwrap.dedent(source), rules=rules, rel=rel)


def assert_flags(rule_id: str, source: str, rel: str = "core/snippet.py"):
    found = findings_for(rule_id, source, rel)
    assert found, f"{rule_id} missed a known-bad snippet"
    assert all(f.rule == rule_id for f in found)
    return found


def assert_clean(rule_id: str, source: str, rel: str = "core/snippet.py"):
    found = findings_for(rule_id, source, rel)
    assert not found, f"{rule_id} false positive: {[f.render() for f in found]}"


# -- one known-bad (and one known-good) snippet per rule -----------------


class TestSL101UnseededRandom:
    def test_flags_global_rng(self):
        assert_flags("SL101", """
            import numpy as np
            x = np.random.uniform(0, 1, 100)
        """)

    def test_allows_default_rng(self):
        assert_clean("SL101", """
            import numpy as np
            rng = np.random.default_rng(0)
            seq = np.random.SeedSequence([1, 2])
            x = rng.uniform(0, 1, 100)
        """)


class TestSL102UnscopedX64:
    def test_flags_config_update(self):
        assert_flags("SL102", """
            import jax
            jax.config.update("jax_enable_x64", True)
        """)

    def test_flags_unscoped_enable_call(self):
        assert_flags("SL102", """
            from jax.experimental import enable_x64
            enable_x64()
        """)

    def test_allows_scoped_context(self):
        assert_clean("SL102", """
            from jax.experimental import enable_x64
            with enable_x64():
                pass
        """)


class TestSL103TracedBranch:
    def test_flags_if_on_jitted_param(self):
        assert_flags("SL103", """
            import jax

            @jax.jit
            def f(x):
                if x > 0:
                    return x
                return -x
        """)

    def test_flags_branch_in_scanned_fn(self):
        assert_flags("SL103", """
            from jax import lax

            def step(carry, ev):
                if ev:
                    carry = carry + 1
                return carry, None

            out = lax.scan(step, 0, xs)
        """)

    def test_allows_static_argnames(self):
        assert_clean("SL103", """
            import functools
            import jax

            @functools.partial(jax.jit, static_argnames=("mode",))
            def f(x, mode):
                if mode:
                    return x
                return -x
        """)

    def test_allows_lax_cond(self):
        assert_clean("SL103", """
            import jax
            import jax.numpy as jnp

            @jax.jit
            def f(x):
                return jnp.where(x > 0, x, -x)
        """)


class TestSL104UnorderedIteration:
    def test_flags_for_over_set_literal(self):
        assert_flags("SL104", """
            for node in {3, 1, 2}:
                emit(node)
        """)

    def test_flags_list_of_set_call(self):
        assert_flags("SL104", """
            order = list(set(xs))
        """)

    def test_allows_sorted_set(self):
        assert_clean("SL104", """
            for node in sorted({3, 1, 2}):
                emit(node)
            order = sorted(set(xs))
        """)


class TestSL105TapeColumnMutation:
    def test_flags_subscript_store(self):
        assert_flags("SL105", """
            def f(batch):
                batch.sizes[0] = 0
        """)

    def test_flags_inplace_sort(self):
        assert_flags("SL105", """
            def f(scores):
                scores.percentage.sort()
        """)

    def test_allows_copy_then_mutate(self):
        assert_clean("SL105", """
            def f(batch):
                sizes = batch.sizes.copy()
                sizes[0] = 0
                srt = np.sort(scores.percentage)
        """)


class TestSL106LoadBearingAssert:
    def test_flags_assert(self):
        assert_flags("SL106", """
            def f(pipeline):
                assert pipeline.flush_job is not None
        """)

    def test_allows_raise(self):
        assert_clean("SL106", """
            def f(pipeline):
                if pipeline.flush_job is None:
                    raise RuntimeError("no active flush job")
        """)


class TestSL107UnitSuffix:
    def test_flags_cross_family_assign(self):
        assert_flags("SL107", """
            total_bytes = elapsed_seconds
        """)

    def test_flags_cross_family_add(self):
        assert_flags("SL107", """
            budget = wait_seconds + backlog_bytes
        """)

    def test_allows_same_family_and_converted(self):
        assert_clean("SL107", """
            total_bytes = region_bytes + overflow_bytes
            wall_seconds = io_seconds + gap_seconds
            total_mb = used_bytes / 1e6
        """)


class TestSL108EngineContract:
    BAD = """
        def run_replay(trace):
            \"\"\"Replays the trace.\"\"\"
            return trace
    """

    def test_flags_core_entry_point_without_contract(self):
        assert_flags("SL108", self.BAD, rel="core/engine.py")

    def test_ignores_non_core_modules(self):
        assert_clean("SL108", self.BAD, rel="service/loop.py")

    def test_allows_documented_contract(self):
        assert_clean("SL108", """
            def run_replay(trace):
                \"\"\"Replay; bit-identical to the per-request oracle.\"\"\"
                return trace
        """, rel="core/engine.py")


class TestSL109MutableDefault:
    def test_flags_list_default(self):
        assert_flags("SL109", """
            def f(x, acc=[]):
                acc.append(x)
                return acc
        """)

    def test_allows_none_default(self):
        assert_clean("SL109", """
            def f(x, acc=None):
                acc = [] if acc is None else acc
                acc.append(x)
                return acc
        """)


class TestSL110SilentException:
    def test_flags_bare_except(self):
        assert_flags("SL110", """
            try:
                risky()
            except:
                pass
        """)

    def test_flags_swallowed_exception(self):
        assert_flags("SL110", """
            try:
                risky()
            except Exception:
                pass
        """)

    def test_allows_handled_exception(self):
        assert_clean("SL110", """
            try:
                risky()
            except ValueError:
                pass
            try:
                risky()
            except Exception as e:
                log(e)
        """)


class TestSL111MethodLruCache:
    def test_flags_cached_method(self):
        assert_flags("SL111", """
            import functools

            class Sim:
                @functools.lru_cache(maxsize=8)
                def score(self, n):
                    return n * n
        """)

    def test_allows_module_level_cache(self):
        assert_clean("SL111", """
            import functools

            @functools.lru_cache(maxsize=8)
            def score(n):
                return n * n

            class Sim:
                @staticmethod
                def helper(n):
                    return score(n)
        """)


# -- engine mechanics ----------------------------------------------------


def test_inline_suppression():
    src = "def f(x):\n    assert x  # simlint: disable=SL106\n"
    assert check_source(src, rules=rules_by_id(["SL106"])) == []
    # a different rule id does not suppress
    src2 = "def f(x):\n    assert x  # simlint: disable=SL101\n"
    assert len(check_source(src2, rules=rules_by_id(["SL106"]))) == 1


def test_suppress_all():
    src = "def f(x):\n    assert x  # simlint: disable=all\n"
    assert check_source(src) == []


def test_fingerprint_is_line_independent():
    a = check_source("def f(x):\n    assert x\n")
    b = check_source("\n\n\ndef f(x):\n    assert x\n")
    assert a[0].line != b[0].line
    assert a[0].fingerprint == b[0].fingerprint


def test_rules_by_id_rejects_unknown():
    with pytest.raises(ValueError, match="unknown rule"):
        rules_by_id(["SL999"])


def test_registry_has_at_least_eight_distinct_rules():
    ids = [r.id for r in all_rules()]
    assert len(ids) == len(set(ids))
    assert len(ids) >= 8


def test_iter_py_files_rejects_non_python(tmp_path):
    f = tmp_path / "data.json"
    f.write_text("{}")
    with pytest.raises(ValueError, match="not a .py file"):
        iter_py_files([f])


# -- baseline ------------------------------------------------------------


def test_baseline_round_trip_and_diff(tmp_path):
    findings = check_source("def f(x):\n    assert x\n    assert not x\n")
    assert len(findings) == 2
    path = tmp_path / "baseline.json"
    write_baseline(path, findings)
    counts = load_baseline(path)
    assert sum(counts.values()) == 2

    # same findings: nothing new, nothing stale
    new, stale = diff_baseline(findings, counts)
    assert new == [] and stale == []

    # one fixed: it shows up as stale
    new, stale = diff_baseline(findings[:1], counts)
    assert new == [] and len(stale) == 1

    # a fresh finding is reported as new
    extra = check_source("def g(y):\n    assert y\n")
    new, stale = diff_baseline(findings + extra, counts)
    assert len(new) == 1


def test_baseline_rejects_bad_schema(tmp_path):
    path = tmp_path / "bad.json"
    path.write_text('{"schema": "nope/v9", "fingerprints": {}}')
    with pytest.raises(ValueError, match="unknown baseline schema"):
        load_baseline(path)


# -- CLI -----------------------------------------------------------------


def test_cli_check_and_baseline_flow(tmp_path, capsys):
    bad = tmp_path / "mod.py"
    bad.write_text("def f(x):\n    assert x\n")

    assert cli_main(["--check", str(bad)]) == 1
    assert "SL106" in capsys.readouterr().out

    baseline = tmp_path / "baseline.json"
    assert cli_main(
        ["--check", str(bad), "--write-baseline", str(baseline)]
    ) == 0
    capsys.readouterr()
    # baselined: clean exit
    assert cli_main(["--check", str(bad), "--baseline", str(baseline)]) == 0
    assert "clean" in capsys.readouterr().out

    # fixing the file makes the baseline entry stale -> nonzero, so the
    # baseline cannot rot silently
    bad.write_text("def f(x):\n    return x\n")
    assert cli_main(["--check", str(bad), "--baseline", str(baseline)]) == 1
    assert "stale" in capsys.readouterr().out


def test_cli_list_rules(capsys):
    assert cli_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    assert "SL106" in out and "load-bearing-assert" in out


def test_cli_requires_check(capsys):
    assert cli_main([]) == 2


# -- the gate: the repo itself scans clean -------------------------------


def test_src_repro_is_simlint_clean():
    findings = check_paths([SRC])
    assert findings == [], "\n".join(f.render() for f in findings)
