"""Golden-fixture store: committed snapshots pin both replay engines,
both index backends, and the diff reporter's first-divergence naming."""

import copy

import pytest

from repro.testing import golden
from repro.testing.golden import (
    FIXTURE_POLICIES,
    FIXTURE_SCHEMES,
    FIXTURE_WORKLOADS,
    GOLDEN_DIR,
    GoldenStorageMismatch,
    GoldenTraceMismatch,
    check_fixture,
    first_divergence,
    fixture_path,
    fleet_result_to_dict,
    load_fixture,
    replay_fixture,
)

FIXTURE_FILES = sorted(GOLDEN_DIR.glob("*__*.json"))


@pytest.fixture(scope="module")
def payloads():
    return {p.name: load_fixture(p) for p in FIXTURE_FILES}


def test_fixture_matrix_complete():
    """Acceptance floor: >= 3 schemes x 2 workloads x 2 policies."""

    assert len(FIXTURE_SCHEMES) >= 3
    assert len(FIXTURE_WORKLOADS) >= 2
    assert len(FIXTURE_POLICIES) >= 2
    for scheme in FIXTURE_SCHEMES:
        for workload in FIXTURE_WORKLOADS:
            for policy in FIXTURE_POLICIES:
                assert fixture_path(scheme, workload, policy).exists()


@pytest.mark.parametrize("path", FIXTURE_FILES, ids=lambda p: p.stem)
def test_replay_matches_fixture(path, payloads):
    payload = payloads[path.name]
    diffs = check_fixture(payload, replay_fixture(payload))
    assert diffs == [], f"{path.name} diverged:\n" + "\n".join(diffs)


# The per-request oracle and the AVL index replay a subset (buffered
# schemes exercise both pipelines); bit-exact equality against the
# batched/numpy-generated snapshot pins all engine/backend combinations.
_CROSS = [
    (s, w, p)
    for s in ("ssdup", "ssdup+", "orangefs-bb")
    for w in FIXTURE_WORKLOADS
    for p in ("range-offset",)
]


@pytest.mark.parametrize("scheme,workload,policy", _CROSS)
def test_per_request_oracle_matches_fixture(scheme, workload, policy,
                                            payloads):
    payload = payloads[golden.fixture_name(scheme, workload, policy)]
    diffs = check_fixture(
        payload, replay_fixture(payload, engine="per-request"))
    assert diffs == [], "\n".join(diffs)


@pytest.mark.parametrize("scheme,workload,policy", _CROSS)
def test_avl_index_matches_fixture(scheme, workload, policy, payloads):
    payload = payloads[golden.fixture_name(scheme, workload, policy)]
    diffs = check_fixture(
        payload, replay_fixture(payload, index_backend="avl"))
    assert diffs == [], "\n".join(diffs)


class TestDiffReporter:
    def test_perturbed_fixture_names_field(self, payloads):
        payload = payloads[golden.fixture_name(
            "ssdup+", "mixed-burst", "range-offset")]
        actual = fleet_result_to_dict(replay_fixture(payload))
        bad = copy.deepcopy(payload["result"])
        bad["nodes"][2]["bytes_to_ssd"] += 512
        msg = first_divergence(bad, actual)
        assert msg is not None
        assert msg.startswith("node[2].bytes_to_ssd: ")

    def test_causal_order_reports_routing_before_clocks(self, payloads):
        """A routing divergence must be named before a clock divergence,
        even on a later node — clocks are downstream of routing."""

        payload = payloads[golden.fixture_name(
            "ssdup+", "mixed-burst", "range-offset")]
        actual = fleet_result_to_dict(replay_fixture(payload))
        bad = copy.deepcopy(payload["result"])
        bad["nodes"][0]["io_seconds"] += 1.0      # clock, node 0
        bad["nodes"][3]["bytes_to_ssd"] += 4096   # routing, node 3
        msg = first_divergence(bad, actual)
        assert msg.startswith("node[3].bytes_to_ssd: ")

    def test_identical_results_have_no_divergence(self, payloads):
        payload = next(iter(payloads.values()))
        assert first_divergence(payload["result"],
                                copy.deepcopy(payload["result"])) is None

    def test_float_fields_compared_bit_exact(self, payloads):
        payload = next(iter(payloads.values()))
        bad = copy.deepcopy(payload["result"])
        bad["nodes"][0]["io_seconds"] += 1e-15
        if bad["nodes"][0]["io_seconds"] == payload["result"]["nodes"][0][
                "io_seconds"]:
            pytest.skip("perturbation below float resolution")
        msg = first_divergence(payload["result"], bad)
        assert "io_seconds" in msg


def test_trace_fingerprint_guards_protocol_drift(payloads):
    payload = copy.deepcopy(next(iter(payloads.values())))
    payload["trace"]["sha256"] = "0" * 64
    with pytest.raises(GoldenTraceMismatch, match="trace"):
        replay_fixture(payload)


def test_every_fixture_embeds_storage_fingerprint(payloads):
    """Fixtures record the SSD backend they were generated under, next
    to the device-tolerance contract."""

    for name, payload in payloads.items():
        sm = payload.get("storage_model")
        assert sm, f"{name}: missing storage_model fingerprint"
        assert sm["name"] == "constant", name


def test_replay_under_different_backend_fails_loudly(payloads):
    """A fixture snapshot is only meaningful under the storage backend
    that produced it: replaying under the FTL must refuse up front, not
    report a confusing timing divergence."""

    payload = next(iter(payloads.values()))
    with pytest.raises(GoldenStorageMismatch, match="storage backend"):
        replay_fixture(payload, ssd="ftl")


def test_fixture_floats_roundtrip_exactly(payloads):
    """JSON must preserve every float bit (repr shortest-roundtrip)."""

    import json

    for payload in payloads.values():
        again = json.loads(json.dumps(payload))
        assert again == payload
