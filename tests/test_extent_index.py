"""ExtentIndex ≡ AVLTree: the vectorized index must be a bit-exact drop-in.

The batched replay engine swaps the paper's AVL tree (§2.5) for the
columnar :class:`repro.core.extent_index.ExtentIndex`; these property
tests drive both through overwrite-heavy random workloads and assert the
full query surface agrees — ``in_order``, ``in_order_arrays``,
``flush_bytes``-style size sums, seek counts, ``lookup``, ``len``,
``min_key``/``max_key``, ``approx_bytes``.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # deterministic no-shrink fallback, same API surface
    from _hypothesis_fallback import given, settings, st

from repro.core import AVLTree, ExtentIndex, LogRegion, make_index
from repro.core.extent_index import INDEX_BACKENDS


def _populate(items):
    """Feed the same (offset, size) sequence to both backends."""

    avl, idx = AVLTree(), ExtentIndex()
    for log_off, (slot, size) in enumerate(items):
        off = slot * 8  # small key space => heavy overwriting
        avl.insert(off, size, log_off * 64)
        idx.insert(off, size, log_off * 64)
    return avl, idx


def _assert_equal(avl: AVLTree, idx: ExtentIndex, keys) -> None:
    assert len(idx) == len(avl)
    assert idx.min_key() == avl.min_key()
    assert idx.max_key() == avl.max_key()
    assert idx.approx_bytes() == avl.approx_bytes()
    a_ext = list(avl.in_order())
    b_ext = list(idx.in_order())
    assert a_ext == b_ext  # offsets, sizes AND log offsets, in flush order
    offs, szs, logs = idx.in_order_arrays()
    assert offs.tolist() == [e.offset for e in a_ext]
    assert szs.tolist() == [e.size for e in a_ext]
    assert logs.tolist() == [e.log_offset for e in a_ext]
    ao, asz, al = avl.in_order_arrays()
    np.testing.assert_array_equal(offs, ao)
    np.testing.assert_array_equal(szs, asz)
    np.testing.assert_array_equal(logs, al)
    for k in keys:
        assert idx.lookup(k) == avl.lookup(k)
    assert idx.lookup(-1) is None and avl.lookup(-1) is None


@settings(max_examples=150, deadline=None)
@given(
    st.lists(
        st.tuples(st.integers(0, 40), st.integers(1, 64)),
        min_size=0,
        max_size=300,
    )
)
def test_property_extent_index_matches_avl(items):
    """Overwrite-heavy random workloads: every query answer matches."""

    avl, idx = _populate(items)
    _assert_equal(avl, idx, keys=[slot * 8 for slot, _ in items])


@settings(max_examples=75, deadline=None)
@given(
    st.lists(
        st.tuples(st.integers(0, 40), st.integers(1, 64)),
        min_size=1,
        max_size=200,
    ),
    st.integers(1, 50),
)
def test_property_interleaved_scalar_and_batch_inserts(items, split):
    """Mixing insert() and insert_batch() must behave like the same
    arrival sequence fed scalar-only to the AVL oracle."""

    split = min(split, len(items))
    avl = AVLTree()
    idx = ExtentIndex()
    for log_off, (slot, size) in enumerate(items):
        avl.insert(slot * 8, size, log_off * 64)
    head, tail = items[:split], items[split:]
    for log_off, (slot, size) in enumerate(head):
        idx.insert(slot * 8, size, log_off * 64)
    if tail:
        offs = np.asarray([slot * 8 for slot, _ in tail], dtype=np.int64)
        szs = np.asarray([size for _, size in tail], dtype=np.int64)
        logs = np.arange(split, len(items), dtype=np.int64) * 64
        idx.insert_batch(offs, szs, logs)
    _assert_equal(avl, idx, keys=[slot * 8 for slot, _ in items])


@settings(max_examples=50, deadline=None)
@given(
    st.lists(
        st.tuples(st.integers(0, 3), st.integers(0, 50), st.integers(1, 16)),
        min_size=1,
        max_size=200,
    )
)
def test_property_log_region_backends_agree(items):
    """LogRegion flush accounting is backend-independent: flush order,
    live bytes, metadata and residual seek counts all match."""

    regions = {b: LogRegion(1 << 20, index_backend=b) for b in INDEX_BACKENDS}
    for fid, slot, size in items:
        for r in regions.values():
            r.append(fid, slot * 64, size)
    a, b = regions["avl"], regions["numpy"]
    assert list(a.flush_order()) == list(b.flush_order())
    assert a.flush_bytes() == b.flush_bytes()
    assert a.metadata_bytes() == b.metadata_bytes()
    assert a.seek_count_sorted() == b.seek_count_sorted()
    assert a.seek_count_if_unsorted() == b.seek_count_if_unsorted()


class TestExtentIndexBasics:
    def test_empty(self):
        idx = ExtentIndex()
        assert len(idx) == 0
        assert idx.min_key() is None and idx.max_key() is None
        assert idx.lookup(0) is None
        assert list(idx.in_order()) == []
        assert idx.approx_bytes() == 0

    def test_latest_version_wins(self):
        idx = ExtentIndex()
        idx.insert(100, 10, 0)
        idx.insert(100, 12, 40)  # newer log copy supersedes
        assert len(idx) == 1
        ext = idx.lookup(100)
        assert (ext.size, ext.log_offset) == (12, 40)

    def test_batch_then_query_then_insert_invalidates_cache(self):
        idx = ExtentIndex()
        idx.insert_batch(
            np.array([30, 10, 20]), np.array([1, 1, 1]), np.array([0, 1, 2])
        )
        assert [e.offset for e in idx.in_order()] == [10, 20, 30]
        idx.insert(10, 5, 99)  # overwrite after a cached compaction
        assert idx.lookup(10).log_offset == 99
        assert len(idx) == 3

    def test_clear(self):
        idx = ExtentIndex()
        idx.insert(1, 1, 0)
        idx.clear()
        assert len(idx) == 0 and idx.lookup(1) is None

    def test_make_index_rejects_unknown(self):
        with pytest.raises(ValueError, match="index_backend"):
            make_index("btree")

    def test_make_index_backends(self):
        assert isinstance(make_index("numpy"), ExtentIndex)
        assert isinstance(make_index("avl"), AVLTree)
