"""Fleet simulator: N=1 equivalence, sharding conservation, scaling."""

import numpy as np
import pytest

from repro.core import (
    FleetSimulator,
    IONodeSimulator,
    TraceBatch,
    compute_stream_scores,
    ior,
    mixed,
    relabel,
    run_fleet_schemes,
)
from repro.core.workloads import MiB
from repro.distributed.sharding import TRACE_POLICIES, assign_nodes

SMALL = 128 * MiB


@pytest.fixture(scope="module")
def mixed_load():
    w1 = relabel(ior("segmented-contiguous", 8, total_bytes=SMALL, seed=1),
                 app_id=0, file_id=0)
    w2 = relabel(ior("segmented-random", 8, total_bytes=SMALL, seed=2),
                 app_id=1, file_id=1)
    w3 = relabel(ior("strided", 16, total_bytes=SMALL, seed=3),
                 app_id=2, file_id=2)
    return mixed(w1, w2, w3, burst_requests=256)


class TestSingleNodeEquivalence:
    """A 1-node fleet must reproduce IONodeSimulator exactly."""

    @pytest.mark.parametrize("scheme", ["orangefs", "orangefs-bb", "ssdup",
                                        "ssdup+"])
    @pytest.mark.parametrize("policy", ["round-robin-app", "hash-file"])
    def test_byte_accounting_bit_for_bit(self, mixed_load, scheme, policy):
        trace = list(mixed_load.trace)
        cap = mixed_load.total_bytes // 2
        single = IONodeSimulator(scheme=scheme, ssd_capacity=cap).run(trace)
        fleet = FleetSimulator(num_nodes=1, scheme=scheme, policy=policy,
                               ssd_capacity=cap).run(trace)
        node = fleet.node_results[0]
        assert node.total_bytes == single.total_bytes
        assert node.bytes_to_ssd == single.bytes_to_ssd
        assert node.bytes_to_hdd_direct == single.bytes_to_hdd_direct
        assert node.flushes == single.flushes
        assert node.peak_ssd_occupancy == single.peak_ssd_occupancy
        assert node.io_seconds == pytest.approx(single.io_seconds, rel=1e-12)
        assert node.total_seconds == pytest.approx(single.total_seconds,
                                                   rel=1e-12)

    def test_precomputed_scores_match_scalar_path(self, mixed_load):
        """run() with scores must equal run() without, byte for byte."""

        trace = list(mixed_load.trace)
        cap = mixed_load.total_bytes // 2
        scores = compute_stream_scores(trace)
        a = IONodeSimulator(scheme="ssdup+", ssd_capacity=cap).run(trace)
        b = IONodeSimulator(scheme="ssdup+", ssd_capacity=cap).run(
            trace, scores=scores)
        assert a.bytes_to_ssd == b.bytes_to_ssd
        assert a.bytes_to_hdd_direct == b.bytes_to_hdd_direct
        assert a.io_seconds == b.io_seconds
        assert a.total_seconds == b.total_seconds

    def test_stream_len_mismatch_rejected(self, mixed_load):
        trace = list(mixed_load.trace)
        scores = compute_stream_scores(trace, stream_len=64)
        with pytest.raises(ValueError, match="stream_len"):
            IONodeSimulator(scheme="ssdup+").run(trace, scores=scores)

    def test_wrong_trace_scores_rejected(self, mixed_load):
        """Scores precomputed for a different trace must not be applied."""

        trace = list(mixed_load.trace)
        other = ior("segmented-random", 8, total_bytes=SMALL, seed=99)
        wrong = compute_stream_scores(list(other.trace))
        with pytest.raises(ValueError, match="scores"):
            IONodeSimulator(scheme="ssdup+").run(trace, scores=wrong)
        # truncated scores (fewer streams than the trace) also rejected
        short = compute_stream_scores(trace[:128])
        with pytest.raises(ValueError, match="scores"):
            IONodeSimulator(scheme="ssdup+").run(trace, scores=short)


class TestShardingPolicies:
    @pytest.mark.parametrize("policy", sorted(TRACE_POLICIES))
    @pytest.mark.parametrize("num_nodes", [1, 2, 5, 16])
    def test_partition_without_loss(self, mixed_load, policy, num_nodes):
        batch = TraceBatch.from_requests(mixed_load.trace)
        assignment = assign_nodes(policy, batch.offsets, batch.file_ids,
                                  batch.app_ids, num_nodes)
        assert assignment.shape == (batch.num_requests,)
        assert assignment.min() >= 0 and assignment.max() < num_nodes
        shards = batch.shard(assignment, num_nodes)
        assert sum(s.num_requests for s in shards) == batch.num_requests
        assert sum(s.total_bytes for s in shards) == batch.total_bytes

    def test_round_robin_keeps_apps_whole(self, mixed_load):
        batch = TraceBatch.from_requests(mixed_load.trace)
        assignment = assign_nodes("round-robin-app", batch.offsets,
                                  batch.file_ids, batch.app_ids, 2)
        for app in np.unique(batch.app_ids):
            nodes = np.unique(assignment[batch.app_ids == app])
            assert len(nodes) == 1

    def test_hash_file_keeps_files_whole(self, mixed_load):
        batch = TraceBatch.from_requests(mixed_load.trace)
        assignment = assign_nodes("hash-file", batch.offsets, batch.file_ids,
                                  batch.app_ids, 4)
        for fid in np.unique(batch.file_ids):
            assert len(np.unique(assignment[batch.file_ids == fid])) == 1

    def test_range_offset_orders_by_offset(self, mixed_load):
        batch = TraceBatch.from_requests(mixed_load.trace)
        assignment = assign_nodes("range-offset", batch.offsets,
                                  batch.file_ids, batch.app_ids, 4)
        # node id must be monotone in offset
        order = np.argsort(batch.offsets, kind="stable")
        assert np.all(np.diff(assignment[order]) >= 0)

    def test_unknown_policy_rejected(self):
        with pytest.raises(ValueError, match="policy"):
            FleetSimulator(num_nodes=2, policy="modulo-17")
        with pytest.raises(ValueError, match="policy"):
            assign_nodes("modulo-17", np.zeros(1), np.zeros(1), np.zeros(1), 2)


class TestFleetAggregation:
    def test_fleet_conserves_bytes(self, mixed_load):
        for policy in sorted(TRACE_POLICIES):
            fr = FleetSimulator(num_nodes=4, scheme="ssdup+", policy=policy,
                                ssd_capacity=SMALL).run(list(mixed_load.trace))
            assert fr.total_bytes == mixed_load.total_bytes
            assert fr.bytes_to_ssd + fr.bytes_to_hdd_direct == fr.total_bytes

    def test_straggler_bounds_fleet_time(self, mixed_load):
        fr = FleetSimulator(num_nodes=4, scheme="ssdup+",
                            ssd_capacity=SMALL).run(list(mixed_load.trace))
        assert fr.io_seconds == max(r.io_seconds for r in fr.node_results)
        assert fr.node_results[fr.straggler].io_seconds == fr.io_seconds
        assert fr.load_imbalance >= 1.0

    def test_more_nodes_do_not_slow_the_fleet(self, mixed_load):
        """Sharding over more I/O nodes must not hurt aggregate throughput."""

        trace = list(mixed_load.trace)
        tp = {
            n: FleetSimulator(num_nodes=n, scheme="ssdup+", policy="range-offset",
                              ssd_capacity=SMALL).run(trace).throughput_mbs
            for n in (1, 4)
        }
        assert tp[4] > tp[1]

    def test_run_fleet_schemes(self):
        # two random-heavy apps, one per node: the burst buffer must win
        w1 = relabel(ior("segmented-random", 8, total_bytes=SMALL, seed=7),
                     app_id=0, file_id=0)
        w2 = relabel(ior("segmented-random", 8, total_bytes=SMALL, seed=8),
                     app_id=1, file_id=1)
        load = mixed(w1, w2, burst_requests=256)
        res = run_fleet_schemes(list(load.trace), num_nodes=2,
                                schemes=("orangefs", "ssdup+"),
                                ssd_capacity=SMALL)
        assert set(res) == {"orangefs", "ssdup+"}
        for fr in res.values():
            assert fr.num_nodes == 2
            assert fr.total_bytes == load.total_bytes
        assert res["ssdup+"].throughput_mbs > res["orangefs"].throughput_mbs

    def test_gap_replicated_to_all_nodes(self, mixed_load):
        from repro.core import Gap

        trace = [Gap(7.0)] + list(mixed_load.trace)
        fr = FleetSimulator(num_nodes=3, scheme="orangefs",
                            policy="round-robin-app").run(trace)
        for r in fr.node_results:
            # every node idles through the compute phase
            assert r.total_seconds - r.io_seconds == pytest.approx(7.0)


NOJAX_SCRIPT = r"""
import sys

class BlockJax:
    def find_module(self, name, path=None):
        if name == "jax" or name.startswith("jax."):
            return self
    def load_module(self, name):
        raise ImportError(f"blocked: {name}")

sys.meta_path.insert(0, BlockJax())
sys.path.insert(0, "src")

from repro.core import FleetSimulator, compute_stream_scores, ior

w = ior("strided", 8, total_bytes=1 << 24)
scores = compute_stream_scores(list(w.trace))
fr = FleetSimulator(num_nodes=2, scheme="ssdup+",
                    ssd_capacity=1 << 24).run(list(w.trace))
assert fr.total_bytes == w.total_bytes
assert len(scores) > 0
print("NOJAX_OK")
"""


def test_fleet_runs_without_jax():
    """The control plane (core + fleet, numpy backend) must work jax-free."""

    import os
    import subprocess
    import sys

    out = subprocess.run(
        [sys.executable, "-c", NOJAX_SCRIPT], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=120,
    )
    assert "NOJAX_OK" in out.stdout, out.stdout + out.stderr
