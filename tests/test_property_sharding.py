"""Hypothesis property tests: sharding-rule invariants.

System invariants: a PartitionSpec never reuses a mesh axis; every sharded
dim is exactly divisible by its assigned axis product; unknown/None logical
names always replicate.
"""

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # deterministic no-shrink fallback, same API surface
    from _hypothesis_fallback import given, settings, st

from repro.distributed.sharding import DEFAULT_RULES, spec_for


class FakeMesh:
    def __init__(self, **axes):
        self.shape = axes


MESHES = [
    FakeMesh(data=16, model=16),
    FakeMesh(pod=2, data=16, model=16),
    FakeMesh(data=4, model=8),
]

LOGICAL = st.sampled_from(
    [None, "batch", "embed", "vocab", "heads", "kv_heads", "mlp",
     "experts", "inner", "cache_seq", "layers", "state", "not-a-rule"])


@settings(max_examples=300, deadline=None)
@given(
    mesh_i=st.integers(0, len(MESHES) - 1),
    dims=st.lists(
        st.tuples(st.integers(1, 4096), LOGICAL), min_size=1, max_size=6),
)
def test_spec_never_reuses_axes_and_always_divides(mesh_i, dims):
    mesh = MESHES[mesh_i]
    shape = tuple(d for d, _ in dims)
    logical = tuple(l for _, l in dims)
    spec = spec_for(shape, logical, mesh, DEFAULT_RULES)
    assert len(spec) == len(shape)

    used: list[str] = []
    for dim, entry in zip(shape, spec):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        for a in axes:
            assert a in mesh.shape, f"unknown mesh axis {a}"
            assert a not in used, f"mesh axis {a} reused"
            used.append(a)
            size *= mesh.shape[a]
        assert dim % size == 0, f"dim {dim} not divisible by {size}"


@settings(max_examples=100, deadline=None)
@given(dims=st.lists(st.integers(1, 128), min_size=1, max_size=4))
def test_none_logical_always_replicates(dims):
    mesh = MESHES[0]
    spec = spec_for(tuple(dims), tuple([None] * len(dims)), mesh, DEFAULT_RULES)
    assert all(e is None for e in spec)
