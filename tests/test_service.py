"""Online burst-buffer service: no-fault bit-exactness, fault scenarios,
recovery accounting, admission control, and the arrival generators."""

import dataclasses

import numpy as np
import pytest

from repro.core import FleetSimulator, IONodeSimulator, ior, mixed, relabel
from repro.core.trace import Gap, TraceBatch
from repro.core.workloads import MiB, checkpoint_wave
from repro.service import (
    BurstBufferService,
    FaultEvent,
    FaultInjector,
    checkpoint_arrivals,
    poisson_arrivals,
    run_service_schemes,
    scripted,
    zipf_mix,
)

SCHEMES = ["orangefs", "orangefs-bb", "ssdup", "ssdup+"]
SMALL = 128 * MiB


def _apps(total=SMALL):
    return [
        relabel(ior("segmented-contiguous", 8, total_bytes=total, seed=1),
                app_id=0, file_id=0),
        relabel(ior("segmented-random", 8, total_bytes=total, seed=2),
                app_id=1, file_id=1),
        relabel(ior("strided", 16, total_bytes=total, seed=3),
                app_id=2, file_id=2),
    ]


@pytest.fixture(scope="module")
def offered():
    """Poisson-stamped mixed load with compute gaps in the middle."""

    items = list(mixed(*_apps(), burst_requests=256).trace)
    items.insert(400, Gap(3.0))
    items.insert(900, Gap(2.0))
    batch = TraceBatch.from_items(items)
    return poisson_arrivals(batch, rate_rps=2000.0, seed=11)


@pytest.fixture(scope="module")
def sustained():
    """Slower arrivals + all-random (SSD-bound) traffic on every lane:
    enough step samples for the straggler rule to trigger while work is
    still queued, and a service time that actually depends on the SSD."""

    apps = [
        ior("segmented-random", 8, total_bytes=256 * MiB,
            seed=i, app_id=i, file_id=i)
        for i in range(8)
    ]
    batch = TraceBatch.from_items(
        mixed(*apps, burst_requests=64, seed=9).trace
    )
    return poisson_arrivals(batch, rate_rps=300.0, seed=2)


# ---------------------------------------------------------------------------
# no-fault equivalence with the offline fleet
# ---------------------------------------------------------------------------


class TestHealthyBitExact:
    """Without faults or admission control the service is a re-timed
    delivery schedule over the same per-node replays: node results must
    equal ``FleetSimulator.run`` field for field."""

    @pytest.mark.parametrize("scheme", SCHEMES)
    def test_node_results_bit_identical(self, offered, scheme):
        kwargs = dict(num_nodes=4, policy="round-robin-app",
                      ssd_capacity=64 * MiB)
        svc = BurstBufferService(scheme=scheme, **kwargs).run(offered)
        off = FleetSimulator(scheme=scheme, **kwargs).run(offered)
        assert svc.node_results == off.node_results  # dataclass equality
        assert svc.fleet.total_bytes == off.total_bytes

    def test_healthy_ledger(self, offered):
        svc = BurstBufferService(
            scheme="ssdup+", num_nodes=4, ssd_capacity=64 * MiB
        ).run(offered)
        m = svc.metrics
        assert m.conservation_violations() == []
        assert m.completed_bytes == m.offered_bytes == offered.total_bytes
        assert m.unserved_bytes == m.rejected_bytes == 0
        assert m.stranded_bytes == m.replayed_bytes == 0
        assert m.degraded_seconds == 0.0
        assert m.healthy_seconds > 0.0
        assert m.faults == []

    def test_latency_percentiles_ordered(self, offered):
        m = BurstBufferService(
            scheme="ssdup+", num_nodes=4, ssd_capacity=64 * MiB
        ).run(offered).metrics
        assert len(m.latencies) == offered.num_requests
        assert 0.0 <= m.p50_latency <= m.p99_latency <= m.p999_latency

    def test_deterministic(self, offered):
        kwargs = dict(scheme="ssdup", num_nodes=4, ssd_capacity=64 * MiB)
        a = BurstBufferService(**kwargs).run(offered)
        b = BurstBufferService(**kwargs).run(offered)
        assert a.node_results == b.node_results
        assert a.metrics.makespan_seconds == b.metrics.makespan_seconds
        assert np.array_equal(a.metrics.latencies, b.metrics.latencies)


# ---------------------------------------------------------------------------
# crash + failover
# ---------------------------------------------------------------------------


class TestCrash:
    def test_crash_on_16_node_fleet_all_schemes(self, offered):
        """The ISSUE acceptance scenario: scripted crash on a 16-node
        fleet completes under every scheme with a clean ledger and
        reports tail latency + recovery time."""

        results = run_service_schemes(
            offered, num_nodes=16, policy="range-offset",
            ssd_capacity=32 * MiB, epoch_seconds=0.5,
            heartbeat_timeout=2.0,
            injector=FaultInjector.crash_at(1.0, 3),
        )
        for scheme, r in results.items():
            m = r.metrics
            assert m.conservation_violations() == [], scheme
            # survivors absorbed everything: nothing unserved or dropped
            assert m.completed_bytes == m.offered_bytes
            assert m.unserved_bytes == 0
            assert m.p999_latency >= m.p99_latency >= 0.0
            crash = [f for f in m.faults if f.kind == "crash"]
            assert len(crash) == 1
            f = crash[0]
            assert f.node == 3
            assert f.detected_at is not None
            assert f.detection_seconds >= 0.0
            assert f.recovery_seconds is not None
            assert m.recovery_seconds == f.recovery_seconds
            # the crashed lane stopped early: it served less than an
            # equal shard, the survivors picked up the difference
            assert len(r.node_results) == 16

    def test_backlog_replayed_on_takeover(self, offered):
        r = BurstBufferService(
            scheme="orangefs-bb", num_nodes=2, policy="range-offset",
            ssd_capacity=SMALL, epoch_seconds=0.5, heartbeat_timeout=2.0,
            injector=FaultInjector.crash_at(0.3, 1), replay=True,
        ).run(offered)
        m = r.metrics
        assert m.conservation_violations() == []
        assert m.replayed_bytes > 0
        assert m.stranded_bytes == 0
        f = m.faults[0]
        assert f.replayed_bytes == m.replayed_bytes
        # replay takes wall time on the takeover lane: recovery ends
        # strictly after detection
        assert f.recovered_at > f.detected_at

    def test_backlog_stranded_without_replay(self, offered):
        r = BurstBufferService(
            scheme="orangefs-bb", num_nodes=2, policy="range-offset",
            ssd_capacity=SMALL, epoch_seconds=0.5, heartbeat_timeout=2.0,
            injector=FaultInjector.crash_at(0.3, 1), replay=False,
        ).run(offered)
        m = r.metrics
        assert m.conservation_violations() == []
        assert m.stranded_bytes > 0
        assert m.replayed_bytes == 0
        assert m.faults[0].stranded_bytes == m.stranded_bytes

    def test_crash_marks_epochs_degraded(self, offered):
        m = BurstBufferService(
            scheme="ssdup+", num_nodes=4, ssd_capacity=64 * MiB,
            heartbeat_timeout=2.0, injector=FaultInjector.crash_at(1.0, 0),
        ).run(offered).metrics
        assert m.degraded_seconds > 0.0
        assert m.conservation_violations() == []


# ---------------------------------------------------------------------------
# stragglers and degraded SSDs
# ---------------------------------------------------------------------------


class TestStragglerAndDegrade:
    def test_slow_node_triggers_rebalance(self, sustained):
        r = BurstBufferService(
            scheme="ssdup+", num_nodes=8, ssd_capacity=64 * MiB,
            straggler_factor=1.5,
            injector=scripted((2.0, "slow", 2, 8.0)),
        ).run(sustained)
        m = r.metrics
        assert m.conservation_violations() == []
        assert m.completed_bytes == m.offered_bytes
        assert m.rebalanced_bytes > 0
        f = m.faults[0]
        assert f.kind == "slow" and f.detected_at is not None
        assert m.degraded_seconds > 0.0

    def test_ssd_degrade_changes_service_math(self, sustained):
        """A degraded SSD slows the node's *service* time, not just its
        wall clock (single node: no survivors to offload to)."""

        base = BurstBufferService(
            scheme="ssdup+", num_nodes=1, ssd_capacity=64 * MiB,
        ).run(sustained)
        deg = BurstBufferService(
            scheme="ssdup+", num_nodes=1, ssd_capacity=64 * MiB,
            injector=scripted((0.5, "ssd_degrade", 0, 0.1)),
        ).run(sustained)
        assert deg.metrics.conservation_violations() == []
        assert (deg.node_results[0].io_seconds
                > base.node_results[0].io_seconds)
        assert deg.metrics.degraded_seconds > 0.0

    def test_degraded_node_detected_and_offloaded(self, sustained):
        m = BurstBufferService(
            scheme="ssdup+", num_nodes=8, ssd_capacity=64 * MiB,
            straggler_factor=1.5,
            injector=scripted((2.0, "ssd_degrade", 2, 0.05)),
        ).run(sustained).metrics
        assert m.conservation_violations() == []
        assert m.rebalanced_bytes > 0
        assert m.faults[0].detected_at is not None


# ---------------------------------------------------------------------------
# stalls: transient full stops
# ---------------------------------------------------------------------------


class TestStall:
    def test_short_stall_invisible_to_controller(self, offered):
        m = BurstBufferService(
            scheme="ssdup+", num_nodes=4, ssd_capacity=64 * MiB,
            heartbeat_timeout=5.0,
            injector=scripted((1.0, "stall", 2, 1.0, 2.0)),
        ).run(offered).metrics
        assert m.conservation_violations() == []
        f = m.faults[0]
        assert f.kind == "stall"
        assert f.detected_at is None  # never declared dead
        assert m.stranded_bytes == m.replayed_bytes == 0
        assert m.completed_bytes == m.offered_bytes

    def test_long_stall_declared_dead_then_rejoins(self, sustained):
        m = BurstBufferService(
            scheme="ssdup+", num_nodes=4, ssd_capacity=64 * MiB,
            epoch_seconds=0.5, heartbeat_timeout=2.0,
            injector=scripted((0.5, "stall", 1, 1.0, 10.0)),
        ).run(sustained).metrics
        assert m.conservation_violations() == []
        f = m.faults[0]
        # stalled past the timeout: a (correct) false-positive death
        assert f.detected_at is not None
        assert f.detection_seconds >= 2.0
        assert f.recovered_at is not None
        assert m.completed_bytes == m.offered_bytes


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------


class TestAdmission:
    def test_redirect_serves_everything_via_hdd(self, offered):
        m = BurstBufferService(
            scheme="orangefs-bb", num_nodes=2, ssd_capacity=16 * MiB,
            admission_occupancy=0.5, admission_action="redirect",
        ).run(offered).metrics
        assert m.conservation_violations() == []
        assert m.redirected_bytes > 0
        assert m.completed_bytes == m.offered_bytes
        assert m.written_hdd_bytes >= m.redirected_bytes

    def test_reject_drops_but_ledger_balances(self, offered):
        m = BurstBufferService(
            scheme="orangefs-bb", num_nodes=2, ssd_capacity=16 * MiB,
            admission_occupancy=0.5, admission_action="reject",
        ).run(offered).metrics
        assert m.conservation_violations() == []
        assert m.rejected_bytes > 0
        assert m.completed_bytes + m.rejected_bytes == m.offered_bytes

    def test_validation(self):
        with pytest.raises(ValueError):
            BurstBufferService(admission_occupancy=1.5)
        with pytest.raises(ValueError):
            BurstBufferService(admission_action="tarpit")
        with pytest.raises(ValueError):
            BurstBufferService(num_nodes=0)
        with pytest.raises(ValueError):
            BurstBufferService(policy="by-vibes")
        with pytest.raises(ValueError):
            BurstBufferService(epoch_seconds=0.0)


# ---------------------------------------------------------------------------
# randomized robustness sweep
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_random_fault_sweep_conserves_bytes(offered, seed):
    """Seeded random multi-fault scenarios: whatever the script does, the
    byte ledgers must balance and the loop must terminate."""

    inj = FaultInjector.random(
        seed=seed, num_nodes=8, horizon_seconds=3.0,
        crashes=1, slows=1, degrades=1, stalls=1, stall_seconds=4.0,
    )
    m = BurstBufferService(
        scheme="ssdup+", num_nodes=8, policy="range-offset",
        ssd_capacity=32 * MiB, epoch_seconds=0.5, heartbeat_timeout=2.0,
        injector=inj,
    ).run(offered).metrics
    assert m.conservation_violations() == []
    assert len(m.faults) == 4


# ---------------------------------------------------------------------------
# fault injector
# ---------------------------------------------------------------------------


class TestInjector:
    def test_event_validation(self):
        with pytest.raises(ValueError):
            FaultEvent(at=1.0, kind="meteor", node=0)
        with pytest.raises(ValueError):
            FaultEvent(at=-1.0, kind="crash", node=0)
        with pytest.raises(ValueError):
            FaultEvent(at=1.0, kind="slow", node=0, factor=0.5)
        with pytest.raises(ValueError):
            FaultEvent(at=1.0, kind="ssd_degrade", node=0, factor=2.0)
        with pytest.raises(ValueError):
            FaultEvent(at=1.0, kind="stall", node=0, duration=0.0)

    def test_scripted_sorts_by_time(self):
        inj = scripted(
            (5.0, "crash", 1), (1.0, "slow", 0, 3.0),
            FaultEvent(at=3.0, kind="stall", node=2, duration=1.0),
        )
        assert [e.at for e in inj] == [1.0, 3.0, 5.0]
        assert len(inj) == 3

    def test_random_is_seeded_and_counted(self):
        a = FaultInjector.random(7, num_nodes=8, horizon_seconds=10.0,
                                 crashes=2, slows=2, stalls=1)
        b = FaultInjector.random(7, num_nodes=8, horizon_seconds=10.0,
                                 crashes=2, slows=2, stalls=1)
        assert a.events == b.events
        kinds = [e.kind for e in a]
        assert kinds.count("crash") == 2 and kinds.count("stall") == 1
        # within one kind, nodes are distinct
        crash_nodes = [e.node for e in a if e.kind == "crash"]
        assert len(set(crash_nodes)) == 2
        with pytest.raises(ValueError):
            FaultInjector.random(0, num_nodes=2, horizon_seconds=1.0,
                                 crashes=3)


# ---------------------------------------------------------------------------
# arrival generators
# ---------------------------------------------------------------------------


class TestArrivals:
    def test_poisson_preserves_everything_but_times(self):
        wl = mixed(*_apps(), burst_requests=256)
        base = TraceBatch.from_items(list(wl.trace))
        stamped = poisson_arrivals(base, rate_rps=500.0, seed=3)
        assert np.array_equal(stamped.offsets, base.offsets)
        assert np.array_equal(stamped.sizes, base.sizes)
        assert np.array_equal(stamped.gap_positions, base.gap_positions)
        assert np.all(np.diff(stamped.times) > 0)  # strictly increasing
        assert stamped.times[0] > 0.0
        # mean inter-arrival ~ 1/rate
        mean_gap = float(np.diff(stamped.times).mean())
        assert mean_gap == pytest.approx(1 / 500.0, rel=0.2)
        with pytest.raises(ValueError):
            poisson_arrivals(base, rate_rps=0.0)

    def test_zipf_mix_preserves_requests_and_order(self):
        apps = _apps(total=8 * MiB)
        batch = zipf_mix(apps, rate_rps=1000.0, s=1.2, seed=4)
        n_expected = sum(
            sum(1 for r in w.trace if hasattr(r, "offset")) for w in apps
        )
        assert batch.num_requests == n_expected
        # per-app internal order preserved
        for k, w in enumerate(apps):
            mine = batch.offsets[batch.app_ids == k]
            orig = [r.offset for r in w.trace if hasattr(r, "offset")]
            assert np.array_equal(mine, np.array(orig))
        # hot app (k=0) tends to finish arriving earlier than the tail app
        last0 = np.max(np.nonzero(batch.app_ids == 0))
        last2 = np.max(np.nonzero(batch.app_ids == 2))
        assert last0 < last2
        b2 = zipf_mix(apps, rate_rps=1000.0, s=1.2, seed=4)
        assert np.array_equal(b2.offsets, batch.offsets)
        with pytest.raises(ValueError):
            zipf_mix([], rate_rps=100.0)

    def test_checkpoint_arrivals_waves_and_gaps(self):
        batch = checkpoint_arrivals(
            8, waves=3, compute_seconds=20.0, seed=1,
            bytes_per_wave=16 * MiB,
        )
        assert len(batch.gap_seconds) == 2  # waves - 1 compute phases
        assert np.all(batch.gap_seconds == 20.0)
        assert batch.total_bytes == 3 * 16 * MiB
        assert np.all(np.diff(batch.times) >= 0)

    def test_checkpoint_wave_rotates_files(self):
        wl = checkpoint_wave(4, waves=4, bytes_per_wave=4 * MiB,
                             rotate_files=2, file_id=10)
        fids = {r.file_id for r in wl.trace if hasattr(r, "offset")}
        assert fids == {10, 11}
        with pytest.raises(ValueError):
            checkpoint_wave(4, waves=0)


# ---------------------------------------------------------------------------
# incremental session API (the simulator-side tentpole hook)
# ---------------------------------------------------------------------------


class TestSessionAPI:
    def test_requires_batched_engine(self):
        sim = IONodeSimulator(scheme="ssdup+", engine="per-request")
        with pytest.raises(ValueError):
            sim.begin_session()

    def test_double_begin_and_missing_session(self):
        sim = IONodeSimulator(scheme="ssdup+", engine="batched")
        sim.begin_session()
        with pytest.raises(RuntimeError):
            sim.begin_session()
        sim.end_session()
        with pytest.raises(RuntimeError):
            sim.feed_gap(1.0)

    def test_oversized_window_rejected(self):
        sim = IONodeSimulator(scheme="ssdup+", engine="batched",
                              stream_len=4)
        sim.begin_session()
        n = 5
        with pytest.raises(ValueError):
            sim.feed_window(
                np.arange(n) * 4096, np.full(n, 4096),
                np.zeros(n, dtype=np.int64), np.zeros(n, dtype=np.int64),
            )

    def test_fed_sessions_match_offline_run(self, offered):
        """Feeding the offline engine's exact window/gap interleaving
        reproduces run() bit for bit — the invariant the service's
        no-fault equality is built on."""

        for scheme in SCHEMES:
            off = IONodeSimulator(
                scheme=scheme, ssd_capacity=64 * MiB, engine="batched"
            ).run(offered)
            sim = IONodeSimulator(
                scheme=scheme, ssd_capacity=64 * MiB, engine="batched"
            )
            svc = BurstBufferService(
                scheme=scheme, num_nodes=1, ssd_capacity=64 * MiB
            )
            sim.begin_session()
            for kind, payload in svc._build_queue(offered):
                if kind == "gap":
                    sim.feed_gap(payload)
                else:
                    sim.feed_window(payload.offsets, payload.sizes,
                                    payload.file_ids, payload.app_ids)
            assert sim.end_session() == off, scheme

    def test_empty_window_is_noop(self):
        sim = IONodeSimulator(scheme="ssdup+", engine="batched")
        sim.begin_session()
        z = np.zeros(0, dtype=np.int64)
        assert sim.feed_window(z, z, z, z) == 0.0
        res = sim.end_session()
        assert res.total_bytes == 0


# ---------------------------------------------------------------------------
# result plumbing
# ---------------------------------------------------------------------------


class TestResultPlumbing:
    def test_fleet_view_matches_node_results(self, offered):
        r = BurstBufferService(
            scheme="ssdup+", num_nodes=4, ssd_capacity=64 * MiB
        ).run(offered)
        fl = r.fleet
        assert fl.num_nodes == 4
        assert fl.total_bytes == sum(
            n.total_bytes for n in r.node_results
        )

    def test_service_result_frozen(self, offered):
        r = BurstBufferService(
            scheme="orangefs", num_nodes=2
        ).run(offered)
        with pytest.raises(dataclasses.FrozenInstanceError):
            r.scheme = "other"
