"""BENCH perf-trajectory artifact: schema, anchors, regression gate,
atomic + merging writes for both artifacts."""

import json
import os
import sys

import pytest

from repro.testing import perf
from repro.testing.perf import (
    atomic_write_text,
    build_trajectory,
    check_trajectory,
    emit_trajectory,
    find_anchor,
    merge_csv,
)

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.common import Row  # noqa: E402


class TestBuildTrajectory:
    ROWS = {"fleet": {"fleet_a": 100.0, "fleet_b": 300.0},
            "replay": {"replay_x": 50.0}}

    def test_schema_and_suite_fields(self):
        t = build_trajectory(self.ROWS, index=6)
        assert t["schema"] == "bench-trajectory/v1"
        assert t["index"] == 6
        assert t["anchor"] is None
        assert t["regression_threshold"] == pytest.approx(0.15)
        fleet = t["suites"]["fleet"]
        assert fleet["us_per_call"] == pytest.approx(400.0)
        assert fleet["rows"] == self.ROWS["fleet"]
        assert fleet["speedup_vs_anchor"] is None
        assert fleet["regression"] is False
        assert t["any_regression"] is False

    def _anchor(self, scale):
        return build_trajectory(
            {s: {k: v * scale for k, v in rows.items()}
             for s, rows in self.ROWS.items()})

    def test_speedup_vs_anchor(self):
        # anchor was 2x slower -> speedup 2.0, no regression
        t = build_trajectory(self.ROWS, anchor_payload=self._anchor(2.0),
                             anchor_name="BENCH_5.json")
        assert t["anchor"] == "BENCH_5.json"
        assert t["suites"]["fleet"]["speedup_vs_anchor"] == pytest.approx(2.0)
        assert not t["any_regression"]

    def test_regression_flag_at_threshold(self):
        # anchor 25% faster -> speedup 0.8 < 0.85 -> regression
        t = build_trajectory(self.ROWS, anchor_payload=self._anchor(0.8))
        assert t["suites"]["fleet"]["speedup_vs_anchor"] == pytest.approx(0.8)
        assert t["suites"]["fleet"]["regression"] is True
        assert t["any_regression"] is True
        assert check_trajectory(t) != []

    def test_within_threshold_not_flagged(self):
        # 10% slowdown stays inside the +/-15% band
        t = build_trajectory(self.ROWS, anchor_payload=self._anchor(0.9))
        assert t["suites"]["fleet"]["regression"] is False
        assert check_trajectory(t) == []

    def test_only_matched_rows_compared(self):
        anchor = build_trajectory(
            {"fleet": {"fleet_a": 10.0, "fleet_gone": 1.0}})
        t = build_trajectory({"fleet": {"fleet_a": 100.0,
                                        "fleet_new": 9999.0}},
                             anchor_payload=anchor)
        fleet = t["suites"]["fleet"]
        assert fleet["matched_rows"] == 1
        assert fleet["speedup_vs_anchor"] == pytest.approx(0.1)

    def test_suite_absent_from_anchor(self):
        anchor = build_trajectory({"fleet": {"fleet_a": 10.0}})
        t = build_trajectory({"replay": {"replay_x": 1.0}},
                             anchor_payload=anchor)
        assert t["suites"]["replay"]["speedup_vs_anchor"] is None


class TestAnchorsAndEmission:
    def test_find_anchor_picks_highest_below_index(self, tmp_path):
        for k in (2, 4, 9):
            (tmp_path / f"BENCH_{k}.json").write_text("{}")
        assert find_anchor(tmp_path, 6)[0] == 4
        assert find_anchor(tmp_path, 10)[0] == 9
        assert find_anchor(tmp_path, 2) is None

    def test_emit_injected_regression_roundtrip(self, tmp_path):
        """A synthetic 2x-faster anchor must trip the gate on emit."""

        anchor = build_trajectory({"fleet": {"fleet_a": 50.0}}, index=5)
        (tmp_path / "BENCH_5.json").write_text(json.dumps(anchor))
        path, payload = emit_trajectory({"fleet": {"fleet_a": 100.0}},
                                        directory=tmp_path, index=6)
        assert path.name == "BENCH_6.json"
        assert payload["anchor"] == "BENCH_5.json"
        assert payload["any_regression"] is True
        assert "fleet" in check_trajectory(payload)[0]
        on_disk = json.loads(path.read_text())
        assert on_disk == payload

    def test_partial_emit_merges_existing_suites(self, tmp_path):
        emit_trajectory({"fleet": {"a": 1.0}, "replay": {"b": 2.0}},
                        directory=tmp_path, index=6)
        _, payload = emit_trajectory({"fleet": {"a": 3.0}},
                                     directory=tmp_path, index=6)
        assert set(payload["suites"]) == {"fleet", "replay"}
        assert payload["suites"]["fleet"]["rows"] == {"a": 3.0}
        assert payload["suites"]["replay"]["rows"] == {"b": 2.0}


class TestRunCheckExit:
    """--check must exit nonzero on an injected regression, end to end."""

    def _patched_run(self, monkeypatch, tmp_path, us):
        import benchmarks.run as run

        monkeypatch.setitem(run.SUITES, "dummy",
                            lambda tb: [Row("dummy_row", us, "d=1")])
        return lambda argv: run.main(
            argv + ["--only", "dummy", "--out-dir", str(tmp_path)])

    def test_check_fails_on_regression(self, monkeypatch, tmp_path, capsys):
        anchor = build_trajectory({"dummy": {"dummy_row": 10.0}}, index=5)
        (tmp_path / "BENCH_5.json").write_text(json.dumps(anchor))
        main = self._patched_run(monkeypatch, tmp_path, us=100.0)
        assert main(["--check"]) == 1
        assert "REGRESSION" in capsys.readouterr().err

    def test_check_passes_without_anchor(self, monkeypatch, tmp_path):
        main = self._patched_run(monkeypatch, tmp_path, us=100.0)
        assert main(["--check"]) == 0
        assert (tmp_path / perf.bench_filename(perf.CURRENT_INDEX)).exists()

    def test_check_passes_on_improvement(self, monkeypatch, tmp_path):
        anchor = build_trajectory({"dummy": {"dummy_row": 1000.0}}, index=5)
        (tmp_path / "BENCH_5.json").write_text(json.dumps(anchor))
        main = self._patched_run(monkeypatch, tmp_path, us=100.0)
        assert main(["--check"]) == 0


class TestAtomicWrites:
    def test_atomic_write_replaces_and_cleans_up(self, tmp_path):
        target = tmp_path / "out.csv"
        target.write_text("old\n")
        atomic_write_text(target, "new\n")
        assert target.read_text() == "new\n"
        assert [p.name for p in tmp_path.iterdir()] == ["out.csv"]

    def test_interrupted_build_leaves_previous_file(self, tmp_path):
        """The text is fully built before the write: a row that raises
        mid-iteration can never truncate the committed CSV."""

        target = tmp_path / "bench_results.csv"
        atomic_write_text(target, merge_csv(None, [Row("a", 1.0, "x=1")]))
        before = target.read_text()

        class Exploding:
            name = "boom"

            def csv(self):
                raise RuntimeError("interrupted")

        with pytest.raises(RuntimeError):
            atomic_write_text(target, merge_csv(before, [Exploding()]))
        assert target.read_text() == before
        assert [p.name for p in tmp_path.iterdir()] == [target.name]

    def test_merge_csv_preserves_unrun_suites(self):
        existing = ("name,us_per_call,derived\n"
                    "fleet_a,1.000,x=1\n"
                    "replay_b,2.000,y=2\n")
        merged = merge_csv(existing, [Row("fleet_a", 9.0, "x=9"),
                                      Row("new_c", 3.0, "z=3")])
        lines = merged.strip().splitlines()
        assert lines[0] == "name,us_per_call,derived"
        assert lines[1] == "fleet_a,9.000,x=9"      # replaced in place
        assert lines[2] == "replay_b,2.000,y=2"     # preserved
        assert lines[3] == "new_c,3.000,z=3"        # appended

    def test_merge_csv_from_scratch(self):
        merged = merge_csv(None, [Row("a", 1.0, "d=1")])
        assert merged == "name,us_per_call,derived\na,1.000,d=1\n"


def test_current_index_matches_committed_artifact():
    """experiments/BENCH_<CURRENT_INDEX>.json is the committed artifact."""

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, "experiments",
                        perf.bench_filename(perf.CURRENT_INDEX))
    assert os.path.exists(path), path
    with open(path) as f:
        payload = json.load(f)
    assert payload["schema"] == "bench-trajectory/v1"
    assert payload["index"] == perf.CURRENT_INDEX
    for suite in payload["suites"].values():
        assert suite["us_per_call"] > 0
        assert "speedup_vs_anchor" in suite
        assert "regression" in suite
