"""AVL tree + log store tests (paper Section 2.5), incl. hypothesis invariants."""

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # deterministic no-shrink fallback, same API surface
    from _hypothesis_fallback import given, settings, st

from repro.core import AVLTree, LogRegion, RegionFullError
from repro.core.avl import NODE_BYTES


class TestAVL:
    def test_insert_lookup(self):
        t = AVLTree()
        t.insert(100, 10, 0)
        t.insert(50, 10, 10)
        t.insert(150, 10, 20)
        assert t.lookup(50).log_offset == 10
        assert t.lookup(100).log_offset == 0
        assert t.lookup(999) is None
        assert len(t) == 3

    def test_in_order_is_sorted_by_original_offset(self):
        t = AVLTree()
        for i, off in enumerate([500, 100, 900, 300, 700]):
            t.insert(off, 10, i * 10)
        keys = [e.offset for e in t.in_order()]
        assert keys == sorted(keys) == [100, 300, 500, 700, 900]

    def test_rewrite_same_offset_latest_wins(self):
        t = AVLTree()
        t.insert(100, 10, 0)
        t.insert(100, 10, 40)  # newer log copy
        assert len(t) == 1
        assert t.lookup(100).log_offset == 40

    def test_height_logarithmic_on_sequential_inserts(self):
        # a plain BST would degenerate to height n here
        t = AVLTree()
        n = 1024
        for i in range(n):
            t.insert(i, 1, i)
        assert t.height <= 1.45 * 10 + 2  # 1.44*log2(n) + O(1)
        t.check_invariants()

    def test_paper_metadata_accounting(self):
        """Paper: 40 GB of 256 KB requests -> ~3 MB of AVL metadata."""

        t = AVLTree()
        req = 256 * 1024
        n = (40 << 30) // req  # 163840 nodes
        # insert a representative subset, then scale the accounting
        for i in range(n // 64):
            t.insert(i * req, req, i * req)
        assert t.approx_bytes() == len(t) * NODE_BYTES
        full_bytes = n * NODE_BYTES
        assert 3_500_000 <= full_bytes <= 4_200_000  # ~3.75 MiB ~ paper's "about 3MB"

    def test_min_max(self):
        t = AVLTree()
        assert t.min_key() is None and t.max_key() is None
        for off in [5, 1, 9]:
            t.insert(off, 1, 0)
        assert t.min_key() == 1 and t.max_key() == 9


@settings(max_examples=150, deadline=None)
@given(st.lists(st.integers(0, 10_000), min_size=1, max_size=300))
def test_property_avl_invariants(keys):
    """Balance, BST order, height bookkeeping and count hold under any
    insertion sequence, including duplicates."""

    t = AVLTree()
    for i, k in enumerate(keys):
        t.insert(k, 1, i)
    t.check_invariants()
    assert len(t) == len(set(keys))
    in_order = [e.offset for e in t.in_order()]
    assert in_order == sorted(set(keys))
    # latest duplicate wins
    last = {}
    for i, k in enumerate(keys):
        last[k] = i
    for k, i in last.items():
        assert t.lookup(k).log_offset == i


class TestLogRegion:
    def test_append_and_flush_order(self):
        r = LogRegion(1000)
        r.append(file_id=1, offset=500, size=100)
        r.append(file_id=1, offset=100, size=100)
        r.append(file_id=0, offset=900, size=100)
        order = list(r.flush_order())
        # files ascending, offsets ascending within file
        assert [(f, e.offset) for f, e in order] == [(0, 900), (1, 100), (1, 500)]

    def test_capacity_enforced(self):
        r = LogRegion(250)
        r.append(0, 0, 100)
        r.append(0, 100, 100)
        assert not r.fits(100)
        with pytest.raises(RegionFullError):
            r.append(0, 200, 100)

    def test_seek_counts_sorted_vs_unsorted(self):
        """The AVL order must never need more seeks than arrival order."""

        r = LogRegion(10_000)
        # reverse arrival of a contiguous range: unsorted = n seeks, sorted = 1
        for off in reversed(range(0, 1000, 100)):
            r.append(0, off, 100)
        assert r.seek_count_sorted() == 1
        assert r.seek_count_if_unsorted() == 10
        assert r.seek_count_sorted() <= r.seek_count_if_unsorted()

    def test_flush_bytes_deduplicates(self):
        r = LogRegion(10_000)
        r.append(0, 0, 100)
        r.append(0, 0, 100)  # rewrite
        assert r.used_bytes == 200  # log grows
        assert r.flush_bytes() == 100  # only the live copy flushes

    def test_reset(self):
        r = LogRegion(1000)
        r.append(0, 0, 100)
        r.reset()
        assert r.used_bytes == 0
        assert r.flush_bytes() == 0
        assert list(r.flush_order()) == []


@settings(max_examples=100, deadline=None)
@given(
    st.lists(
        st.tuples(st.integers(0, 3), st.integers(0, 50), st.integers(1, 16)),
        min_size=1,
        max_size=200,
    )
)
def test_property_log_region_flush_conservation(items):
    """Every live (file, offset) extent appears in flush order exactly once,
    and the sorted flush never costs more seeks than arrival order."""

    r = LogRegion(1 << 20)
    live = {}
    for fid, slot, size in items:
        off = slot * 64  # avoid pathological overlap aliasing
        r.append(fid, off, size)
        live[(fid, off)] = size
    flushed = {(fid, e.offset): e.size for fid, e in r.flush_order()}
    assert flushed == live
    assert r.seek_count_sorted() <= max(r.seek_count_if_unsorted(), 1)
