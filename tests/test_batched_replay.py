"""Batched replay engine ≡ per-request oracle, plus flush-path bugfixes.

The batched engine routes and accounts whole streams (no per-request
Python on the SSD path); these tests assert its :class:`SimResult` is
**bit-identical** to the per-request oracle on every scheme, including
the hard corners: region swaps, blocked writers, plain-BB overflow,
compute gaps, trailing partial streams, and both index backends.

The bugfix sweep is locked in alongside:

* compute-gap flushing continues through the backlog (not just the
  current job);
* flush time charges Eq. 6's residual seeks on every drain path;
* ``SimResult.app_throughput_mbs`` guards ``io_seconds == 0``;
* ``TwoRegionPipeline.drain()`` returns and forces backlog jobs.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import (
    Gap,
    IONodeSimulator,
    Request,
    TraceBatch,
    TwoRegionPipeline,
    compute_stream_scores,
    ior,
    mixed,
    relabel,
)
from repro.core.device_model import HDDModel
from repro.core.workloads import GiB, MiB

SMALL = 128 * MiB
SCHEMES = ("orangefs", "orangefs-bb", "ssdup", "ssdup+")


def assert_results_identical(a, b, context=""):
    for f in dataclasses.fields(a):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        assert va == vb, f"{context}{f.name}: {va!r} != {vb!r}"


@pytest.fixture(scope="module")
def mixed_trace():
    w1 = relabel(ior("segmented-contiguous", 8, total_bytes=SMALL, seed=1),
                 app_id=0, file_id=0)
    w2 = relabel(ior("segmented-random", 8, total_bytes=SMALL, seed=2),
                 app_id=1, file_id=1)
    w3 = relabel(ior("strided", 32, total_bytes=SMALL, seed=3),
                 app_id=2, file_id=2)
    return list(mixed(w1, w2, w3, burst_requests=256).trace)


@pytest.fixture(scope="module")
def gapped_trace():
    wa = relabel(ior("segmented-random", 16, total_bytes=SMALL, seed=5),
                 app_id=0, file_id=0)
    wb = relabel(ior("strided", 64, total_bytes=SMALL, seed=6),
                 app_id=1, file_id=1)
    # gaps mid-trace AND trailing, plus a partial final stream
    return (
        list(wa.trace) + [Gap(2.0)] + list(wb.trace)[:-37] + [Gap(7.5)]
    )


class TestEngineEquivalence:
    """batched == per-request, field for field, bit for bit."""

    @pytest.mark.parametrize("scheme", SCHEMES)
    def test_mixed_load(self, mixed_trace, scheme):
        cap = SMALL  # constrained: forces swaps / blocks / BB overflow
        a = IONodeSimulator(scheme=scheme, ssd_capacity=cap,
                            engine="per-request").run(mixed_trace)
        b = IONodeSimulator(scheme=scheme, ssd_capacity=cap,
                            engine="batched").run(mixed_trace)
        assert_results_identical(a, b, f"{scheme}: ")

    @pytest.mark.parametrize("scheme", SCHEMES)
    def test_gaps_and_partial_tail(self, gapped_trace, scheme):
        cap = SMALL // 2
        a = IONodeSimulator(scheme=scheme, ssd_capacity=cap,
                            engine="per-request").run(gapped_trace)
        b = IONodeSimulator(scheme=scheme, ssd_capacity=cap,
                            engine="batched").run(gapped_trace)
        assert_results_identical(a, b, f"{scheme}: ")

    @pytest.mark.parametrize("index_backend", ["avl", "numpy"])
    def test_index_backends_identical(self, mixed_trace, index_backend):
        """Either backend under either engine: same SimResult."""

        cap = SMALL
        ref = IONodeSimulator(scheme="ssdup+", ssd_capacity=cap,
                              engine="per-request",
                              index_backend="avl").run(mixed_trace)
        got = IONodeSimulator(scheme="ssdup+", ssd_capacity=cap,
                              engine="batched",
                              index_backend=index_backend).run(mixed_trace)
        assert_results_identical(ref, got, f"{index_backend}: ")

    def test_trace_batch_input_equivalent(self, mixed_trace):
        """run() accepts a TraceBatch directly (the fleet hot path)."""

        batch = TraceBatch.from_items(mixed_trace)
        a = IONodeSimulator(scheme="ssdup+", ssd_capacity=SMALL).run(mixed_trace)
        b = IONodeSimulator(scheme="ssdup+", ssd_capacity=SMALL).run(batch)
        assert_results_identical(a, b)

    def test_precomputed_scores_equivalent(self, mixed_trace):
        scores = compute_stream_scores(mixed_trace)
        a = IONodeSimulator(scheme="ssdup+", ssd_capacity=SMALL).run(
            mixed_trace)
        b = IONodeSimulator(scheme="ssdup+", ssd_capacity=SMALL).run(
            mixed_trace, scores=scores)
        assert_results_identical(a, b)

    def test_unknown_engine_rejected(self):
        with pytest.raises(ValueError, match="engine"):
            IONodeSimulator(engine="turbo")


class TestGapBacklogDrain:
    """Bugfix: a compute gap keeps draining into the flush backlog."""

    def _sim_with_two_full_regions(self):
        cap = 8 * MiB
        sim = IONodeSimulator(scheme="ssdup+", ssd_capacity=cap)
        rng = np.random.default_rng(0)
        for region in sim.pipeline.regions:
            # discontiguous 64 KiB extents -> residual seeks > 0
            for i, slot in enumerate(rng.permutation(2 * (cap // 2) // (64 << 10))[
                    : (cap // 2) // (64 << 10)]):
                region.append(0, int(slot) * (128 << 10), 64 << 10)
        sim.pipeline.drain()  # job on R0, backlog holds R1
        assert sim.pipeline.flush_job is not None
        assert len(sim.pipeline._flush_backlog) == 1
        return sim

    def test_long_gap_drains_both_regions(self):
        sim = self._sim_with_two_full_regions()
        jobs = sim.pipeline.drain()
        need = sum(j.service_seconds(sim.hdd) for j in jobs)
        res = sim.run([Gap(need * 2)])
        assert sim.pipeline.buffered_bytes == 0
        assert res.flushes == 2
        assert res.io_seconds == 0.0
        assert res.total_seconds == pytest.approx(need * 2)

    def test_short_gap_progress_is_not_discarded(self):
        """The gap budget left after finishing job 1 must flow into job 2."""

        sim = self._sim_with_two_full_regions()
        job1 = sim.pipeline.flush_job
        rate1 = job1.effective_rate(sim.hdd)
        t1 = job1.bytes_total / rate1
        extra = t1 / 2
        sim.run([Gap(t1 + extra)])
        # job 1 completed AND job 2 absorbed the leftover budget
        assert sim.pipeline.flushes_completed >= 2  # finalize drains the rest
        # the stronger check: before finalize, progress carried over — use
        # the pipeline state mid-run via a fresh sim and _gap directly
        sim2 = self._sim_with_two_full_regions()
        job1 = sim2.pipeline.flush_job
        rate1 = job1.effective_rate(sim2.hdd)
        t1 = job1.bytes_total / rate1
        from repro.core.simulator import _ReplayState

        st = _ReplayState()
        sim2._gap(st, t1 + extra)
        assert sim2.pipeline.flushes_completed == 1
        job2 = sim2.pipeline.flush_job
        assert job2 is not None
        expected = int(job2.effective_rate(sim2.hdd) * (t1 + extra - t1))
        assert job2.bytes_done == pytest.approx(expected, abs=2)


class TestEq6FlushCost:
    """Bugfix: residual seeks are charged on every flush drain path."""

    def test_service_seconds_formula(self):
        hdd = HDDModel()
        p = TwoRegionPipeline(1 << 20)
        p.regions[0].append(0, 0, 4096)
        p.regions[0].append(0, 65536, 4096)  # gap -> 2 residual seeks
        jobs = p.drain()
        job = jobs[0]
        assert job.seeks == 2
        assert job.service_seconds(hdd) == pytest.approx(
            2 * hdd.seek_time + 8192 / hdd.seq_bw
        )
        assert job.effective_rate(hdd) < hdd.seq_bw

    def test_final_drain_charges_seeks(self):
        """An end-of-trace drain is slower than bytes/seq_bw alone."""

        sim = IONodeSimulator(scheme="ssdup+", ssd_capacity=8 * MiB)
        region = sim.pipeline.regions[0]
        n, sz = 32, 64 << 10
        for i in range(n):
            region.append(0, i * 2 * sz, sz)  # every extent discontiguous
        res = sim.run([])
        expected = n * sim.hdd.seek_time + n * sz / sim.hdd.seq_bw
        assert res.total_seconds == pytest.approx(expected)
        assert res.total_seconds > n * sz / sim.hdd.seq_bw

    def test_blocked_writer_drain_charges_seeks(self):
        """drain_current_flush (writer blocked) pays Eq. 6 too."""

        sim = IONodeSimulator(scheme="ssdup+", ssd_capacity=8 * MiB)
        region = sim.pipeline.regions[0]
        n, sz = 16, 64 << 10
        for i in range(n):
            region.append(0, i * 2 * sz, sz)
        sim.pipeline.drain()
        job = sim.pipeline.flush_job
        from repro.core.simulator import _ReplayState

        st = _ReplayState()
        dt = sim._drain_current_flush(st)
        assert dt == pytest.approx(job.service_seconds(sim.hdd))
        assert dt > job.bytes_total / sim.hdd.seq_bw


class TestAppThroughputGuard:
    """Bugfix: io_seconds == 0 must not raise ZeroDivisionError."""

    def test_gap_only_trace(self):
        res = IONodeSimulator(scheme="ssdup+").run([Gap(5.0)])
        assert res.io_seconds == 0.0
        assert res.throughput_mbs == 0.0
        assert res.app_throughput_mbs(0) == 0.0  # raised before the fix

    def test_empty_trace(self):
        res = IONodeSimulator(scheme="orangefs").run([])
        assert res.app_throughput_mbs(42) == 0.0

    def test_nonzero_path_unchanged(self):
        w = ior("strided", 16, total_bytes=16 * MiB)
        res = IONodeSimulator(scheme="orangefs").run(list(w.trace))
        assert res.app_throughput_mbs(0) == pytest.approx(
            res.per_app_bytes[0] / res.io_seconds / 1e6
        )


class TestDrainReturnsBacklog:
    """Bugfix: drain() returns and forces the backlog jobs too."""

    def test_all_jobs_returned_and_forced(self):
        p = TwoRegionPipeline(1 << 20)
        p.regions[0].append(0, 0, 1000)
        p.regions[1].append(1, 0, 2000)
        jobs = p.drain()
        assert len(jobs) == 2
        assert all(j.forced for j in jobs)
        assert {j.region for j in jobs} == set(p.regions)
        # draining the returned jobs empties everything with no extra force
        for job in jobs:
            assert p.flush_job is job
            p.flush_progress(job.bytes_left)
        assert p.flush_job is None
        assert p.buffered_bytes == 0
        assert p.flushes_completed == 2

    def test_drain_idempotent(self):
        p = TwoRegionPipeline(1 << 20)
        p.regions[0].append(0, 0, 1000)
        assert len(p.drain()) == 1
        assert len(p.drain()) == 1  # re-drain does not double-schedule


@pytest.mark.slow
class TestMillionRequestReplay:
    """The batched engine at the scale the seed could not reach."""

    def test_million_request_trace_replays_and_conserves(self):
        rng = np.random.default_rng(7)
        n = 1_000_000
        sz = 64 << 10
        batch = TraceBatch(
            offsets=rng.integers(0, 1 << 38, size=n).astype(np.int64),
            sizes=np.full(n, sz, dtype=np.int64),
            file_ids=rng.integers(0, 8, size=n).astype(np.int64),
            app_ids=rng.integers(0, 4, size=n).astype(np.int64),
            times=np.zeros(n),
            gap_positions=np.asarray([n // 2], dtype=np.int64),
            gap_seconds=np.asarray([30.0]),
        )
        res = IONodeSimulator(scheme="ssdup+", ssd_capacity=4 * GiB).run(batch)
        assert res.total_bytes == n * sz
        assert res.bytes_to_ssd + res.bytes_to_hdd_direct == res.total_bytes
        assert res.io_seconds > 0
        assert sum(res.per_app_bytes.values()) == res.total_bytes

    def test_large_trace_matches_oracle(self):
        """100k-request spot check of bit-exactness at scale."""

        rng = np.random.default_rng(11)
        n = 100_000
        reqs = [
            Request(offset=int(o), size=256 << 10, file_id=int(f),
                    app_id=int(ap))
            for o, f, ap in zip(
                rng.integers(0, 1 << 34, size=n),
                rng.integers(0, 4, size=n),
                rng.integers(0, 2, size=n),
            )
        ]
        cap = 2 * GiB
        a = IONodeSimulator(scheme="ssdup+", ssd_capacity=cap,
                            engine="per-request").run(reqs)
        b = IONodeSimulator(scheme="ssdup+", ssd_capacity=cap,
                            engine="batched").run(reqs)
        assert_results_identical(a, b)
