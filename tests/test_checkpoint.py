"""Checkpoint substrate: tiered store round-trip, async pipeline, restart,
elastic partial loads."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, TieredCheckpointStore


def tree_of(seed: int, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return {
        "params": {
            "emb": rng.normal(size=(64, 16)).astype(dtype),
            "layers": {"w": rng.normal(size=(4, 16, 32)).astype(dtype)},
        },
        "step": np.asarray(seed, np.int32),
    }


def assert_tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class TestTieredStore:
    def test_round_trip(self, tmp_path):
        store = TieredCheckpointStore(str(tmp_path), host_id=0)
        t = tree_of(1)
        store.save(10, t)
        assert_tree_equal(store.load(10), t)

    def test_round_trip_shuffled_contention(self, tmp_path):
        """Heavy-contention arrival (fast-tier log + AVL flush) must still
        reassemble bit-exactly — the §2.5 correctness property.  The tree is
        sized to produce hundreds of chunks so real streams form."""

        rng = np.random.default_rng(2)
        t = {"params": {"emb": rng.normal(size=(512, 256)).astype(np.float32),
                        "w": rng.normal(size=(8, 128, 128)).astype(np.float32)}}
        store = TieredCheckpointStore(str(tmp_path), host_id=0,
                                      region_bytes=1 << 18)
        stats = store.save(3, t, writers=-1, chunk=1 << 12)
        assert stats["bytes_fast"] > 0  # random traffic rode the fast tier
        assert_tree_equal(store.load(3), t)

    def test_latest_step_and_commit_point(self, tmp_path):
        store = TieredCheckpointStore(str(tmp_path), host_id=0)
        assert store.latest_step() is None
        store.save(5, tree_of(5))
        store.save(9, tree_of(9))
        assert store.latest_step() == 9
        # a torn checkpoint (no manifest) must be invisible
        os.makedirs(tmp_path / "step_00000012", exist_ok=True)
        assert store.latest_step() == 9

    def test_partial_load_for_elastic_reshard(self, tmp_path):
        store = TieredCheckpointStore(str(tmp_path), host_id=0)
        t = tree_of(7)
        store.save(1, t)
        sub = store.load(1, only_paths={"params/emb"})
        assert list(sub["params"].keys()) == ["emb"]
        np.testing.assert_array_equal(sub["params"]["emb"], t["params"]["emb"])

    def test_dtype_preserved(self, tmp_path):
        store = TieredCheckpointStore(str(tmp_path), host_id=0)
        t = {"x": np.arange(7, dtype=np.int64),
             "y": np.ones((3,), np.float16)}
        store.save(2, t)
        out = store.load(2)
        assert out["x"].dtype == np.int64
        assert out["y"].dtype == np.float16


class TestCheckpointer:
    def test_async_double_buffer(self, tmp_path):
        store = TieredCheckpointStore(str(tmp_path), host_id=0)
        ck = Checkpointer(store)
        ck.save_async(1, tree_of(1))
        ck.save_async(2, tree_of(2))  # waits for #1 (two-region semantics)
        ck.wait()
        assert ck.saves_completed == 2
        assert store.latest_step() == 2
        ck.close()

    def test_restore_latest_with_cast(self, tmp_path):
        store = TieredCheckpointStore(str(tmp_path), host_id=0)
        ck = Checkpointer(store)
        t = tree_of(3)
        ck.save_blocking(7, t)
        like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, jnp.bfloat16)
            if x.dtype == np.float32 else jax.ShapeDtypeStruct(x.shape, x.dtype),
            t)
        step, restored = ck.restore_latest(like=like)
        assert step == 7
        assert restored["params"]["emb"].dtype == jnp.bfloat16
        ck.close()

    def test_restore_none_when_empty(self, tmp_path):
        ck = Checkpointer(TieredCheckpointStore(str(tmp_path), host_id=0))
        assert ck.restore_latest() is None
        ck.close()
