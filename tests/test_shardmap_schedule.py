"""Numerical validation of the shard_map token-stationary FFN schedule.

Runs on a REAL 8-device mesh (host platform override in a subprocess-safe
way: this test module must import jax first in the session OR skip) and
checks the explicit-collective schedule computes exactly the same FFN as
the dense reference.
"""

import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

import sys
sys.path.insert(0, "src")
sys.path.insert(0, ".")
from benchmarks.bench_shardmap_decode import build_fns

axis_type = getattr(jax.sharding, "AxisType", None)  # absent in older jax
kw = {"axis_types": (axis_type.Auto,) * 2} if axis_type else {}
mesh = jax.make_mesh((2, 4), ("data", "model"), **kw)
gspmd_ffn, shardmap_ffn, xspec, wspec, w2spec = build_fns(mesh)

rng = np.random.default_rng(0)
B, D, F = 8, 16, 32
x = jnp.asarray(rng.normal(size=(B, D)), jnp.float32)
w1 = jnp.asarray(rng.normal(size=(D, F)) * 0.1, jnp.float32)
w2 = jnp.asarray(rng.normal(size=(F, D)) * 0.1, jnp.float32)

with mesh:
    args = (jax.device_put(x, NamedSharding(mesh, xspec)),
            jax.device_put(w1, NamedSharding(mesh, wspec)),
            jax.device_put(w2, NamedSharding(mesh, w2spec)))
    ref = np.asarray(jax.jit(gspmd_ffn)(*args))
    got = np.asarray(jax.jit(shardmap_ffn)(*args))
np.testing.assert_allclose(got, ref, atol=1e-4, rtol=1e-4)
print("SHARDMAP_OK")
"""


def test_token_stationary_schedule_matches_dense():
    """Run in a subprocess so the 8-device override doesn't clash with the
    already-initialized single-device jax in this test session."""

    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=300,
    )
    assert "SHARDMAP_OK" in out.stdout, out.stdout + out.stderr
