"""Workload-generator calibration and structure tests (paper §2.2/Fig. 6)."""

import numpy as np
import pytest

from repro.core import StreamGrouper, hpio, ior, mixed, mpi_tile_io, relabel, stream_percentage
from repro.core.workloads import GiB, MiB, contention_skew


def mean_rp(w, stream_len=128):
    g = StreamGrouper(stream_len)
    ps = [stream_percentage(s) for s in g.push_many(w.trace)]
    return float(np.mean(ps))


class TestIORCalibration:
    def test_strided_rp_monotone_in_procs(self):
        rps = [mean_rp(ior("strided", n, total_bytes=GiB)) for n in (8, 32, 128)]
        assert rps[0] < rps[1] < rps[2]

    def test_strided_matches_paper_band(self):
        """Fig. 6 targets 7/28/71% at 8/32/128 procs (±10 points)."""

        for n, target in ((8, 0.07), (32, 0.28), (128, 0.71)):
            rp = mean_rp(ior("strided", n, total_bytes=2 * GiB))
            assert abs(rp - target) < 0.12, (n, rp, target)

    def test_segmented_random_is_nearly_fully_random(self):
        assert mean_rp(ior("segmented-random", 16, total_bytes=GiB)) > 0.85

    def test_segmented_contiguous_structural_rp(self):
        """Paper Fig. 5a: 16 sequential writers -> RF 15 of 127 after sort."""

        rp = mean_rp(ior("segmented-contiguous", 16, total_bytes=GiB))
        assert rp == pytest.approx(15 / 127, abs=0.04)

    def test_request_accounting(self):
        w = ior("strided", 8, total_bytes=256 * MiB)
        assert w.total_bytes == 256 * MiB
        assert len(w.trace) == 256 * MiB // (256 * 1024)
        offs = sorted(r.offset for r in w.trace)
        assert offs == list(range(0, 256 * MiB, 256 * 1024))  # full coverage


class TestOtherGenerators:
    def test_hpio_contiguous_vs_noncontiguous(self):
        cc = mean_rp(hpio(True, 32, total_bytes=256 * MiB))
        cnc = mean_rp(hpio(False, 32, total_bytes=256 * MiB))
        assert cnc > cc

    def test_tileio_2d_more_random_than_1d(self):
        d1 = mean_rp(mpi_tile_io(32, one_dimensional=True, total_bytes=256 * MiB))
        d2 = mean_rp(mpi_tile_io(32, one_dimensional=False, total_bytes=256 * MiB))
        assert d2 >= d1

    def test_mixed_conserves_and_orders(self):
        a = relabel(ior("strided", 8, total_bytes=64 * MiB, seed=1), 0, 0)
        b = relabel(ior("segmented-random", 8, total_bytes=64 * MiB, seed=2), 1, 1)
        m = mixed(a, b)
        assert len(m) == len(a.trace) + len(b.trace)
        times = [r.time for r in m.trace]
        assert times == sorted(times)

    def test_mixed_bursty_keeps_app_character(self):
        a = relabel(ior("segmented-contiguous", 8, total_bytes=64 * MiB, seed=1), 0, 0)
        b = relabel(ior("segmented-random", 8, total_bytes=64 * MiB, seed=2), 1, 1)
        m = mixed(a, b, burst_requests=256)
        g = StreamGrouper(128)
        ps = [stream_percentage(s) for s in g.push_many(m.trace)]
        # bursty interleave -> wide spread: pure sequential streams exist
        # alongside random(ish) ones (vs ~uniform blend without bursts)
        assert min(ps) < 0.2 and max(ps) > 0.45

    def test_contention_skew_grows(self):
        assert contention_skew(128) > contention_skew(8)
