"""Pallas kernel sweeps vs. pure-jnp oracles (interpret mode on CPU).

Per the assignment: for each kernel, sweep shapes/dtypes and
assert_allclose against ref.py.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.random_factor import random_factor_batch
from repro.kernels.flash_attention.ops import flash_attention_op
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.stream_rf.ops import random_percentage_op, stream_rf_op
from repro.kernels.stream_rf.ref import stream_rf_ref

pytestmark = pytest.mark.slow  # interpret-mode Pallas runs, seconds per case


class TestStreamRF:
    @pytest.mark.parametrize("m", [1, 3, 8, 37, 300])
    @pytest.mark.parametrize("n", [8, 64, 128])
    def test_shapes_vs_ref(self, m, n):
        rng = np.random.default_rng(m * 1000 + n)
        offs = rng.integers(0, 1 << 24, size=(m, n)).astype(np.int32)
        szs = rng.integers(1, 1 << 10, size=(m, n)).astype(np.int32)
        got = np.asarray(stream_rf_op(offs, szs))
        want = np.asarray(stream_rf_ref(offs, szs))
        np.testing.assert_array_equal(got, want)

    def test_agrees_with_core_detector(self):
        """Kernel == the host control-plane's batched scorer (same Eq. 1)."""

        rng = np.random.default_rng(7)
        offs = rng.integers(0, 1 << 20, size=(16, 128)).astype(np.int32)
        szs = np.full((16, 128), 256, np.int32)
        got = np.asarray(stream_rf_op(offs, szs))
        want = np.asarray(random_factor_batch(offs, szs))
        np.testing.assert_array_equal(got, want)

    def test_contiguous_and_reversed(self):
        offs = (np.arange(128, dtype=np.int32) * 64)[None]
        szs = np.full((1, 128), 64, np.int32)
        assert int(stream_rf_op(offs, szs)[0]) == 0
        assert int(stream_rf_op(offs[:, ::-1].copy(), szs)[0]) == 0  # sorted away

    def test_fully_random(self):
        offs = (np.arange(128, dtype=np.int32) * 1000)[None]
        szs = np.full((1, 128), 64, np.int32)
        assert int(stream_rf_op(offs, szs)[0]) == 127

    def test_percentage(self):
        offs = (np.arange(128, dtype=np.int32) * 1000)[None]
        szs = np.full((1, 128), 64, np.int32)
        assert float(random_percentage_op(offs, szs)[0]) == pytest.approx(1.0)

    def test_block_boundary_padding(self):
        """M not divisible by the stream block: padded rows must not leak."""

        rng = np.random.default_rng(9)
        offs = rng.integers(0, 1 << 20, size=(5, 128)).astype(np.int32)
        szs = np.full((5, 128), 17, np.int32)
        got = np.asarray(stream_rf_op(offs, szs, block_streams=4))
        want = np.asarray(stream_rf_ref(offs, szs))
        np.testing.assert_array_equal(got, want)


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "b,h,kv,sq,sk,hd,causal",
        [
            (1, 2, 2, 128, 128, 64, True),
            (2, 4, 2, 128, 128, 64, True),   # GQA n_rep=2
            (1, 6, 1, 128, 128, 32, True),   # MQA-ish n_rep=6
            (1, 2, 2, 256, 256, 128, False),
            (1, 2, 2, 64, 192, 64, False),   # sq != sk (cross-ish)
        ],
    )
    def test_vs_ref(self, b, h, kv, sq, sk, hd, causal, dtype):
        rng = np.random.default_rng(hash((b, h, sq, sk, hd)) % 2**31)
        q = jnp.asarray(rng.normal(size=(b, h, sq, hd)), dtype)
        k = jnp.asarray(rng.normal(size=(b, kv, sk, hd)), dtype)
        v = jnp.asarray(rng.normal(size=(b, kv, sk, hd)), dtype)
        got = flash_attention_op(q, k, v, causal=causal,
                                 block_q=64, block_k=64)
        want = flash_attention_ref(q, k, v, causal=causal)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            atol=tol, rtol=tol)

    def test_block_shape_independence(self):
        """Different tilings must give identical math (within fp error)."""

        rng = np.random.default_rng(3)
        q = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
        a = flash_attention_op(q, k, v, causal=True, block_q=64, block_k=64)
        b = flash_attention_op(q, k, v, causal=True, block_q=128, block_k=32)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5)

    def test_matches_model_attention_layer(self):
        """The kernel agrees with the XLA path used by the model trunk."""

        from repro.models.layers import _attend_direct

        rng = np.random.default_rng(4)
        q = jnp.asarray(rng.normal(size=(2, 128, 4, 64)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(2, 128, 2, 64)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(2, 128, 2, 64)), jnp.float32)
        xla = _attend_direct(q, k, v, n_rep=2, scale=0.125, causal=True)
        from repro.kernels.flash_attention.ops import flash_attention_bshd

        pal = flash_attention_bshd(q, k, v, causal=True, scale=0.125)
        np.testing.assert_allclose(np.asarray(xla), np.asarray(pal),
                                   atol=2e-5, rtol=2e-5)
