"""Device-resident fleet replay: one jitted sweep vs looped batched numpy.

The acceptance benchmark for ``FleetProgram``: a 64-node fleet replayed
under all four schemes is 256 ``scheme x node`` lane replays.  The
baseline runs them the pre-device way — a Python loop of
``FleetSimulator(engine="batched")`` over schemes, each looping nodes —
while ``FleetProgram`` stacks all 256 lanes and replays them in ONE
``jit(scan(vmap(step)))`` device call.  Acceptance bar: >= 10x
steady-state sweep speedup on the replay-scale trace (the same
million-request random mix ``bench_replay`` uses).

The first call pays the host tape build (2 lexsorts + anchor passes per
shard) plus XLA compile; both amortize — tapes are cached per trace,
the executable per program shape — which is the point of fixing the
program's shape.  Rows:

* ``device_replay_loop_batched``   — the scheme-looped numpy baseline
* ``device_replay_fleet_program``  — FleetProgram steady-state sweep
* ``device_replay_compile``        — first-call cost (tapes + compile)
"""

from __future__ import annotations

import time

from benchmarks.common import Row
from benchmarks.bench_replay import DEFAULT_REQUESTS, FULL_REQUESTS, _make_trace
from repro.core import FleetSimulator
from repro.core.workloads import GiB, MiB

NODES = 64
SCHEMES = ("orangefs", "orangefs-bb", "ssdup", "ssdup+")
POLICY = "range-offset"


def run(total_bytes: int = 2 * GiB) -> list[Row]:
    try:
        import jax  # noqa: F401
    except Exception:
        print("jax unavailable; skipping device replay benchmark")
        return []
    from repro.core import FleetProgram

    rows: list[Row] = []
    n = FULL_REQUESTS if total_bytes >= 16 * GiB else DEFAULT_REQUESTS
    batch = _make_trace(n)
    cap = max(batch.total_bytes // 2 // NODES, 64 * MiB)
    lanes = NODES * len(SCHEMES)

    print(f"\n-- device fleet replay, {n:,} requests "
          f"({batch.total_bytes / GiB:.0f} GiB logical), {NODES} nodes x "
          f"{len(SCHEMES)} schemes ({lanes} lanes), {POLICY} sharding --")

    # baseline: the pre-device path — Python loop over schemes, each a
    # FleetSimulator Python loop over nodes with the batched numpy engine
    t0 = time.perf_counter()
    loop_results = {
        scheme: FleetSimulator(num_nodes=NODES, scheme=scheme, policy=POLICY,
                               ssd_capacity=cap, engine="batched").run(batch)
        for scheme in SCHEMES
    }
    t_loop = time.perf_counter() - t0
    print(f"{'loop-batched':18s} {t_loop*1e3:9.1f} ms   "
          f"{lanes / t_loop:8.1f} lanes/s")
    rows.append(Row("device_replay_loop_batched", t_loop * 1e6,
                    f"lanes_per_s={lanes / t_loop:.1f}"))

    prog = FleetProgram(num_nodes=NODES, schemes=SCHEMES, policy=POLICY,
                        ssd_capacity=cap)
    t0 = time.perf_counter()
    dev_results = prog.run(batch)  # builds tapes, traces + compiles
    t_compile = time.perf_counter() - t0
    print(f"{'fleet-program(1st)':18s} {t_compile*1e3:9.1f} ms   "
          "(host tape build + XLA compile)")
    rows.append(Row("device_replay_compile", t_compile * 1e6,
                    f"lanes={lanes}"))

    t_dev = None
    for _ in range(3):
        t0 = time.perf_counter()
        dev_results = prog.run(batch)
        dt = time.perf_counter() - t0
        t_dev = dt if t_dev is None else min(t_dev, dt)
    speedup = t_loop / t_dev
    print(f"{'fleet-program':18s} {t_dev*1e3:9.1f} ms   "
          f"{lanes / t_dev:8.1f} lanes/s   {speedup:5.1f}x vs loop "
          "(bar: >= 10x)")
    rows.append(Row("device_replay_fleet_program", t_dev * 1e6,
                    f"speedup_vs_loop={speedup:.1f}"))

    # sanity: the sweep must land on the baseline's aggregate bytes — a
    # speedup over a wrong answer is no speedup
    for scheme in SCHEMES:
        want = sum(r.total_bytes for r in loop_results[scheme].node_results)
        got = sum(r.total_bytes for r in dev_results[scheme].node_results)
        assert got == want, (
            f"{scheme}: device sweep routed {got} bytes, baseline {want}")
    return rows


if __name__ == "__main__":
    run()
