"""Kernel micro-benchmarks (interpret mode on CPU — correctness-grade
timing only; the real perf story is the §Roofline analysis).

Reports per-call wall time for the Pallas paths and the derived work:
streams/s for stream_rf, attention FLOPs for flash_attention, plus the
jnp-oracle comparison so the CSV captures the overhead of interpret mode
honestly.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import Row, emit, timeit
from repro.kernels.flash_attention.ops import flash_attention_op
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.stream_rf.ops import stream_rf_op
from repro.kernels.stream_rf.ref import stream_rf_ref


def run() -> list[Row]:
    rows: list[Row] = []
    print("\n== kernel micro (interpret mode; correctness-grade timing) ==")
    rng = np.random.default_rng(0)

    offs = rng.integers(0, 1 << 24, size=(512, 128)).astype(np.int32)
    szs = np.full((512, 128), 256 * 1024, np.int32)
    for name, fn in (("stream_rf_pallas", stream_rf_op),
                     ("stream_rf_ref", stream_rf_ref)):
        out = fn(offs, szs)  # warmup/compile
        us, _ = timeit(lambda: jax.block_until_ready(fn(offs, szs)), repeat=3)
        sps = 512 / (us / 1e6)
        print(f"{name:22s} {us:10.1f} us/call  {sps:12.0f} streams/s")
        rows.append(Row(name, us, f"streams_per_s={sps:.0f}"))

    q = jnp.asarray(rng.normal(size=(1, 4, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    flops = 4 * 1 * 4 * 256 * 256 * 64  # qk + pv
    for name, fn in (
        ("flash_attn_pallas", lambda: flash_attention_op(
            q, k, v, causal=True, block_q=64, block_k=64)),
        ("flash_attn_ref", lambda: flash_attention_ref(q, k, v, causal=True)),
    ):
        jax.block_until_ready(fn())
        us, _ = timeit(lambda: jax.block_until_ready(fn()), repeat=3)
        print(f"{name:22s} {us:10.1f} us/call  {flops/(us/1e6)/1e9:8.2f} GFLOP/s")
        rows.append(Row(name, us, f"gflops={flops/(us/1e6)/1e9:.2f}"))
    return rows


if __name__ == "__main__":
    emit(run())
