"""Measured validation of the shard_map token-stationary decode schedule.

EXPERIMENTS.md §Perf target 3 found grok-1 decode collective-bound:
GSPMD re-gathers ~575 MB of FSDP-sharded weights per layer per 128-token
step, and refuses the cheap alternative (moving the tiny activations).
This benchmark measures both schedules on ONE representative FFN layer at
grok decode shapes, on the real 16x16 dry-run mesh:

* gspmd    — weights (D->data, F->model) FSDP x TP, activations
             batch-sharded; GSPMD inserts the weight all-gathers.
* shardmap — explicit token-stationary schedule: all_gather the (128, D)
             activations over "data" (1.5 MB), keep weights STATIONARY,
             psum the partials, all_to_all the result back to
             batch-sharded layout.  Weights never move.

Semantics are verified against the dense reference on a real 8-device mesh
in ``tests/test_shardmap_schedule.py``; here the collective bytes parsed
from the compiled HLO of each variant quantify the win.

Run standalone (needs the 512-device env):
    PYTHONPATH=src python -m benchmarks.bench_shardmap_decode
"""

from __future__ import annotations

import os


def build_fns(mesh):
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    try:
        from jax import shard_map
    except ImportError:  # older jax
        from jax.experimental.shard_map import shard_map

    wspec = P("data", "model")
    w2spec = P("model", "data")
    xspec = P("data", None)

    def gspmd_ffn(x, w1, w2):
        h = jax.nn.silu(x @ w1)
        return (h @ w2).astype(x.dtype)

    def _local(x, w1, w2):
        # x (B/data, D); w1 (D/data, F/model); w2 (F/model, D/data)
        xg = jax.lax.all_gather(x, "data", axis=0, tiled=True)  # (B, D)
        di = jax.lax.axis_index("data")
        dloc = w1.shape[0]
        xs = jax.lax.dynamic_slice_in_dim(xg, di * dloc, dloc, axis=1)
        h = jax.lax.psum(xs.astype(jnp.float32) @ w1.astype(jnp.float32),
                         "data")  # (B, F/model) exact
        out = jax.nn.silu(h) @ w2.astype(jnp.float32)  # (B, D/data) partial
        out = jax.lax.psum(out, "model")  # exact (B, D/data)
        # transpose (B, D/data)-per-data-shard -> (B/data, D): tiny all_to_all
        out = jax.lax.all_to_all(out, "data", split_axis=0, concat_axis=1,
                                 tiled=True)
        return out.astype(x.dtype)

    def shardmap_ffn(x, w1, w2):
        return shard_map(_local, mesh=mesh,
                         in_specs=(xspec, wspec, w2spec),
                         out_specs=xspec)(x, w1, w2)

    return gspmd_ffn, shardmap_ffn, xspec, wspec, w2spec


def run() -> list:
    import jax

    from benchmarks.common import Row

    if len(jax.devices()) < 256:
        print("[shardmap_decode] needs the 512-device dry-run env; run "
              "standalone: PYTHONPATH=src python -m benchmarks.bench_shardmap_decode")
        return []

    import jax.numpy as jnp
    from jax.sharding import NamedSharding

    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import ICI_BW, parse_collectives

    mesh = make_production_mesh()
    B, D, F = 128, 6144, 32768  # grok FFN at decode batch
    gspmd_ffn, shardmap_ffn, xspec, wspec, w2spec = build_fns(mesh)

    x = jax.ShapeDtypeStruct((B, D), jnp.bfloat16)
    w1 = jax.ShapeDtypeStruct((D, F), jnp.bfloat16)
    w2 = jax.ShapeDtypeStruct((F, D), jnp.bfloat16)

    rows = []
    results = {}
    with mesh:
        for name, fn in (("gspmd", gspmd_ffn), ("shardmap", shardmap_ffn)):
            jf = jax.jit(
                fn,
                in_shardings=(
                    NamedSharding(mesh, xspec),
                    NamedSharding(mesh, wspec),
                    NamedSharding(mesh, w2spec),
                ),
                out_shardings=NamedSharding(mesh, xspec),
            )
            compiled = jf.lower(x, w1, w2).compile()
            st = parse_collectives(compiled.as_text())
            coll_ms = st.link_bytes / ICI_BW * 1e3
            results[name] = st.link_bytes
            print(f"{name:9s} link_bytes/dev={st.link_bytes/2**20:9.1f} MiB "
                  f"collective={coll_ms:7.3f} ms  ops={st.counts}")
            rows.append(Row(f"shardmap_decode_{name}", 0.0,
                            f"link_mib={st.link_bytes/2**20:.1f};coll_ms={coll_ms:.3f}"))
    cut = 1 - results["shardmap"] / max(results["gspmd"], 1)
    print(f"shard_map token-stationary schedule cuts per-layer decode "
          f"collective bytes by {cut*100:.1f}%")
    rows.append(Row("shardmap_decode_cut", 0.0, f"cut={cut:.4f}"))
    return rows


def main() -> None:
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=512")
    run()


if __name__ == "__main__":
    main()
