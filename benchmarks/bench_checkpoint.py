"""Framework-level benchmark: checkpoint writes through the burst buffer.

The paper's motivating workload (bursty checkpoint dumps, §1) on the real
byte-moving path: save a model pytree through TieredCheckpointStore with
traffic-aware buffering ON vs OFF and plain direct-to-slow writes, report
wall time and tier split.  (Timing here is host wall-clock on tmpfs-backed
dirs — relative numbers matter.)
"""

from __future__ import annotations

import os
import shutil
import tempfile
import time

import jax
import numpy as np

from benchmarks.common import Row, emit
from repro.checkpoint import TieredCheckpointStore
from repro.launch.train import PRESETS
from repro.models import get_model


def run() -> list[Row]:
    rows: list[Row] = []
    print("\n== Checkpoint-through-burst-buffer (tiny preset, 1 host) ==")
    cfg = PRESETS["tiny"]
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    tree = {"params": jax.tree.map(np.asarray, params)}
    nbytes = sum(a.nbytes for a in jax.tree.leaves(tree))
    print(f"checkpoint bytes: {nbytes/2**20:.1f} MiB")

    # writers=1: a single sequential dumper (detector correctly bypasses the
    # fast tier).  writers=8: concurrent shard writers — the paper's bursty
    # interleaved arrival; the random streams ride the fast-tier log.
    for mode, writers, kwargs in (
        ("sequential_1w", 1, dict(traffic_aware=True)),
        ("interleaved_24w", 24, dict(traffic_aware=True)),
        ("contended_shuffle", -1, dict(traffic_aware=True)),
        ("contended_shuffle_imm", -1, dict(traffic_aware=False)),
    ):
        root = tempfile.mkdtemp(prefix=f"ckpt_{mode}_")
        try:
            store = TieredCheckpointStore(root, host_id=0,
                                          region_bytes=8 << 20, **kwargs)
            t0 = time.perf_counter()
            stats = store.save(1, tree, writers=writers, chunk=64 << 10)
            dt = time.perf_counter() - t0
            # integrity: reload and compare one leaf
            loaded = store.load(1)
            flat_a = jax.tree.leaves(tree)
            flat_b = jax.tree.leaves(loaded)
            ok = all(np.array_equal(a, np.asarray(b).view(a.dtype).reshape(a.shape))
                     for a, b in zip(flat_a, flat_b))
            mbps = nbytes / dt / 1e6
            print(f"{mode:14s}: {dt*1e3:8.1f} ms ({mbps:7.1f} MB/s) "
                  f"fast_ratio={stats['fast_byte_ratio']:.2f} "
                  f"flushes={stats['flushes_completed']} intact={ok}")
            rows.append(Row(
                f"ckpt_{mode}", dt * 1e6,
                f"mbps={mbps:.1f};fast_ratio={stats['fast_byte_ratio']:.3f};"
                f"intact={ok}"))
            assert ok, "checkpoint round-trip corrupted"
        finally:
            shutil.rmtree(root, ignore_errors=True)
    return rows


if __name__ == "__main__":
    emit(run())
