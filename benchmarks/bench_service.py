"""Service benchmark: online arrival-to-completion throughput, healthy
vs one-crash, across the four schemes.

The offline fleet benchmarks measure replay throughput; this one measures
the *service* view — a Poisson-stamped mixed load dispatched through the
discrete-event loop (`repro.service.BurstBufferService`) — and reports
per-scheme tail latency plus the cost of a mid-run node crash (failover,
reshard, backlog replay on the takeover node).

Rows:

* ``service_<scheme>_healthy``  — no faults; derived p99 latency (s) and
  completed MB/s over the makespan.
* ``service_<scheme>_crash``    — one scripted crash at 25% of the
  arrival horizon on an 8-node fleet; derived recovery seconds.
"""

from __future__ import annotations

import time

from benchmarks.common import Row
from repro.core import TraceBatch, ior, mixed, relabel
from repro.core.workloads import GiB, MiB
from repro.service import BurstBufferService, FaultInjector, poisson_arrivals

NUM_NODES = 8
RATE_RPS = 2000.0
SCHEMES = ("orangefs", "orangefs-bb", "ssdup", "ssdup+")


def _offered(total_bytes: int) -> TraceBatch:
    per_app = max(total_bytes // 4, 64 * MiB)
    apps = [
        relabel(ior("segmented-contiguous", 8, total_bytes=per_app, seed=1),
                app_id=0, file_id=0),
        relabel(ior("segmented-random", 8, total_bytes=per_app, seed=2),
                app_id=1, file_id=1),
        relabel(ior("strided", 32, total_bytes=per_app, seed=3),
                app_id=2, file_id=2),
        relabel(ior("segmented-random", 16, total_bytes=per_app, seed=4),
                app_id=3, file_id=3),
    ]
    load = mixed(*apps, burst_requests=512)
    return poisson_arrivals(
        TraceBatch.from_items(load.trace), rate_rps=RATE_RPS, seed=7
    )


def run(total_bytes: int = 2 * GiB) -> list[Row]:
    rows: list[Row] = []
    batch = _offered(total_bytes)
    horizon = float(batch.times[-1])
    ssd = max(batch.total_bytes // 2 // NUM_NODES, 64 * MiB)
    crash_at = 0.25 * horizon

    print("\n== service: online arrivals, healthy vs one-crash ==")
    print(f"-- {batch.total_bytes / GiB:.1f} GiB offered at "
          f"{RATE_RPS:.0f} req/s over {NUM_NODES} nodes --")
    print(f"{'scheme':>12s} {'healthy MB/s':>13s} {'p99 (s)':>9s} "
          f"{'crash MB/s':>11s} {'recovery (s)':>13s}")
    for scheme in SCHEMES:
        t0 = time.perf_counter()
        healthy = BurstBufferService(
            scheme=scheme, num_nodes=NUM_NODES, policy="range-offset",
            ssd_capacity=ssd,
        ).run(batch)
        dt_h = time.perf_counter() - t0
        hm = healthy.metrics
        assert not hm.conservation_violations()
        rows.append(Row(
            f"service_{scheme}_healthy", dt_h * 1e6,
            f"mbs={hm.throughput_mbs:.1f};p99_s={hm.p99_latency:.3f}",
        ))

        t0 = time.perf_counter()
        crashed = BurstBufferService(
            scheme=scheme, num_nodes=NUM_NODES, policy="range-offset",
            ssd_capacity=ssd, heartbeat_timeout=2.0, epoch_seconds=0.5,
            injector=FaultInjector.crash_at(crash_at, NUM_NODES // 2),
        ).run(batch)
        dt_c = time.perf_counter() - t0
        cm = crashed.metrics
        assert not cm.conservation_violations()
        rec = cm.recovery_seconds or 0.0
        rows.append(Row(
            f"service_{scheme}_crash", dt_c * 1e6,
            f"mbs={cm.throughput_mbs:.1f};recovery_s={rec:.2f}",
        ))
        print(f"{scheme:>12s} {hm.throughput_mbs:13.1f} "
              f"{hm.p99_latency:9.3f} {cm.throughput_mbs:11.1f} "
              f"{rec:13.2f}")
    return rows


if __name__ == "__main__":
    from benchmarks.common import BENCH_BYTES, emit

    emit(run(BENCH_BYTES))
