"""Paper Fig. 15: HPIO region-size sweep (c-c x c-nc mixed instances).

Two HPIO instances run concurrently (contiguous + non-contiguous); region
size sweeps 32K..256K at 32 processes.  Reported: per-scheme throughput and
the SSD bytes saved by SSDUP+ vs SSDUP (paper: >15% average saving at <6%
throughput cost).
"""

from __future__ import annotations

from benchmarks.common import BENCH_BYTES, Row, emit, timeit
from repro.core import hpio, mixed, relabel, run_schemes
from repro.core.workloads import KiB


def run(total_bytes: int = BENCH_BYTES) -> list[Row]:
    rows: list[Row] = []
    app = total_bytes // 2
    print("\n== Fig 15: HPIO region-size sweep (32 procs, c-c x c-nc) ==")
    print(f"{'region':>8s} | {'orangefs-bb':>22s} | {'ssdup':>22s} | {'ssdup+':>22s} | {'ssd saved':>9s}")
    for rs in (32 * KiB, 64 * KiB, 128 * KiB, 256 * KiB):
        w1 = relabel(hpio(True, 32, region_size=rs, total_bytes=app // 2, seed=1),
                     app_id=0, file_id=0)
        w2 = relabel(hpio(False, 32, region_size=rs, total_bytes=app // 2, seed=2),
                     app_id=1, file_id=1)
        mw = mixed(w1, w2, burst_requests=512)
        us, res = timeit(lambda: run_schemes(
            mw.trace, schemes=("orangefs-bb", "ssdup", "ssdup+"),
            ssd_capacity=app))
        cells = []
        for s in ("orangefs-bb", "ssdup", "ssdup+"):
            r = res[s]
            cells.append(f"{2*r.throughput_mbs:8.1f}MB/s {r.ssd_byte_ratio*100:5.1f}%")
            rows.append(Row(
                f"fig15_{s}_{rs//KiB}k", us / 3,
                f"agg_mbs={2*r.throughput_mbs:.1f};ssd_ratio={r.ssd_byte_ratio:.3f}"))
        saved = 1 - res["ssdup+"].ssd_byte_ratio / max(res["ssdup"].ssd_byte_ratio, 1e-9)
        print(f"{rs//KiB:6d}K | " + " | ".join(cells) + f" | {saved*100:8.1f}%")
        rows.append(Row(f"fig15_saving_{rs//KiB}k", 0.0, f"saving={saved:.3f}"))
    return rows


if __name__ == "__main__":
    emit(run())
