"""Aggregate the dry-run JSONs into the §Roofline table (deliverable g).

Reads ``experiments/dryrun/*.json`` (written by ``repro.launch.dryrun``)
and renders the per-(arch x cell x mesh) table: the three terms, dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS ratio, and per-device memory — plus a
one-line "what would move the dominant term" note per dominant kind.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--mesh single] [--md]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

NOTES = {
    "memory": "cut bytes: lighter remat policy / fused attention kernel "
              "(flash) / fp8-bf16 master-weight split",
    "collective": "cut link bytes: reshard to cut FSDP all-gathers "
                  "(sequence-shard activations), overlap via latency-hiding "
                  "scheduler, compress grads",
    "compute": "near roofline on MXU: raise arithmetic intensity or accept",
}


def load(out_dir: str, mesh: str | None) -> list[dict]:
    rows = []
    for p in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        r = json.load(open(p))
        if r.get("status") != "ok":
            continue
        if mesh and r["mesh"] != mesh:
            continue
        rows.append(r)
    return rows


def render(rows: list[dict], md: bool = False) -> str:
    hdr = (f"{'arch':22s} {'cell':12s} {'mesh':6s} "
           f"{'compute_ms':>10s} {'memory_ms':>10s} {'collective_ms':>13s} "
           f"{'dominant':>10s} {'useful':>7s} {'mem/dev GiB':>11s} {'roofline%':>9s}")
    sep = "-" * len(hdr)
    lines = [hdr, sep]
    if md:
        lines = ["| arch | cell | mesh | compute ms | memory ms | "
                 "collective ms | dominant | useful | mem/dev GiB | roofline% |",
                 "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        t = r["roofline"]
        uf = r.get("useful_flops_ratio")
        mem = r["memory"]["peak_live_bytes_est"] / 2**30
        # roofline fraction: compute term / max(term) — how close the step
        # is to being MXU-bound (1.0 = perfectly compute-bound)
        frac = t["compute_s"] / max(t["step_time_s"], 1e-12) * 100
        vals = (r["arch"], r["cell"], r["mesh"],
                f"{t['compute_s']*1e3:.2f}", f"{t['memory_s']*1e3:.2f}",
                f"{t['collective_s']*1e3:.2f}", t["dominant"],
                f"{uf:.3f}" if uf else "-", f"{mem:.1f}", f"{frac:.1f}")
        if md:
            lines.append("| " + " | ".join(str(v) for v in vals) + " |")
        else:
            lines.append(f"{vals[0]:22s} {vals[1]:12s} {vals[2]:6s} "
                         f"{vals[3]:>10s} {vals[4]:>10s} {vals[5]:>13s} "
                         f"{vals[6]:>10s} {vals[7]:>7s} {vals[8]:>11s} "
                         f"{vals[9]:>9s}")
    doms = {}
    for r in rows:
        doms.setdefault(r["roofline"]["dominant"], 0)
        doms[r["roofline"]["dominant"]] += 1
    lines.append("")
    lines.append(f"dominant-term distribution: {doms}")
    for d, n in sorted(doms.items()):
        lines.append(f"  {d:10s} ({n:2d} cells): {NOTES[d]}")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--mesh", default=None, choices=[None, "single", "multi"])
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    rows = load(args.out, args.mesh)
    if not rows:
        print("no dry-run records found; run `python -m repro.launch.dryrun --all`")
        return
    print(render(rows, md=args.md))


if __name__ == "__main__":
    main()
