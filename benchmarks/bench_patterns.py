"""Paper Fig. 2 / Fig. 6: access-pattern throughput + random percentage.

Reproduces the inverse throughput <-> randomness correlation that motivates
the random-factor detector, on the calibrated device model (aggregate over
2 I/O nodes, like the paper's testbed).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import BENCH_BYTES, Row, emit, timeit
from repro.core import IONodeSimulator, StreamGrouper, ior, stream_percentage

PAPER_FIG6 = {8: 208.1, 16: 211.76, 32: 175.8, 64: 159.29, 128: 132.68}


def run(total_bytes: int = BENCH_BYTES, procs=(8, 16, 32, 64, 128)) -> list[Row]:
    rows: list[Row] = []
    print("\n== Fig 2/6: throughput vs pattern & process count (OrangeFS) ==")
    print(f"{'pattern':24s} {'procs':>5s} {'RP%':>6s} {'MB/s(agg)':>10s} {'paper':>7s}")
    for pattern in ("segmented-contiguous", "strided", "segmented-random"):
        for n in procs:
            w = ior(pattern, n, total_bytes=total_bytes // 2)  # per node
            g = StreamGrouper(128)
            rps = [stream_percentage(s) for s in g.push_many(w.trace)]
            rp = float(np.mean(rps)) if rps else 0.0
            us, res = timeit(
                lambda: IONodeSimulator(scheme="orangefs").run(list(w.trace)))
            agg = 2 * res.throughput_mbs
            paper = PAPER_FIG6.get(n, float("nan")) if pattern == "strided" else float("nan")
            print(f"{pattern:24s} {n:5d} {rp*100:6.1f} {agg:10.1f} "
                  f"{paper if paper == paper else '':>7}")
            rows.append(Row(
                f"fig6_{pattern}_{n}p", us,
                f"agg_mbs={agg:.1f};rp={rp:.3f}"))
    return rows


if __name__ == "__main__":
    emit(run())
