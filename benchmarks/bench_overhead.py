"""Paper Table 1: system overhead — grouping cost + AVL cost.

Measures the REAL code paths (StreamGrouper + percentage scoring; AVL
insert + in-order traversal) wall-clock against the simulated I/O time of
the same workload, for request sizes 32K..512K over a fixed data volume.
Paper: 0.13%-0.79% of total execution time.
"""

from __future__ import annotations

import time

from benchmarks.common import Row, emit
from repro.core import (
    AVLTree,
    IONodeSimulator,
    StreamGrouper,
    ior,
    stream_percentage,
)
from repro.core.workloads import GiB, KiB


def run(total_bytes: int = GiB) -> list[Row]:
    rows: list[Row] = []
    print("\n== Table 1: grouping + AVL overhead (seg-random, all to SSD) ==")
    print(f"{'req size':>9s} {'io time':>9s} {'group':>9s} {'avl':>9s} {'overhead':>9s}")
    for req in (32 * KiB, 64 * KiB, 128 * KiB, 256 * KiB, 512 * KiB):
        w = ior("segmented-random", 16, total_bytes=total_bytes,
                request_size=req)
        # grouping + scoring cost
        t0 = time.perf_counter()
        g = StreamGrouper(128)
        for s in g.push_many(w.trace):
            stream_percentage(s)
        group_s = time.perf_counter() - t0
        # AVL cost: insert every request + one in-order traversal
        t0 = time.perf_counter()
        tree = AVLTree()
        off = 0
        for r in w.trace:
            tree.insert(r.offset, r.size, off)
            off += r.size
        _ = sum(1 for _ in tree.in_order())
        avl_s = time.perf_counter() - t0
        # simulated I/O time of the same trace under ssdup+
        io_s = IONodeSimulator(scheme="ssdup+",
                               ssd_capacity=2 * total_bytes).run(
            list(w.trace)).io_seconds
        ov = (group_s + avl_s) / io_s * 100
        print(f"{req//KiB:7d}K {io_s:8.2f}s {group_s*1e3:7.1f}ms "
              f"{avl_s*1e3:7.1f}ms {ov:8.2f}%")
        rows.append(Row(
            f"table1_{req//KiB}k",
            (group_s + avl_s) / max(len(w.trace), 1) * 1e6,
            f"overhead_pct={ov:.3f};group_ms={group_s*1e3:.1f};"
            f"avl_ms={avl_s*1e3:.1f};metadata_bytes={tree.approx_bytes()}"))
    return rows


if __name__ == "__main__":
    emit(run())
