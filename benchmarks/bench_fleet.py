"""Fleet benchmark: batched stream scoring speedup + 1->16 node scaling.

Part 1 — scoring: per-stream scalar NumPy (the seed simulator's hot path:
one ``stream_percentage`` + one ``sorted_seek_distance`` per 128-request
window inside a Python loop) versus the vectorized batched paths
(``numpy`` int64 oracle, one-call ``jnp``, and the ``stream_rf`` Pallas
kernel) on the same >= 4096-stream trace.  The acceptance bar is a >= 5x
speedup for batched over scalar.

Part 2 — fleet scaling: aggregate throughput of the four schemes as the
same mixed workload is sharded over 1 -> 16 I/O nodes (range-offset
policy, per-node SSD shrinking with the shard so total fleet SSD is
constant), the paper's 2-node aggregate generalized.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Row, timeit
from repro.core import (
    FleetSimulator,
    Request,
    StreamGrouper,
    TraceBatch,
    compute_stream_scores,
    ior,
    mixed,
    relabel,
    stream_percentage,
)
from repro.core.random_factor import sorted_seek_distance
from repro.core.workloads import GiB, MiB

SCORE_STREAMS = 4096
STREAM_LEN = 128


def _scalar_score_all(streams) -> float:
    t0 = time.perf_counter()
    for s in streams:
        stream_percentage(s)
        sorted_seek_distance(s)
    return time.perf_counter() - t0


def bench_scoring(rows: list[Row]) -> None:
    rng = np.random.default_rng(0)
    n = SCORE_STREAMS * STREAM_LEN
    trace = [
        Request(offset=int(o), size=256 * 1024)
        for o in rng.integers(0, 1 << 30, size=n)
    ]
    grouper = StreamGrouper(STREAM_LEN)
    streams = list(grouper.push_many(trace))
    batch = TraceBatch.from_requests(trace)

    print(f"\n-- stream scoring, {SCORE_STREAMS} streams x {STREAM_LEN} reqs --")
    t_scalar = min(_scalar_score_all(streams) for _ in range(3))
    sps = SCORE_STREAMS / t_scalar
    print(f"{'scalar-loop':18s} {t_scalar*1e3:9.1f} ms   {sps:12.0f} streams/s")
    rows.append(Row("fleet_score_scalar", t_scalar * 1e6,
                    f"streams_per_s={sps:.0f}"))

    backends = ["numpy"]
    try:
        import jax  # noqa: F401
        backends += ["jnp", "pallas"]
    except Exception:
        pass
    for backend in backends:
        compute_stream_scores(batch, STREAM_LEN, backend=backend)  # warmup
        us, _ = timeit(
            lambda: compute_stream_scores(batch, STREAM_LEN, backend=backend),
            repeat=3,
        )
        t = us / 1e6
        speedup = t_scalar / t
        print(f"{'batched-' + backend:18s} {t*1e3:9.1f} ms   "
              f"{SCORE_STREAMS/t:12.0f} streams/s   {speedup:5.1f}x vs scalar")
        rows.append(Row(f"fleet_score_{backend}", us,
                        f"speedup_vs_scalar={speedup:.1f}"))


def bench_scaling(rows: list[Row], total_bytes: int) -> None:
    per_app = max(total_bytes // 4, 64 * MiB)
    apps = [
        relabel(ior("segmented-contiguous", 8, total_bytes=per_app, seed=1),
                app_id=0, file_id=0),
        relabel(ior("segmented-random", 8, total_bytes=per_app, seed=2),
                app_id=1, file_id=1),
        relabel(ior("strided", 32, total_bytes=per_app, seed=3),
                app_id=2, file_id=2),
        relabel(ior("segmented-random", 16, total_bytes=per_app, seed=4),
                app_id=3, file_id=3),
    ]
    load = mixed(*apps, burst_requests=512)
    batch = TraceBatch.from_requests(load.trace)
    fleet_ssd = load.total_bytes // 2  # total fleet SSD, split over nodes

    print(f"\n-- fleet scaling, {load.total_bytes / GiB:.1f} GiB mixed load, "
          "range-offset sharding --")
    print(f"{'nodes':>5s} " + "".join(f"{s:>14s}" for s in
                                      ("orangefs", "orangefs-bb", "ssdup",
                                       "ssdup+")) + f" {'imbalance':>10s}")
    for nodes in (1, 2, 4, 8, 16):
        tps = []
        imb = 1.0
        for scheme in ("orangefs", "orangefs-bb", "ssdup", "ssdup+"):
            t0 = time.perf_counter()
            fr = FleetSimulator(
                num_nodes=nodes, scheme=scheme, policy="range-offset",
                ssd_capacity=max(fleet_ssd // nodes, 64 * MiB),
            ).run(batch)
            dt = time.perf_counter() - t0
            tps.append(fr.throughput_mbs)
            imb = fr.load_imbalance
            rows.append(Row(
                f"fleet_{scheme}_{nodes}n", dt * 1e6,
                f"agg_mbs={fr.throughput_mbs:.1f}",
            ))
        print(f"{nodes:5d} " + "".join(f"{t:12.1f} MB/s"[-14:] for t in tps)
              + f" {imb:10.2f}")


def run(total_bytes: int = 2 * GiB) -> list[Row]:
    rows: list[Row] = []
    print("\n== fleet: batched scoring + multi-node scaling ==")
    bench_scoring(rows)
    bench_scaling(rows, total_bytes)
    return rows


if __name__ == "__main__":
    from benchmarks.common import BENCH_BYTES, emit

    emit(run(BENCH_BYTES))
