"""Paper Fig. 7 / Fig. 8 / Fig. 11: adaptive threshold & scheme comparison.

* Fig. 7  — threshold case study: fraction of 'successful directions'
* Fig. 8/11 — OrangeFS vs OrangeFS-BB vs SSDUP vs SSDUP+ throughput and the
  fraction of data buffered in SSD (the capacity-saving headline)
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import BENCH_BYTES, Row, emit, timeit
from repro.core import (
    AdaptiveThreshold,
    DataRedirector,
    Device,
    ior,
    run_schemes,
)


def fig7_case_study(total_bytes: int) -> list[Row]:
    print("\n== Fig 7: adaptive-threshold direction quality (strided, 64p) ==")
    w = ior("strided", 64, total_bytes=total_bytes // 2)
    red = DataRedirector(AdaptiveThreshold(window=64))
    routed = list(red.route(w.trace))
    pcts = np.array([r.percentage for r in routed])
    to_ssd = np.array([r.device is Device.SSD for r in routed])
    avg = pcts.mean()
    # paper's criterion: a direction is "successful" when the SSD decision
    # coincides with the stream's percentage exceeding the average
    success = float(np.mean(to_ssd == (pcts > avg)))
    print(f"streams={len(routed)} ssd_frac={to_ssd.mean():.3f} "
          f"success={success*100:.1f}% (paper: 79.48%)")
    return [Row("fig7_success", 0.0,
                f"success={success:.4f};ssd_frac={to_ssd.mean():.4f}")]


def fig8_11_schemes(total_bytes: int, procs=(8, 16, 32, 64, 128)) -> list[Row]:
    rows: list[Row] = []
    print("\n== Fig 8/11: schemes on strided IOR (ample SSD) ==")
    print(f"{'procs':>5s} | " + " | ".join(
        f"{s:>24s}" for s in ("orangefs", "orangefs-bb", "ssdup", "ssdup+")))
    for n in procs:
        w = ior("strided", n, total_bytes=total_bytes // 2)
        us, res = timeit(lambda: run_schemes(
            w.trace, ssd_capacity=total_bytes))
        cells = []
        for s in ("orangefs", "orangefs-bb", "ssdup", "ssdup+"):
            r = res[s]
            cells.append(f"{2*r.throughput_mbs:7.1f}MB/s {r.ssd_byte_ratio*100:5.1f}%ssd")
            rows.append(Row(
                f"fig11_{s}_{n}p", us / 4,
                f"agg_mbs={2*r.throughput_mbs:.1f};ssd_ratio={r.ssd_byte_ratio:.3f}"))
        print(f"{n:5d} | " + " | ".join(cells))
    # capacity-saving headline (paper: ~50% less SSD than SSDUP at 64p)
    w = ior("strided", 64, total_bytes=total_bytes // 2)
    res = run_schemes(w.trace, schemes=("ssdup", "ssdup+"),
                      ssd_capacity=total_bytes)
    saving = 1 - res["ssdup+"].ssd_byte_ratio / max(res["ssdup"].ssd_byte_ratio, 1e-9)
    print(f"SSD capacity saving vs SSDUP @64p: {saving*100:.1f}% "
          "(paper: >50%)")
    rows.append(Row("fig11_capacity_saving_64p", 0.0, f"saving={saving:.3f}"))
    return rows


def run(total_bytes: int = BENCH_BYTES) -> list[Row]:
    return fig7_case_study(total_bytes) + fig8_11_schemes(total_bytes)


if __name__ == "__main__":
    emit(run())
