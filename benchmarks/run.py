"""Run every benchmark (one per paper table/figure) and emit the CSV.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig13,...]

``--full`` uses the paper's 16 GiB volumes (slow on one core); the default
2 GiB keeps a full sweep short while preserving every trend.
Output: human tables on stdout plus ``name,us_per_call,derived`` lines,
also written to ``experiments/bench_results.csv``.
"""

from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks import (  # noqa: E402
    bench_adaptive,
    bench_checkpoint,
    bench_fleet,
    bench_hpio,
    bench_kernels,
    bench_overhead,
    bench_patterns,
    bench_pipeline,
    bench_queue,
    bench_replay,
    bench_shardmap_decode,
    bench_tileio,
)
from benchmarks.common import BENCH_BYTES, PAPER_BYTES, Row  # noqa: E402

SUITES = {
    "patterns": lambda tb: bench_patterns.run(tb),
    "adaptive": lambda tb: bench_adaptive.run(tb),
    "queue": lambda tb: bench_queue.run(tb),
    "pipeline": lambda tb: bench_pipeline.run(tb),
    "hpio": lambda tb: bench_hpio.run(tb),
    "tileio": lambda tb: bench_tileio.run(tb),
    "overhead": lambda tb: bench_overhead.run(),
    "checkpoint": lambda tb: bench_checkpoint.run(),
    "kernels": lambda tb: bench_kernels.run(),
    "shardmap_decode": lambda tb: bench_shardmap_decode.run(),
    "fleet": lambda tb: bench_fleet.run(tb),
    "replay": lambda tb: bench_replay.run(tb),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale 16 GiB volumes")
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    args = ap.parse_args()

    tb = PAPER_BYTES if args.full else BENCH_BYTES
    names = list(SUITES) if not args.only else args.only.split(",")
    all_rows: list[Row] = []
    t0 = time.time()
    for name in names:
        print(f"\n######## {name} ########", flush=True)
        t1 = time.time()
        rows = SUITES[name](tb)
        all_rows.extend(rows)
        print(f"[{name}] {time.time()-t1:.1f}s", flush=True)

    print("\n######## CSV (name,us_per_call,derived) ########")
    for r in all_rows:
        print(r.csv())
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/bench_results.csv", "w") as f:
        f.write("name,us_per_call,derived\n")
        for r in all_rows:
            f.write(r.csv() + "\n")
    print(f"\n[benchmarks] {len(all_rows)} rows in {time.time()-t0:.1f}s "
          f"-> experiments/bench_results.csv")


def run_all():  # programmatic entry for tests
    return [r for name in SUITES for r in SUITES[name](BENCH_BYTES)]


if __name__ == "__main__":
    main()
