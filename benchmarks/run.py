"""Run every benchmark (one per paper table/figure) and emit the CSV.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fleet,...]
                                            [--check] [--bench-index N]

``--full`` uses the paper's 16 GiB volumes (slow on one core); the default
2 GiB keeps a full sweep short while preserving every trend.

Output artifacts (both written atomically — temp file + rename — and
*merged* by name, so a partial ``--only`` run never truncates results
from suites it did not run):

* ``experiments/bench_results.csv`` — ``name,us_per_call,derived`` rows.
* ``experiments/BENCH_<n>.json`` — the perf-trajectory artifact
  (per-suite timings, speedup vs the previous ``BENCH_<k>.json`` anchor,
  regression flag at +/-15%; see :mod:`repro.testing.perf`).

``--check`` exits nonzero if any suite run this invocation regressed more
than the threshold against the anchor — the CI perf gate.
"""

from __future__ import annotations

import argparse
import os
import sys
import time
from typing import Sequence

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks import (  # noqa: E402
    bench_adaptive,
    bench_checkpoint,
    bench_device_replay,
    bench_fleet,
    bench_ftl,
    bench_hpio,
    bench_kernels,
    bench_overhead,
    bench_patterns,
    bench_pipeline,
    bench_queue,
    bench_replay,
    bench_service,
    bench_shardmap_decode,
    bench_tileio,
)
from benchmarks.common import BENCH_BYTES, PAPER_BYTES, Row  # noqa: E402
from repro.testing import perf  # noqa: E402

SUITES = {
    "patterns": lambda tb: bench_patterns.run(tb),
    "adaptive": lambda tb: bench_adaptive.run(tb),
    "queue": lambda tb: bench_queue.run(tb),
    "pipeline": lambda tb: bench_pipeline.run(tb),
    "hpio": lambda tb: bench_hpio.run(tb),
    "tileio": lambda tb: bench_tileio.run(tb),
    "overhead": lambda tb: bench_overhead.run(),
    "checkpoint": lambda tb: bench_checkpoint.run(),
    "kernels": lambda tb: bench_kernels.run(),
    "shardmap_decode": lambda tb: bench_shardmap_decode.run(),
    "fleet": lambda tb: bench_fleet.run(tb),
    "ftl": lambda tb: bench_ftl.run(tb),
    "replay": lambda tb: bench_replay.run(tb),
    "device_replay": lambda tb: bench_device_replay.run(tb),
    "service": lambda tb: bench_service.run(tb),
}

CSV_PATH = os.path.join("experiments", "bench_results.csv")


def _write_csv(all_rows: list[Row], path: str = CSV_PATH) -> None:
    existing = None
    if os.path.exists(path):
        with open(path) as f:
            existing = f.read()
    perf.atomic_write_text(path, perf.merge_csv(existing, all_rows))


def main(argv: Sequence[str] | None = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale 16 GiB volumes")
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero if any suite run here regressed "
                         "vs the previous BENCH anchor")
    ap.add_argument("--bench-index", type=int, default=perf.CURRENT_INDEX,
                    help="index of the BENCH_<n>.json artifact to write")
    ap.add_argument("--out-dir", default="experiments",
                    help="artifact directory")
    args = ap.parse_args(argv)

    tb = PAPER_BYTES if args.full else BENCH_BYTES
    names = list(SUITES) if not args.only else args.only.split(",")
    unknown = [n for n in names if n not in SUITES]
    if unknown:
        ap.error(f"unknown suites {unknown}; choose from {list(SUITES)}")

    all_rows: list[Row] = []
    rows_by_suite: dict[str, dict[str, float]] = {}
    t0 = time.time()
    for name in names:
        print(f"\n######## {name} ########", flush=True)
        t1 = time.time()
        rows = SUITES[name](tb)
        all_rows.extend(rows)
        if rows:
            rows_by_suite[name] = {r.name: r.us_per_call for r in rows}
        else:
            # a suite that skipped itself (missing env) must not enter the
            # trajectory as a 0 us entry — that would read as a regression
            print(f"[{name}] skipped (no rows)", flush=True)
        print(f"[{name}] {time.time()-t1:.1f}s", flush=True)

    print("\n######## CSV (name,us_per_call,derived) ########")
    for r in all_rows:
        print(r.csv())
    _write_csv(all_rows, os.path.join(args.out_dir,
                                      os.path.basename(CSV_PATH)))

    bench_path, payload = perf.emit_trajectory(
        rows_by_suite, directory=args.out_dir, index=args.bench_index)
    print(f"\n######## perf trajectory ({bench_path.name}, "
          f"anchor={payload['anchor']}) ########")
    print(perf.format_trajectory(payload))
    print(f"\n[benchmarks] {len(all_rows)} rows in {time.time()-t0:.1f}s "
          f"-> {args.out_dir}/bench_results.csv, {bench_path}")

    if args.check:
        # gate only on the suites actually run this invocation — carried-
        # over entries from a previous partial run are someone else's news
        gated = {n: payload["suites"][n] for n in rows_by_suite}
        problems = perf.check_trajectory(
            {**payload, "suites": gated})
        if problems:
            print("\n[benchmarks] PERF REGRESSION:", file=sys.stderr)
            for p in problems:
                print(f"  {p}", file=sys.stderr)
            return 1
        print("\n[benchmarks] perf gate: ok")
    return 0


def run_all():  # programmatic entry for tests
    return [r for name in SUITES for r in SUITES[name](BENCH_BYTES)]


if __name__ == "__main__":
    sys.exit(main())
