"""FTL storage backend: replay cost vs the constant model + WA sweep.

Part 1 — replay cost: one random-heavy trace replayed through the
batched engine under ``ssd="constant"`` (stateless, vectorized charge)
and ``ssd="ftl"`` (stateful page-mapped charge in arrival order).  The
FTL's per-request charging is the price of mapping-table fidelity; this
suite tracks it so a regression in the stateful path is caught by the
``--check`` perf gate like any other engine path.

Part 2 — write amplification: the paper's §2.5 rationale measured on
the device model itself.  In-place random overwrites at increasing
occupancy force GC to relocate live pages (WA grows with occupancy);
the log-structured append+trim pattern the burst buffer actually uses
keeps WA at 1.0 regardless.  The suite asserts
``WA(log-store) < WA(in-place)`` — the §2.5 claim — at every occupancy
level swept.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import BENCH_BYTES, Row
from repro.core import IONodeSimulator, TraceBatch, compute_stream_scores
from repro.core.ftl import FTLModel
from repro.core.workloads import GiB, KiB, MiB

REQ_SIZE = 64 * KiB
DEFAULT_REQUESTS = 100_000
FULL_REQUESTS = 400_000

# WA-sweep geometry: small enough that a few MiB of traffic cycles the
# overprovision pool many times, large enough that greedy victim choice
# has real candidates.
WA_GEOM = dict(
    logical_bytes=8 * MiB,
    page_size=4 * KiB,
    pages_per_block=128,
    n_channels=4,
    gc_low_blocks=2,
    gc_high_blocks=4,
)
OCCUPANCIES = (0.5, 0.7, 0.85, 0.95)


def _make_trace(n_requests: int, seed: int = 0) -> TraceBatch:
    rng = np.random.default_rng(seed)
    return TraceBatch(
        offsets=rng.integers(0, 1 << 34, size=n_requests).astype(np.int64),
        sizes=np.full(n_requests, REQ_SIZE, dtype=np.int64),
        file_ids=rng.integers(0, 16, size=n_requests).astype(np.int64),
        app_ids=rng.integers(0, 8, size=n_requests).astype(np.int64),
        times=np.zeros(n_requests),
        gap_positions=np.asarray([], dtype=np.int64),
        gap_seconds=np.asarray([], dtype=np.float64),
    )


def bench_replay_cost(rows: list[Row], n_requests: int) -> None:
    batch = _make_trace(n_requests)
    scores = compute_stream_scores(batch)
    cap = 1 * GiB
    print(f"\n-- batched replay, {n_requests:,} requests, ssdup+ --")
    times = {}
    for backend in ("constant", "ftl"):
        sim = IONodeSimulator(scheme="ssdup+", ssd_capacity=cap, ssd=backend)
        t0 = time.perf_counter()
        res = sim.run(batch, scores=scores)
        times[backend] = time.perf_counter() - t0
        rps = n_requests / times[backend]
        print(f"  {backend:9s} {times[backend]:7.2f}s  {rps:10,.0f} req/s  "
              f"io={res.io_seconds:.3f}s")
        rows.append(Row(
            f"ftl_replay_{backend}",
            times[backend] * 1e6 / n_requests,
            f"req_per_s={rps:.0f}",
        ))
    overhead = times["ftl"] / times["constant"]
    print(f"  stateful-charge overhead: {overhead:.2f}x")


def _wa_inplace(occupancy: float, passes: int = 3, seed: int = 1) -> float:
    """Random in-place overwrites across ``occupancy`` of the space."""

    ftl = FTLModel(**WA_GEOM)
    rng = np.random.default_rng(seed)
    page = WA_GEOM["page_size"]
    pages = int(WA_GEOM["logical_bytes"] // page * occupancy)
    for _ in range(passes):
        offs = (rng.permutation(pages) * page).astype(np.int64)
        ftl.charge_write(offs, np.full(pages, page, dtype=np.int64))
    return ftl.wa


def _wa_logstore(occupancy: float, passes: int = 3) -> float:
    """The burst buffer's pattern: sequential appends over the same
    byte volume, whole-log trim when the region dies."""

    ftl = FTLModel(**WA_GEOM)
    page = WA_GEOM["page_size"]
    span = int(WA_GEOM["logical_bytes"] // page * occupancy) * page
    chunk = 64 * KiB
    for _ in range(passes):
        head = 0
        while head < span:
            n = min(chunk, span - head)
            ftl.charge_write(
                np.array([head], dtype=np.int64),
                np.array([n], dtype=np.int64),
            )
            head += n
        ftl.trim(0, span)
    return ftl.wa


def bench_wa_sweep(rows: list[Row]) -> None:
    print("\n-- write amplification vs occupancy (3 full passes) --")
    print(f"  {'occupancy':>9s} {'WA in-place':>12s} {'WA log-store':>13s}")
    for occ in OCCUPANCIES:
        t0 = time.perf_counter()
        wa_ip = _wa_inplace(occ)
        wa_log = _wa_logstore(occ)
        dt = time.perf_counter() - t0
        print(f"  {occ:9.2f} {wa_ip:12.3f} {wa_log:13.3f}")
        # the §2.5 claim this suite exists to demonstrate: never worse,
        # and strictly better once occupancy pressures the GC (at low
        # occupancy the overprovision pool absorbs the churn and both
        # patterns sit at WA=1.0)
        assert wa_log <= wa_ip, (
            f"log-store WA {wa_log} above in-place WA {wa_ip} "
            f"at occupancy {occ}"
        )
        if occ >= 0.85:
            assert wa_log < wa_ip, (
                f"log-store WA {wa_log} not below in-place WA {wa_ip} "
                f"at occupancy {occ}"
            )
        rows.append(Row(
            f"ftl_wa_occ{int(occ * 100)}",
            dt * 1e6,
            f"wa_inplace={wa_ip:.3f};wa_logstore={wa_log:.3f}",
        ))


def run(total_bytes: int = BENCH_BYTES) -> list[Row]:
    rows: list[Row] = []
    n_requests = FULL_REQUESTS if total_bytes > BENCH_BYTES else DEFAULT_REQUESTS
    bench_replay_cost(rows, n_requests)
    bench_wa_sweep(rows)
    return rows


if __name__ == "__main__":
    run()
