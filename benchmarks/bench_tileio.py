"""Paper Fig. 16: MPI-Tile-IO — 1-D x 2-D tile instances, process sweep.

Two MPI-Tile-IO instances (one 1-D dense, one 2-D dense, 4 KiB elements)
run concurrently with 16..128 processes.  Paper: OrangeFS decays with
process count; SSDUP+ tracks OrangeFS-BB's plateau while buffering ~half
the bytes SSDUP does at 32 procs.
"""

from __future__ import annotations

from benchmarks.common import BENCH_BYTES, Row, emit, timeit
from repro.core import mixed, mpi_tile_io, relabel, run_schemes


def run(total_bytes: int = BENCH_BYTES) -> list[Row]:
    rows: list[Row] = []
    app = total_bytes // 2
    print("\n== Fig 16: MPI-Tile-IO (1-D x 2-D mixed), process sweep ==")
    print(f"{'procs':>5s} | {'orangefs':>10s} | {'orangefs-bb':>20s} | {'ssdup':>20s} | {'ssdup+':>20s}")
    for n in (16, 32, 64, 128):
        w1 = relabel(mpi_tile_io(n, one_dimensional=True, total_bytes=app // 2,
                                 seed=1), app_id=0, file_id=0)
        w2 = relabel(mpi_tile_io(n, one_dimensional=False, total_bytes=app // 2,
                                 seed=2), app_id=1, file_id=1)
        mw = mixed(w1, w2, burst_requests=512)
        us, res = timeit(lambda: run_schemes(mw.trace, ssd_capacity=app))
        cells = [f"{2*res['orangefs'].throughput_mbs:10.1f}"]
        for s in ("orangefs-bb", "ssdup", "ssdup+"):
            r = res[s]
            cells.append(f"{2*r.throughput_mbs:9.1f} {r.ssd_byte_ratio*100:5.1f}%ssd")
        print(f"{n:5d} | " + " | ".join(cells))
        for s in ("orangefs", "orangefs-bb", "ssdup", "ssdup+"):
            r = res[s]
            rows.append(Row(
                f"fig16_{s}_{n}p", us / 4,
                f"agg_mbs={2*r.throughput_mbs:.1f};ssd_ratio={r.ssd_byte_ratio:.3f}"))
    return rows


if __name__ == "__main__":
    emit(run())
