"""Paper Fig. 12: CFQ queue size (= stream length) sensitivity.

The paper re-runs 32-process strided IOR with CFQ queues of 32/128/512 and
reports SSDUP+ improvements of 59.7% / 41.5% / 12.3% over OrangeFS: shorter
sort windows see more randomness (more data redirected), longer windows let
the elevator merge more (less benefit).  Stream length tracks the queue.
"""

from __future__ import annotations

from benchmarks.common import BENCH_BYTES, Row, emit, timeit
from repro.core import IONodeSimulator, ior

PAPER = {32: 59.7, 128: 41.5, 512: 12.3}


def run(total_bytes: int = BENCH_BYTES) -> list[Row]:
    rows: list[Row] = []
    print("\n== Fig 12: stream length (CFQ queue) sensitivity, strided 32p ==")
    print(f"{'queue':>6s} {'orangefs':>10s} {'ssdup+':>10s} {'gain%':>7s} {'paper%':>7s}")
    w = ior("strided", 32, total_bytes=total_bytes // 2)
    for qlen in (32, 128, 512):
        us, base = timeit(lambda: IONodeSimulator(
            scheme="orangefs", stream_len=qlen).run(list(w.trace)))
        _, plus = timeit(lambda: IONodeSimulator(
            scheme="ssdup+", stream_len=qlen,
            ssd_capacity=total_bytes).run(list(w.trace)))
        gain = (plus.throughput_mbs / base.throughput_mbs - 1) * 100
        print(f"{qlen:6d} {2*base.throughput_mbs:10.1f} "
              f"{2*plus.throughput_mbs:10.1f} {gain:7.1f} {PAPER[qlen]:7.1f}")
        rows.append(Row(f"fig12_q{qlen}", us,
                        f"gain_pct={gain:.2f};ssd_ratio={plus.ssd_byte_ratio:.3f}"))
    return rows


if __name__ == "__main__":
    emit(run())
