"""Replay benchmark: batched engine speedup + 16-64-node fleet sweep.

Part 1 — replay speedup: one multi-million-request random trace replayed
through ``IONodeSimulator`` twice: the seed configuration (per-request
engine, AVL index — one Python ``pipeline.append`` + pointer-chasing
``insert`` per request) versus the batched engine (vectorized
``append_batch`` + ``ExtentIndex``, whole-stream accounting).  The two
produce bit-identical ``SimResult``\\ s (asserted here); the acceptance
bar is a >= 5x replay-throughput speedup.

Part 2 — fleet sweep: the same trace sharded over 16/32/64 I/O nodes
(range-offset policy, per-node SSD shrinking with the shard), reporting
aggregate throughput, load imbalance, and replay wall time per fleet —
the scale the ROADMAP's fleet layer targets.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks.common import Row
from repro.core import (
    FleetSimulator,
    IONodeSimulator,
    TraceBatch,
    compute_stream_scores,
)
from repro.core.workloads import GiB, MiB

REQ_SIZE = 64 << 10
DEFAULT_REQUESTS = 1_000_000
FULL_REQUESTS = 4_000_000


def _make_trace(n_requests: int, seed: int = 0) -> TraceBatch:
    """Random-heavy multi-app trace with a mid-trace compute gap."""

    rng = np.random.default_rng(seed)
    return TraceBatch(
        offsets=rng.integers(0, 1 << 38, size=n_requests).astype(np.int64),
        sizes=np.full(n_requests, REQ_SIZE, dtype=np.int64),
        file_ids=rng.integers(0, 16, size=n_requests).astype(np.int64),
        app_ids=rng.integers(0, 8, size=n_requests).astype(np.int64),
        times=np.zeros(n_requests),
        gap_positions=np.asarray([n_requests // 2], dtype=np.int64),
        gap_seconds=np.asarray([30.0]),
    )


def bench_replay_speedup(rows: list[Row], n_requests: int) -> None:
    batch = _make_trace(n_requests)
    scores = compute_stream_scores(batch)
    cap = 8 * GiB
    print(f"\n-- replay engines, {n_requests:,} requests "
          f"({batch.total_bytes / GiB:.0f} GiB logical), ssdup+ --")

    configs = [
        ("per-request+avl", dict(engine="per-request", index_backend="avl")),
        ("per-request+numpy", dict(engine="per-request", index_backend="numpy")),
        ("batched+numpy", dict(engine="batched", index_backend="numpy")),
    ]
    results = {}
    times = {}
    items = None
    for name, kw in configs:
        sim = IONodeSimulator(scheme="ssdup+", ssd_capacity=cap, **kw)
        if kw["engine"] == "per-request":
            if items is None:
                items = batch.to_items()
            trace = items
        else:
            trace = batch
        t0 = time.perf_counter()
        results[name] = sim.run(trace, scores=scores)
        times[name] = time.perf_counter() - t0
        rps = n_requests / times[name]
        speedup = times["per-request+avl"] / times[name]
        print(f"{name:20s} {times[name]:8.2f} s   {rps:12,.0f} req/s   "
              f"{speedup:5.1f}x vs seed")
        rows.append(Row(f"replay_{name.replace('+', '_')}",
                        times[name] * 1e6,
                        f"req_per_s={rps:.0f};speedup={speedup:.1f}"))

    # the speedup must not come from a different answer
    ref = results["per-request+avl"]
    for name, res in results.items():
        for f in dataclasses.fields(ref):
            assert getattr(ref, f.name) == getattr(res, f.name), (
                f"{name} diverged on {f.name}")
    speedup = times["per-request+avl"] / times["batched+numpy"]
    print(f"{'':20s} bit-identical SimResults; batched speedup "
          f"{speedup:.1f}x (bar: >= 5x)")
    assert speedup >= 5.0, f"batched replay speedup {speedup:.2f}x < 5x"


def bench_fleet_sweep(rows: list[Row], n_requests: int) -> None:
    batch = _make_trace(max(n_requests, 1_000_000), seed=1)
    fleet_ssd = batch.total_bytes // 2

    print(f"\n-- fleet sweep, {batch.num_requests:,} requests, "
          "range-offset sharding, ssdup+ --")
    print(f"{'nodes':>5s} {'replay_s':>9s} {'agg MB/s':>10s} "
          f"{'imbalance':>10s} {'ssd_ratio':>10s}")
    for nodes in (16, 32, 64):
        t0 = time.perf_counter()
        fr = FleetSimulator(
            num_nodes=nodes, scheme="ssdup+", policy="range-offset",
            ssd_capacity=max(fleet_ssd // nodes, 64 * MiB),
        ).run(batch)
        dt = time.perf_counter() - t0
        print(f"{nodes:5d} {dt:9.2f} {fr.throughput_mbs:10.1f} "
              f"{fr.load_imbalance:10.2f} {fr.ssd_byte_ratio:10.2f}")
        rows.append(Row(
            f"replay_fleet_{nodes}n", dt * 1e6,
            f"agg_mbs={fr.throughput_mbs:.1f};imbalance={fr.load_imbalance:.2f}",
        ))


def run(total_bytes: int = 2 * GiB) -> list[Row]:
    rows: list[Row] = []
    n = FULL_REQUESTS if total_bytes >= 16 * GiB else DEFAULT_REQUESTS
    print("\n== replay: batched engine speedup + 16-64-node fleet ==")
    bench_replay_speedup(rows, n)
    bench_fleet_sweep(rows, n)
    return rows


if __name__ == "__main__":
    from benchmarks.common import BENCH_BYTES, emit

    emit(run(BENCH_BYTES))
