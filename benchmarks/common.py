"""Shared benchmark plumbing.

Every benchmark prints human-readable tables plus machine lines
``name,us_per_call,derived`` (one per measured configuration) so
``python -m benchmarks.run`` can aggregate a CSV.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

from repro.core.workloads import GiB

# paper-scale is 16 GiB; default bench scale keeps a single-core run short
BENCH_BYTES = 2 * GiB
PAPER_BYTES = 16 * GiB


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.3f},{self.derived}"


def timeit(fn: Callable, *args, repeat: int = 1, **kw) -> tuple[float, object]:
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return best * 1e6, out


def emit(rows: list[Row]) -> None:
    for r in rows:
        print(r.csv(), flush=True)
