"""Paper Fig. 9/13 (constrained SSD, traffic-aware flushing) and Fig. 14
(compute-gap tolerance).

workload1 = segmented-contiguous x segmented-random (bursty mix);
workload2 = segmented-random x segmented-random.
SSD = half the total data; SSDUP+ splits it into two regions.
"""

from __future__ import annotations

from benchmarks.common import BENCH_BYTES, Row, emit, timeit
from repro.core import Gap, IONodeSimulator, ior, mixed, relabel, run_schemes


def fig13(total_bytes: int) -> list[Row]:
    rows: list[Row] = []
    # the traffic-aware-flushing effect needs the paper's phase geometry
    # (bursts small relative to the app volume): pin to >= 8 GiB mixed
    # regardless of the default bench scale
    app = max(total_bytes, 8 * 2**30) // 2
    print("\n== Fig 9/13: constrained SSD (cap = data/2), mixed loads ==")
    for wl_name, p1 in (("workload1", "segmented-contiguous"),
                        ("workload2", "segmented-random")):
        w1 = relabel(ior(p1, 16, total_bytes=app // 2, seed=1), app_id=0, file_id=0)
        w2 = relabel(ior("segmented-random", 16, total_bytes=app // 2, seed=2),
                     app_id=1, file_id=1)
        mw = mixed(w1, w2, burst_requests=512)
        us, res = timeit(lambda: run_schemes(
            mw.trace, schemes=("orangefs-bb", "ssdup", "ssdup+"),
            ssd_capacity=app // 2))
        line = f"{wl_name}: "
        for s in ("orangefs-bb", "ssdup", "ssdup+"):
            r = res[s]
            line += (f"{s}={2*r.throughput_mbs:6.1f}MB/s"
                     f"(pause {r.flush_paused_seconds:4.0f}s,"
                     f" {r.flushes}fl)  ")
            rows.append(Row(f"fig13_{wl_name}_{s}", us / 3,
                            f"agg_mbs={2*r.throughput_mbs:.1f};"
                            f"paused_s={r.flush_paused_seconds:.1f};"
                            f"flushes={r.flushes}"))
        print(line)
        gain = (res["ssdup+"].throughput_mbs / res["ssdup"].throughput_mbs - 1) * 100
        print(f"  SSDUP+ vs SSDUP: {gain:+.1f}%  (paper wl1: +34.8%)")
    return rows


def fig14(total_bytes: int) -> list[Row]:
    rows: list[Row] = []
    app = total_bytes // 4
    print("\n== Fig 14: compute-gap tolerance (2 seg-random phases) ==")
    print(f"{'gap':>4s} {'orangefs-bb':>12s} {'ssdup+':>10s}")
    for gap in (0, 10, 20, 30):
        line = f"{gap:3d}s"
        vals = {}
        for s in ("orangefs-bb", "ssdup+"):
            wa = relabel(ior("segmented-random", 16, total_bytes=app, seed=5),
                         app_id=0, file_id=0)
            wb = relabel(ior("segmented-random", 16, total_bytes=app, seed=6),
                         app_id=1, file_id=1, start_time=1e9)
            trace = list(wa.trace) + [Gap(float(gap))] + list(wb.trace)
            us, r = timeit(lambda: IONodeSimulator(
                scheme=s, ssd_capacity=app).run(trace))
            vals[s] = 2 * r.throughput_mbs
            rows.append(Row(f"fig14_{s}_gap{gap}", us,
                            f"agg_mbs={vals[s]:.1f}"))
        print(f"{line} {vals['orangefs-bb']:12.1f} {vals['ssdup+']:10.1f}")
    return rows


def run(total_bytes: int = BENCH_BYTES) -> list[Row]:
    return fig13(total_bytes) + fig14(total_bytes)


if __name__ == "__main__":
    emit(run())
