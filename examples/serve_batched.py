"""Batched serving example: prefill a prompt batch, decode greedily.

Exercises exactly the path the decode_32k / long_500k dry-run cells lower
(serve_step: one token against a KV cache), at CPU-friendly sizes, for a
dense arch and an SSM arch (O(1)-state decode).

    PYTHONPATH=src python examples/serve_batched.py
"""

import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_smoke_config  # noqa: E402
from repro.launch.serve import pad_cache  # noqa: E402
from repro.launch.steps import make_prefill_step, make_serve_step  # noqa: E402
from repro.models import get_model  # noqa: E402

BATCH, PROMPT, GEN = 4, 24, 16


def serve(arch: str) -> None:
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (BATCH, PROMPT), 0, cfg.vocab_size)
    batch = {"tokens": prompts}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.zeros(
            (BATCH, cfg.n_patches, cfg.d_model), jnp.bfloat16)

    prefill = jax.jit(make_prefill_step(model))
    step = jax.jit(make_serve_step(model), donate_argnums=(1,))

    t0 = time.time()
    logits, cache = prefill(params, batch)
    if cfg.family not in ("ssm",):
        cache = pad_cache(cache, GEN)
    tok = jnp.argmax(logits[:, -1, :], -1)[:, None].astype(jnp.int32)
    toks = [np.asarray(tok)]
    for i in range(GEN - 1):
        tok, _, cache = step(params, cache, tok, jnp.int32(PROMPT + i))
        toks.append(np.asarray(tok))
    dt = time.time() - t0
    gen = np.concatenate(toks, axis=1)
    print(f"{arch:18s} [{cfg.family:6s}] generated {gen.shape} in {dt:5.1f}s "
          f"sample: {gen[0][:8].tolist()}")
    assert gen.shape == (BATCH, GEN)
    assert np.all((gen >= 0) & (gen < cfg.padded_vocab))


def main() -> None:
    for arch in ("qwen3-1.7b", "falcon-mamba-7b"):
        serve(arch)
    print("serve example ok")


if __name__ == "__main__":
    main()
