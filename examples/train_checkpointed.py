"""End-to-end example: train a small LM with burst-buffered checkpointing,
kill it mid-run, and restart from the newest committed manifest.

This is the driver deliverable (train a model for a few hundred steps) in
example form; the same flow scales to the 16x16 production mesh by swapping
``make_host_mesh`` for ``make_production_mesh`` — parameter shardings come
from the same logical axes either way.

    PYTHONPATH=src python examples/train_checkpointed.py [--steps 120]
"""

import argparse
import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.checkpoint import Checkpointer, TieredCheckpointStore  # noqa: E402
from repro.data import DataConfig, ShardedLoader  # noqa: E402
from repro.launch.steps import make_train_step  # noqa: E402
from repro.launch.train import PRESETS  # noqa: E402
from repro.models import get_model  # noqa: E402
from repro.optim import AdamWConfig, init_state, linear_warmup_cosine  # noqa: E402


def train_segment(model, params, opt_state, data, ckpt, start, stop, steps):
    opt_cfg = AdamWConfig(lr=3e-3, schedule=linear_warmup_cosine(10, steps))
    step_fn = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0, 1))
    loss = None
    for step in range(start, stop):
        batch = {k: jax.numpy.asarray(v) for k, v in data.get(step).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        if step % 20 == 0:
            print(f"  step {step:4d} loss {loss:.4f}")
        if (step + 1) % 40 == 0:
            ckpt.save_async(step + 1, {"params": params})
    return params, opt_state, loss


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    args = ap.parse_args()

    cfg = PRESETS["tiny"]
    model = get_model(cfg)
    data = ShardedLoader(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                    global_batch=8), host_id=0)
    root = tempfile.mkdtemp(prefix="ckpt_example_")
    store = TieredCheckpointStore(root, host_id=0)
    ckpt = Checkpointer(store)

    print(f"phase 1: train to step {args.steps // 2} then 'crash'")
    params = model.init_params(jax.random.PRNGKey(0))
    opt_state = init_state(params)
    params, opt_state, loss_a = train_segment(
        model, params, opt_state, data, ckpt, 0, args.steps // 2, args.steps)
    ckpt.wait()  # simulate crash AFTER the last async save commits
    del params, opt_state

    print("phase 2: restart from the newest committed manifest")
    fresh = model.init_params(jax.random.PRNGKey(42))  # wrong weights
    like = {"params": jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), fresh)}
    restored = ckpt.restore_latest(like=like)
    assert restored is not None, "no committed checkpoint found"
    start, tree = restored
    params = jax.tree.map(lambda p, v: jax.numpy.asarray(v, p.dtype),
                          fresh, tree["params"])
    opt_state = init_state(params)  # cold optimizer (could also be saved)
    print(f"  resumed at step {start}")
    params, opt_state, loss_b = train_segment(
        model, params, opt_state, data, ckpt, start, args.steps, args.steps)
    ckpt.close()

    print(f"\nloss before crash: {loss_a:.4f}; final loss: {loss_b:.4f}")
    assert loss_b is not None and np.isfinite(loss_b)
    print(f"checkpoints in {root}")


if __name__ == "__main__":
    main()
