"""Batched replay: a million-request trace through both index backends.

Builds a random-heavy columnar trace (no per-request Python objects),
replays it with the default batched engine under `index_backend="numpy"`
(the vectorized ExtentIndex) and `index_backend="avl"` (the paper's AVL
oracle), and shows that the results agree while the numpy backend is
several times faster — then replays a small slice with the per-request
oracle engine to demonstrate full bit-exactness.

    PYTHONPATH=src python examples/batched_replay.py
"""

import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from repro.core import (  # noqa: E402
    IONodeSimulator,
    TraceBatch,
    compute_stream_scores,
)
from repro.core.workloads import GiB  # noqa: E402


def make_trace(n: int, seed: int = 0) -> TraceBatch:
    rng = np.random.default_rng(seed)
    return TraceBatch(
        offsets=rng.integers(0, 1 << 36, size=n).astype(np.int64),
        sizes=np.full(n, 64 << 10, dtype=np.int64),
        file_ids=rng.integers(0, 8, size=n).astype(np.int64),
        app_ids=rng.integers(0, 4, size=n).astype(np.int64),
        times=np.zeros(n),
        gap_positions=np.asarray([n // 2], dtype=np.int64),  # compute phase
        gap_seconds=np.asarray([20.0]),
    )


def main() -> None:
    n = 1_000_000
    batch = make_trace(n)
    scores = compute_stream_scores(batch)  # once; reused by every replay

    print(f"{n:,} requests, {batch.total_bytes / GiB:.0f} GiB logical, "
          f"{len(scores):,} streams\n")
    results = {}
    for backend in ("numpy", "avl"):
        sim = IONodeSimulator(scheme="ssdup+", ssd_capacity=8 * GiB,
                              index_backend=backend)
        t0 = time.perf_counter()
        results[backend] = sim.run(batch, scores=scores)
        dt = time.perf_counter() - t0
        r = results[backend]
        print(f"index_backend={backend:6s}  replay {dt:6.2f} s  "
              f"throughput {r.throughput_mbs:7.1f} MB/s  "
              f"ssd_ratio {r.ssd_byte_ratio:.2f}  flushes {r.flushes}")

    a, b = results["numpy"], results["avl"]
    assert (a.io_seconds, a.total_seconds, a.bytes_to_ssd) == \
           (b.io_seconds, b.total_seconds, b.bytes_to_ssd)
    print("\nbackends agree bit-for-bit.")

    # the per-request oracle on a small slice: same answer, slowly
    small = make_trace(32_768, seed=1)
    fast = IONodeSimulator(scheme="ssdup+", ssd_capacity=GiB).run(small)
    oracle = IONodeSimulator(scheme="ssdup+", ssd_capacity=GiB,
                             engine="per-request").run(small.to_items())
    assert fast == oracle
    print("batched engine == per-request oracle on the spot-check slice.")


if __name__ == "__main__":
    main()
