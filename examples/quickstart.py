"""Quickstart: SSDUP+ in 60 seconds.

Builds the paper's full pipeline on a synthetic mixed workload:
random-factor detection -> adaptive threshold -> redirection -> two-region
pipeline with traffic-aware flushing, then prints what each scheme would
have done (the paper's Fig. 13 comparison) on the calibrated device model.

    PYTHONPATH=src python examples/quickstart.py
"""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.core import (  # noqa: E402
    AdaptiveThreshold,
    DataRedirector,
    Device,
    ior,
    mixed,
    relabel,
    run_schemes,
)
from repro.core.workloads import GiB  # noqa: E402


def main() -> None:
    # two applications hitting the same I/O node: one sequential writer,
    # one random writer (the paper's workload_1)
    seq_app = relabel(ior("segmented-contiguous", 16, total_bytes=GiB // 2,
                          seed=1), app_id=0, file_id=0)
    rnd_app = relabel(ior("segmented-random", 16, total_bytes=GiB // 2,
                          seed=2), app_id=1, file_id=1)
    workload = mixed(seq_app, rnd_app, burst_requests=512)
    print(f"workload: {len(workload)} requests, "
          f"{workload.total_bytes / 2**30:.1f} GiB from 2 apps")

    # 1) detection + adaptive redirection (paper Sections 2.2-2.3)
    red = DataRedirector(AdaptiveThreshold(window=64))
    routed = list(red.route(workload.trace))
    print(f"\nstreams: {len(routed)}; "
          f"redirected to fast tier: {red.ssd_stream_ratio*100:.1f}% of streams "
          f"({red.ssd_byte_ratio*100:.1f}% of bytes)")
    print(f"final adaptive threshold: {red.policy.threshold:.3f}")
    ssd_pcts = [r.percentage for r in routed if r.device is Device.SSD]
    hdd_pcts = [r.percentage for r in routed if r.device is Device.HDD]
    if ssd_pcts and hdd_pcts:
        print(f"mean pct | fast tier: {sum(ssd_pcts)/len(ssd_pcts):.2f}  "
              f"slow tier: {sum(hdd_pcts)/len(hdd_pcts):.2f}  "
              "(random streams buffered, sequential pass through)")

    # 2) end-to-end scheme comparison under a constrained SSD (Fig. 13)
    print("\nscheme comparison (SSD = half the data):")
    res = run_schemes(workload.trace, ssd_capacity=workload.total_bytes // 2)
    for name, r in res.items():
        print(f"  {name:12s} {2*r.throughput_mbs:7.1f} MB/s aggregate | "
              f"ssd {r.ssd_byte_ratio*100:5.1f}% | "
              f"flush paused {r.flush_paused_seconds:5.1f}s | "
              f"{r.flushes} flushes")
    best = max(res, key=lambda s: res[s].throughput_mbs)
    print(f"\nbest scheme on this trace: {best}")


if __name__ == "__main__":
    main()
