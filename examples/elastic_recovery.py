"""Fault-tolerance example: heartbeats, straggler detection, elastic remesh.

Simulates a 512-host fleet (2 pods x 16 data x 16 model): hosts heartbeat,
two die, one straggles; the controller emits the recovery plan — restart
from the newest checkpoint under a SHRUNK data axis (whole TP groups are
dropped together) plus a work-steal for the straggler.

    PYTHONPATH=src python examples/elastic_recovery.py
"""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.distributed.fault_tolerance import (  # noqa: E402
    FaultToleranceController,
    HeartbeatTable,
    Topology,
)


def main() -> None:
    clock = [0.0]
    table = HeartbeatTable(timeout=30.0, straggler_factor=1.5,
                           clock=lambda: clock[0])
    topo = Topology(pods=2, data=16, model=16)
    ctl = FaultToleranceController(table, topo)
    for h in range(topo.n_hosts):
        table.register(h)

    # steady state: everyone heartbeats with ~1s steps; host 77 runs 2.2x slow
    for t in range(8):
        clock[0] += 10.0
        for h in range(topo.n_hosts):
            if h in (3, 200):  # these two will die at t>40
                if clock[0] <= 40:
                    table.heartbeat(h, 1.0)
                continue
            table.heartbeat(h, 2.2 if h == 77 else 1.0)

    actions = ctl.tick()
    print(f"fleet: {topo.n_hosts} hosts as (pods={topo.pods}, "
          f"data={topo.data}, model={topo.model})")
    for a in actions:
        print(f"\naction: {a.kind}")
        for k, v in a.detail.items():
            print(f"    {k}: {v}")

    kinds = {a.kind for a in actions}
    assert "restart_from_checkpoint" in kinds, "dead hosts not detected"
    assert "steal_shard" in kinds, "straggler not detected"
    new_topo = ctl.topo
    print(f"\nnew topology: pods={new_topo.pods} data={new_topo.data} "
          f"model={new_topo.model} ({new_topo.n_hosts} hosts)")
    print("elastic plan keeps every TP group intact; checkpoints restore "
          "under the new mesh because they store logical arrays "
          "(repro.checkpoint).")


if __name__ == "__main__":
    main()
