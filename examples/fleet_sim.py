"""Fleet simulation: the paper's evaluation scaled from 1 to N I/O nodes.

Shards one mixed multi-app arrival trace across a fleet of I/O nodes under
each trace-sharding policy, replays every shard through the calibrated
single-node simulator (scores precomputed in one vectorized pass), and
prints the aggregate picture: fleet throughput, SSD-byte ratio, load
imbalance, and the straggler node.

    PYTHONPATH=src python examples/fleet_sim.py
"""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.core import (  # noqa: E402
    FleetSimulator,
    TraceBatch,
    ior,
    mixed,
    relabel,
)
from repro.core.workloads import GiB, MiB  # noqa: E402
from repro.distributed.sharding import TRACE_POLICIES  # noqa: E402


def main() -> None:
    per_app = GiB // 4
    apps = [
        relabel(ior("segmented-contiguous", 8, total_bytes=per_app, seed=1),
                app_id=0, file_id=0),
        relabel(ior("segmented-random", 8, total_bytes=per_app, seed=2),
                app_id=1, file_id=1),
        relabel(ior("strided", 32, total_bytes=per_app, seed=3),
                app_id=2, file_id=2),
        relabel(ior("segmented-random", 16, total_bytes=per_app, seed=4),
                app_id=3, file_id=3),
    ]
    load = mixed(*apps, burst_requests=512)
    batch = TraceBatch.from_requests(load.trace)
    print(f"workload: {batch.num_requests} requests, "
          f"{batch.total_bytes / GiB:.2f} GiB from {len(apps)} apps")

    # 1) how each policy spreads the load over 4 nodes
    print("\nsharding policies (4 nodes, ssdup+, per-node SSD = 128 MiB):")
    for policy in sorted(TRACE_POLICIES):
        fleet = FleetSimulator(num_nodes=4, scheme="ssdup+", policy=policy,
                               ssd_capacity=128 * MiB)
        fr = fleet.run(batch)
        loads = ", ".join(f"{b / MiB:.0f}" for b in fr.node_bytes)
        print(f"  {policy:16s} {fr.throughput_mbs:7.1f} MB/s aggregate | "
              f"imbalance {fr.load_imbalance:4.2f} | "
              f"straggler node {fr.straggler} | node MiB [{loads}]")

    # 2) scheme comparison at the paper's 2-node testbed size
    print("\n2-node scheme comparison (paper's testbed aggregate):")
    for scheme in ("orangefs", "orangefs-bb", "ssdup", "ssdup+"):
        fr = FleetSimulator(num_nodes=2, scheme=scheme, policy="range-offset",
                            ssd_capacity=load.total_bytes // 4).run(batch)
        print(f"  {scheme:12s} {fr.throughput_mbs:7.1f} MB/s | "
              f"ssd {fr.ssd_byte_ratio * 100:5.1f}%")


if __name__ == "__main__":
    main()
