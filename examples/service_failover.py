"""Service-layer example: online arrivals, a mid-run crash, failover.

A Poisson-stamped mixed IOR load is dispatched to an 8-node burst-buffer
fleet through the discrete-event service loop.  Node 3 crashes mid-burst:
the heartbeat table times out, the controller declares it dead, its
queued windows are resharded to the survivors, and its unflushed SSD
backlog is replayed on the least-loaded takeover node (Eq. 6 flush
costing).  The byte ledgers must balance to the last byte — every
offered byte completed, every SSD byte flushed/replayed/deduped.

    PYTHONPATH=src python examples/service_failover.py
"""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.core import TraceBatch, ior, mixed, relabel  # noqa: E402
from repro.core.workloads import MiB  # noqa: E402
from repro.service import (  # noqa: E402
    FaultInjector,
    poisson_arrivals,
    run_service_schemes,
)


def main() -> None:
    per_app = 128 * MiB
    apps = [
        relabel(ior("segmented-contiguous", 8, total_bytes=per_app, seed=1),
                app_id=0, file_id=0),
        relabel(ior("segmented-random", 8, total_bytes=per_app, seed=2),
                app_id=1, file_id=1),
        relabel(ior("strided", 16, total_bytes=per_app, seed=3),
                app_id=2, file_id=2),
        relabel(ior("segmented-random", 16, total_bytes=per_app, seed=4),
                app_id=3, file_id=3),
    ]
    load = mixed(*apps, burst_requests=256)
    offered = poisson_arrivals(
        TraceBatch.from_items(load.trace), rate_rps=1500.0, seed=7
    )

    results = run_service_schemes(
        offered,
        num_nodes=8,
        policy="range-offset",
        ssd_capacity=32 * MiB,
        epoch_seconds=0.5,
        heartbeat_timeout=2.0,
        injector=FaultInjector.crash_at(0.8, 3),
    )

    print(f"offered: {offered.total_bytes / MiB:.0f} MiB over 8 nodes, "
          "crash on node 3 at t=0.8s\n")
    print(f"{'scheme':>12s} {'MB/s':>8s} {'p50':>7s} {'p99':>7s} "
          f"{'p999':>7s} {'detect':>7s} {'recover':>8s} {'replayed':>9s}")
    for scheme, r in results.items():
        m = r.metrics
        violations = m.conservation_violations()
        assert not violations, violations
        crash = next(f for f in m.faults if f.kind == "crash")
        print(f"{scheme:>12s} {m.throughput_mbs:8.1f} "
              f"{m.p50_latency:6.2f}s {m.p99_latency:6.2f}s "
              f"{m.p999_latency:6.2f}s {crash.detection_seconds:6.2f}s "
              f"{crash.recovery_seconds:7.2f}s "
              f"{crash.replayed_bytes / MiB:7.1f}Mi")

    m = results["orangefs-bb"].metrics
    print(f"\norangefs-bb ledger: offered={m.offered_bytes / MiB:.0f}Mi "
          f"completed={m.completed_bytes / MiB:.0f}Mi "
          f"ssd={m.written_ssd_bytes / MiB:.0f}Mi "
          f"(flushed={m.flushed_bytes / MiB:.0f}Mi "
          f"replayed={m.replayed_bytes / MiB:.0f}Mi "
          f"deduped={m.deduped_bytes / MiB:.0f}Mi)")
    print("every byte accounted for: the dead node's queue moved to "
          "survivors and its unflushed backlog replayed on the takeover "
          "lane.  Note the traffic-detecting schemes had nothing to "
          "replay — node 3's sequential slice never entered the SSD, so "
          "a blind buffer (orangefs-bb) carries the crash exposure.")


if __name__ == "__main__":
    main()
